"""L2 model correctness: forward shape/semantics, loss, train-step dynamics.

Includes a pure-jnp GCN oracle (no Pallas) to validate the end-to-end forward
used by the artifacts, plus invariants the Rust coordinator relies on:
padding rows are inert, the SGD step equals p - lr*g, Adam state threading.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline env without hypothesis (see Makefile)
    pytest.skip(
        "hypothesis not installed in this environment", allow_module_level=True
    )

from compile import model

jax.config.update("jax_platform_name", "cpu")

B, F1, F2, D, H, C = 8, 4, 4, 12, 16, 5
N1, N2 = B * F1, B * F1 * F2


def _mk_blocks(seed=0, b=B, n1=N1, n2=N2, d=D):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    # row-normalized operators with some zero (padding) rows
    a1 = jax.random.uniform(ks[0], (b, n1))
    a1 = a1 * (jax.random.uniform(ks[1], (b, n1)) > 0.5)
    a1 = a1 / jnp.maximum(a1.sum(1, keepdims=True), 1e-9)
    a2 = jax.random.uniform(ks[2], (n1, n2))
    a2 = a2 * (jax.random.uniform(ks[3], (n1, n2)) > 0.7)
    a2 = a2 / jnp.maximum(a2.sum(1, keepdims=True), 1e-9)
    x0 = jax.random.normal(ks[4], (b, d))
    x1 = jax.random.normal(ks[5], (n1, d))
    x2 = jax.random.normal(jax.random.PRNGKey(seed + 99), (n2, d))
    return {"a1": a1, "a2": a2, "x0": x0, "x1": x1, "x2": x2}


def _init_params(arch, seed=0, d=D, h=H, c=C):
    specs = model.param_specs(arch, d, h, c)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(specs))
    out = {}
    for (name, shape), k in zip(specs, keys):
        fan_in = shape[0] if len(shape) == 2 else shape[0]
        out[name] = jax.random.normal(k, shape) * (1.0 / np.sqrt(fan_in))
    return out


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", model.ARCHS)
def test_forward_shape(arch):
    p = _init_params(arch)
    logits = model.forward(arch, p, _mk_blocks())
    assert logits.shape == (B, C)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_gcn_forward_matches_jnp_oracle():
    """The Pallas-backed GCN forward == plain jnp GCN on the same block."""
    p = _init_params("gcn")
    blocks = _mk_blocks()
    got = model.forward("gcn", p, blocks)
    h1 = jax.nn.relu(blocks["a2"] @ blocks["x2"] @ p["w1"] + p["b1"])
    want = blocks["a1"] @ h1 @ p["w2"] + p["b2"]
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_mlp_ignores_graph():
    """MLP must be invariant to the aggregation operators (Fig 10b)."""
    p = _init_params("mlp")
    b1, b2 = _mk_blocks(0), _mk_blocks(1)
    b2 = dict(b2, x0=b1["x0"])
    np.testing.assert_allclose(
        model.forward("mlp", p, b1), model.forward("mlp", p, b2), rtol=1e-6
    )


def test_gcn_depends_on_graph():
    p = _init_params("gcn")
    b1, b2 = _mk_blocks(0), _mk_blocks(1)
    b2 = dict(b2, x0=b1["x0"])
    assert not np.allclose(
        model.forward("gcn", p, b1), model.forward("gcn", p, b2), atol=1e-3
    )


@pytest.mark.parametrize("arch", ["gcn", "sage", "gat", "appnp"])
def test_isolated_row_gives_finite_output(arch):
    """A target with zero A1 row (no sampled neighbors) must stay finite."""
    p = _init_params(arch)
    blocks = _mk_blocks()
    a1 = np.asarray(blocks["a1"]).copy()
    a1[0, :] = 0.0
    blocks = dict(blocks, a1=jnp.asarray(a1))
    logits = model.forward(arch, p, blocks)
    assert bool(jnp.all(jnp.isfinite(logits)))


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------
def test_softmax_ce_masked():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0], [5.0, 5.0]])
    y = jnp.asarray([0, 1, 0], jnp.int32)
    full = model.loss_fn("softmax_ce", logits, y, jnp.asarray([1.0, 1.0, 1.0]))
    masked = model.loss_fn("softmax_ce", logits, y, jnp.asarray([1.0, 1.0, 0.0]))
    assert masked < full  # dropping the uncertain row lowers the mean
    assert float(masked) < 1e-3


def test_softmax_ce_uniform_is_log_c():
    logits = jnp.zeros((4, 7))
    y = jnp.asarray([0, 1, 2, 3], jnp.int32)
    l = model.loss_fn("softmax_ce", logits, y, jnp.ones(4))
    np.testing.assert_allclose(float(l), np.log(7.0), rtol=1e-5)


def test_sigmoid_bce_perfect_prediction():
    y = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
    logits = (y * 2 - 1) * 20.0
    l = model.loss_fn("sigmoid_bce", logits, y, jnp.ones(2))
    assert float(l) < 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bce_matches_naive(seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = jax.random.normal(k1, (6, 9)) * 3
    y = (jax.random.uniform(k2, (6, 9)) > 0.5).astype(jnp.float32)
    got = model.loss_fn("sigmoid_bce", logits, y, jnp.ones(6))
    p = jax.nn.sigmoid(logits)
    naive = -jnp.mean(y * jnp.log(p + 1e-12) + (1 - y) * jnp.log(1 - p + 1e-12))
    np.testing.assert_allclose(float(got), float(naive), rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------
def _flat_params(arch):
    p = _init_params(arch)
    return [p[n] for n, _ in model.param_specs(arch, D, H, C)]


def _block_args(loss="softmax_ce"):
    blocks = _mk_blocks()
    y = (
        jnp.arange(B, dtype=jnp.int32) % C
        if loss == "softmax_ce"
        else (jax.random.uniform(jax.random.PRNGKey(5), (B, C)) > 0.5).astype(
            jnp.float32
        )
    )
    mask = jnp.ones((B,), jnp.float32)
    return [blocks["a1"], blocks["a2"], blocks["x0"], blocks["x1"], blocks["x2"], y, mask]


@pytest.mark.parametrize("arch", model.ARCHS)
def test_sgd_step_decreases_loss(arch):
    step, n_params, n_opt = model.make_train_step(arch, "softmax_ce", "sgd", D, H, C)
    params = _flat_params(arch)
    args = _block_args()
    lr = jnp.asarray(0.1, jnp.float32)
    out1 = step(*params, *args, lr)
    out2 = step(*out1[1:], *args, lr)
    out3 = step(*out2[1:], *args, lr)
    assert float(out3[0]) < float(out1[0])


def test_sgd_step_is_p_minus_lr_g():
    step, n_params, _ = model.make_train_step("gcn", "softmax_ce", "sgd", D, H, C)
    params = _flat_params("gcn")
    args = _block_args()
    lr = jnp.asarray(0.05, jnp.float32)

    names = [n for n, _ in model.param_specs("gcn", D, H, C)]

    def obj(plist):
        logits = model.forward("gcn", dict(zip(names, plist)),
                               dict(zip(["a1","a2","x0","x1","x2"], args[:5])))
        return model.loss_fn("softmax_ce", logits, args[5], args[6])

    grads = jax.grad(obj)(params)
    out = step(*params, *args, lr)
    for p, g, pn in zip(params, grads, out[1:]):
        np.testing.assert_allclose(pn, p - 0.05 * g, rtol=2e-3, atol=2e-4)


def test_adam_step_threads_state_and_learns():
    step, n_params, n_opt = model.make_train_step("gcn", "softmax_ce", "adam", D, H, C)
    assert n_opt == 2 * n_params + 1
    params = _flat_params("gcn")
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    t = jnp.asarray(0.0, jnp.float32)
    args = _block_args()
    lr = jnp.asarray(0.01, jnp.float32)
    state = [*params, *m, *v, t]
    losses = []
    for _ in range(5):
        out = step(*state, *args, lr)
        losses.append(float(out[0]))
        state = list(out[1:])
    assert losses[-1] < losses[0]
    assert float(state[-1]) == 5.0  # t incremented once per step
    # second moment (v) is a sum of squares — must be non-negative everywhere
    for vi in state[2 * n_params : 3 * n_params]:
        assert bool(jnp.all(vi >= 0.0))


def test_masked_rows_do_not_affect_gradient():
    """Zeroing a row's mask must make its label irrelevant (padding safety)."""
    step, _, _ = model.make_train_step("gcn", "softmax_ce", "sgd", D, H, C)
    params = _flat_params("gcn")
    args = _block_args()
    lr = jnp.asarray(0.1, jnp.float32)
    mask = np.ones(B, np.float32)
    mask[0] = 0.0
    args[6] = jnp.asarray(mask)
    y2 = np.asarray(args[5]).copy()
    y2[0] = (y2[0] + 1) % C
    out_a = step(*params, *args, lr)
    args_b = list(args)
    args_b[5] = jnp.asarray(y2)
    out_b = step(*params, *args_b, lr)
    for pa, pb in zip(out_a[1:], out_b[1:]):
        np.testing.assert_allclose(pa, pb, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("arch", ["gcn", "sage"])
def test_eval_step_matches_forward(arch):
    estep, n_params = model.make_eval_step(arch, D, H, C)
    params = _flat_params(arch)
    blocks = _mk_blocks()
    (logits,) = estep(
        *params, blocks["a1"], blocks["a2"], blocks["x0"], blocks["x1"], blocks["x2"]
    )
    names = [n for n, _ in model.param_specs(arch, D, H, C)]
    want = model.forward(arch, dict(zip(names, params)), blocks)
    np.testing.assert_allclose(logits, want, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# jit parity: the exact jitted function that aot.py lowers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["gcn", "sage"])
def test_jit_matches_eager(arch):
    step, n_params, _ = model.make_train_step(arch, "softmax_ce", "sgd", D, H, C)
    params = _flat_params(arch)
    args = _block_args()
    lr = jnp.asarray(0.1, jnp.float32)
    eager = step(*params, *args, lr)
    jitted = jax.jit(step)(*params, *args, lr)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)


# --------------------------------------------------------------------------
# pure-jnp oracles for the remaining architectures (GCN's is above)
# --------------------------------------------------------------------------
def test_sage_forward_matches_jnp_oracle():
    p = _init_params("sage")
    bl = _mk_blocks()
    got = model.forward("sage", p, bl)
    relu = jax.nn.relu
    h1 = relu(bl["x1"] @ p["ws1"] + (bl["a2"] @ bl["x2"]) @ p["wn1"] + p["b1"])
    h0 = relu(bl["x0"] @ p["ws1"] + (bl["a1"] @ bl["x1"]) @ p["wn1"] + p["b1"])
    want = h0 @ p["ws2"] + p["b2"] + (bl["a1"] @ h1) @ p["wn2"]
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_appnp_forward_matches_jnp_oracle():
    p = _init_params("appnp")
    bl = _mk_blocks()
    got = model.forward("appnp", p, bl)

    def mlp(x):
        return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    beta = model.APPNP_TELEPORT
    z1 = beta * mlp(bl["x1"]) + (1 - beta) * (bl["a2"] @ mlp(bl["x2"]))
    want = beta * mlp(bl["x0"]) + (1 - beta) * (bl["a1"] @ z1)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_gat_forward_matches_jnp_oracle():
    p = _init_params("gat")
    bl = _mk_blocks()
    got = model.forward("gat", p, bl)

    def gat_layer(a, xr, xc, w, asrc, adst, b, relu_out):
        zc, zr = xc @ w, xr @ w
        e = (zr @ asrc)[:, None] + (zc @ adst)[None, :]
        e = jnp.where(e > 0, e, 0.2 * e)
        adj = (a > 0).astype(e.dtype)
        e = jnp.where(adj > 0, e, -1e30)
        ex = jnp.exp(e - jnp.max(e, axis=1, keepdims=True)) * adj
        alpha = ex / jnp.maximum(ex.sum(1, keepdims=True), 1e-9)
        out = alpha @ zc + b[None, :]
        return jax.nn.relu(out) if relu_out else out

    h1 = gat_layer(bl["a2"], bl["x1"], bl["x2"], p["w1"], p["asrc1"], p["adst1"], p["b1"], True)
    h0 = gat_layer(bl["a1"], bl["x0"], bl["x1"], p["w1"], p["asrc1"], p["adst1"], p["b1"], True)
    want = gat_layer(bl["a1"], h0, h1, p["w2"], p["asrc2"], p["adst2"], p["b2"], False)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_gat_attention_sums_to_one_on_real_rows():
    """Indirect invariant: scaling one neighbor's features changes only
    that row's output (attention is row-local)."""
    p = _init_params("gat")
    b1 = _mk_blocks()
    x1 = np.asarray(b1["x1"]).copy()
    x1[0] *= 5.0
    b2 = dict(b1, x1=jnp.asarray(x1))
    o1 = model.forward("gat", p, b1)
    o2 = model.forward("gat", p, b2)
    assert not np.allclose(o1, o2, atol=1e-5)


@pytest.mark.parametrize("arch", ["gat", "appnp"])
def test_train_step_learns_all_archs_jit(arch):
    step, n_params, n_opt = model.make_train_step(arch, "softmax_ce", "adam", D, H, C)
    params = _flat_params(arch)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    t = jnp.asarray(0.0, jnp.float32)
    args = _block_args()
    lr = jnp.asarray(0.01, jnp.float32)
    jstep = jax.jit(step, keep_unused=True)
    state = [*params, *m, *v, t]
    losses = []
    for _ in range(6):
        out = jstep(*state, *args, lr)
        losses.append(float(out[0]))
        state = list(out[1:])
    assert losses[-1] < losses[0], f"{arch} did not learn: {losses}"
