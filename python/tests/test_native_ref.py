"""Cross-check of the Rust native reference backend's math.

``rust/src/runtime/native.rs`` hand-derives forward/backward/optimizer for
mlp / gcn / sage / appnp so the coordinator can run without PJRT. This test
transcribes those exact formulas into numpy and checks them against
``jax.value_and_grad`` over the real L2 models (``compile.model``) — if the
formulas here match JAX, the Rust transcription computes the same training
trajectory as the HLO artifacts.

Kept op-for-op in sync with native.rs: if you change one, change both.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

B, F1, F2, D, H, C = 6, 3, 3, 10, 12, 5
N1, N2 = B * F1, B * F1 * F2

ADAM_B1, ADAM_B2, ADAM_EPS = model.ADAM_B1, model.ADAM_B2, model.ADAM_EPS
BETA = model.APPNP_TELEPORT

NATIVE_ARCHS = ("mlp", "gcn", "sage", "appnp")


# --------------------------------------------------------------------------
# block + param builders (banded, row-normalized, padded — sampler-shaped)
# --------------------------------------------------------------------------
def _banded(rows, cols, f, live_rows, rng):
    """Row-normalized operator with non-zeros only in each row's slot band,
    zero rows beyond ``live_rows`` (padding) — the Rust sampler's layout."""
    a = np.zeros((rows, cols), np.float32)
    for i in range(live_rows):
        lo = i * f
        width = rng.integers(1, f + 1)
        a[i, lo : lo + width] = 1.0 / width
    return a


def _mk_block(seed=0, live=B - 2):
    rng = np.random.default_rng(seed)
    return {
        "a1": _banded(B, N1, F1, live, rng),
        "a2": _banded(N1, N2, F2, live * F1, rng),
        "x0": rng.standard_normal((B, D)).astype(np.float32),
        "x1": rng.standard_normal((N1, D)).astype(np.float32),
        "x2": rng.standard_normal((N2, D)).astype(np.float32),
        "mask": (np.arange(B) < live).astype(np.float32),
        "y_class": rng.integers(0, C, B).astype(np.int32),
        "y_multi": (rng.random((B, C)) > 0.5).astype(np.float32),
    }


def _mk_params(arch, seed=1):
    rng = np.random.default_rng(seed)
    return [
        (0.4 * rng.standard_normal(shape)).astype(np.float32)
        for _, shape in model.param_specs(arch, D, H, C)
    ]


# --------------------------------------------------------------------------
# numpy transcription of native.rs (losses, forward, backward, optimizers)
# --------------------------------------------------------------------------
def _relu(x):
    return np.maximum(x, 0.0)


def _loss_grad(loss, logits, blk):
    mask = blk["mask"]
    denom = max(mask.sum(), 1.0)
    g = np.zeros_like(logits)
    total = 0.0
    if loss == "softmax_ce":
        y = blk["y_class"]
        for i in range(logits.shape[0]):
            if mask[i] == 0.0:
                continue
            row = logits[i]
            m = row.max()
            ex = np.exp(row - m)
            s = ex.sum()
            total += mask[i] * (np.log(s) - (row[y[i]] - m))
            p = ex / s
            p[y[i]] -= 1.0
            g[i] = mask[i] / denom * p
    else:  # sigmoid_bce
        y = blk["y_multi"]
        for i in range(logits.shape[0]):
            if mask[i] == 0.0:
                continue
            z = logits[i]
            bce = np.maximum(z, 0.0) - z * y[i] + np.log1p(np.exp(-np.abs(z)))
            total += mask[i] * bce.mean()
            sig = 1.0 / (1.0 + np.exp(-z))
            g[i] = mask[i] / denom * (sig - y[i]) / z.shape[0]
    return total / denom, g


def _ref_forward_backward(arch, loss, params, blk):
    """native.rs ``loss_and_grads``: returns (loss, [grads in param order])."""
    a1, a2 = blk["a1"], blk["a2"]
    x0, x1, x2 = blk["x0"], blk["x1"], blk["x2"]

    if arch == "mlp":
        w1, b1, w2, b2 = params
        h1 = _relu(x0 @ w1 + b1)
        logits = h1 @ w2 + b2
        lval, g = _loss_grad(loss, logits, blk)
        dw2 = h1.T @ g
        db2 = g.sum(0)
        dh1 = g @ w2.T
        dh1[h1 <= 0] = 0.0
        dw1 = x0.T @ dh1
        db1 = dh1.sum(0)
        return lval, [dw1, db1, dw2, db2]

    if arch == "gcn":
        w1, b1, w2, b2 = params
        agg2 = a2 @ x2
        h1 = _relu(agg2 @ w1 + b1)
        agg1 = a1 @ h1
        logits = agg1 @ w2 + b2
        lval, g = _loss_grad(loss, logits, blk)
        dw2 = agg1.T @ g
        db2 = g.sum(0)
        dagg1 = g @ w2.T
        dh1 = a1.T @ dagg1
        dh1[h1 <= 0] = 0.0
        dw1 = agg2.T @ dh1
        db1 = dh1.sum(0)
        return lval, [dw1, db1, dw2, db2]

    if arch == "sage":
        ws1, wn1, b1, ws2, wn2, b2 = params
        n1v = a2 @ x2
        h1 = _relu(x1 @ ws1 + n1v @ wn1 + b1)
        n0 = a1 @ h1
        m0 = a1 @ x1
        h0 = _relu(x0 @ ws1 + m0 @ wn1 + b1)
        logits = h0 @ ws2 + n0 @ wn2 + b2
        lval, g = _loss_grad(loss, logits, blk)
        dws2 = h0.T @ g
        dwn2 = n0.T @ g
        db2 = g.sum(0)
        dh0 = g @ ws2.T
        dh0[h0 <= 0] = 0.0
        dn0 = g @ wn2.T
        dh1 = a1.T @ dn0
        dh1[h1 <= 0] = 0.0
        dws1 = x0.T @ dh0 + x1.T @ dh1
        dwn1 = m0.T @ dh0 + n1v.T @ dh1
        db1 = dh0.sum(0) + dh1.sum(0)
        return lval, [dws1, dwn1, db1, dws2, dwn2, db2]

    if arch == "appnp":
        w1, b1, w2, b2 = params

        def mlp(x):
            u = _relu(x @ w1 + b1)
            return u @ w2 + b2, u

        h2, u2 = mlp(x2)
        h1v, u1 = mlp(x1)
        h0, u0 = mlp(x0)
        p1 = BETA * h1v + (1.0 - BETA) * (a2 @ h2)
        logits = BETA * h0 + (1.0 - BETA) * (a1 @ p1)
        lval, g = _loss_grad(loss, logits, blk)
        dp1 = (1.0 - BETA) * (a1.T @ g)
        dh2 = (1.0 - BETA) * (a2.T @ dp1)
        dh1v = BETA * dp1
        dh0 = BETA * g
        dw1 = np.zeros_like(w1)
        db1 = np.zeros_like(b1)
        dw2 = np.zeros_like(w2)
        db2 = np.zeros_like(b2)
        for x, u, dh in ((x2, u2, dh2), (x1, u1, dh1v), (x0, u0, dh0)):
            dw2 += u.T @ dh
            db2 += dh.sum(0)
            du = dh @ w2.T
            du[u <= 0] = 0.0
            dw1 += x.T @ du
            db1 += du.sum(0)
        return lval, [dw1, db1, dw2, db2]

    raise ValueError(arch)


def _ref_train_step(arch, loss, optimizer, params, opt, blk, lr):
    """native.rs ``train_step``: in-place update, returns loss."""
    lval, grads = _ref_forward_backward(arch, loss, params, blk)
    if optimizer == "sgd":
        for p, g in zip(params, grads):
            p -= lr * g
        return lval
    n = len(params)
    ms, vs, t = opt[:n], opt[n : 2 * n], opt[2 * n]
    t1 = np.float32(t[()]) + np.float32(1.0)
    t[()] = t1
    # f32 scalar arithmetic throughout, matching both JAX and native.rs
    b1, b2 = np.float32(ADAM_B1), np.float32(ADAM_B2)
    one, eps, lr32 = np.float32(1.0), np.float32(ADAM_EPS), np.float32(lr)
    bc1 = one - b1**t1
    bc2 = one - b2**t1
    for p, g, m, v in zip(params, grads, ms, vs):
        m[...] = b1 * m + (one - b1) * g
        v[...] = b2 * v + (one - b2) * g * g
        p -= lr32 * (m / bc1) / (np.sqrt(v / bc2) + eps)
    return lval


# --------------------------------------------------------------------------
# the checks
# --------------------------------------------------------------------------
def _jax_loss_and_grads(arch, loss, params, blk):
    names = [n for n, _ in model.param_specs(arch, D, H, C)]
    blocks = {k: jnp.asarray(blk[k]) for k in ("a1", "a2", "x0", "x1", "x2")}
    y = jnp.asarray(blk["y_class"] if loss == "softmax_ce" else blk["y_multi"])
    mask = jnp.asarray(blk["mask"])

    def objective(plist):
        logits = model.forward(arch, dict(zip(names, plist)), blocks)
        return model.loss_fn(loss, logits, y, mask)

    lval, grads = jax.value_and_grad(objective)([jnp.asarray(p) for p in params])
    return float(lval), [np.asarray(g) for g in grads]


@pytest.mark.parametrize("arch", NATIVE_ARCHS)
@pytest.mark.parametrize("loss", model.LOSSES)
def test_reference_gradients_match_jax(arch, loss):
    blk = _mk_block(seed=3)
    params = _mk_params(arch, seed=4)
    l_jax, g_jax = _jax_loss_and_grads(arch, loss, params, blk)
    l_ref, g_ref = _ref_forward_backward(arch, loss, [p.copy() for p in params], blk)
    assert l_ref == pytest.approx(l_jax, rel=1e-5, abs=1e-6)
    for name_shape, gj, gr in zip(model.param_specs(arch, D, H, C), g_jax, g_ref):
        np.testing.assert_allclose(
            gr, gj, rtol=2e-4, atol=2e-5,
            err_msg=f"{arch}/{loss}: grad mismatch for {name_shape[0]}",
        )


@pytest.mark.parametrize("arch", ["gcn", "sage"])
@pytest.mark.parametrize("optimizer", model.OPTIMIZERS)
def test_reference_train_step_matches_jax(arch, optimizer):
    loss, lr, steps = "softmax_ce", 0.05, 3
    blk = _mk_block(seed=5)
    params0 = _mk_params(arch, seed=6)
    n = len(params0)

    step, n_params, n_opt = model.make_train_step(arch, loss, optimizer, D, H, C)
    assert n_params == n
    jp = [jnp.asarray(p) for p in params0]
    jopt = (
        [jnp.zeros_like(p) for p in jp] * 2 + [jnp.zeros((), jnp.float32)]
        if optimizer == "adam"
        else []
    )
    block_args = (
        jnp.asarray(blk["a1"]), jnp.asarray(blk["a2"]), jnp.asarray(blk["x0"]),
        jnp.asarray(blk["x1"]), jnp.asarray(blk["x2"]),
        jnp.asarray(blk["y_class"]), jnp.asarray(blk["mask"]),
        jnp.float32(lr),
    )

    rp = [p.copy() for p in params0]
    ropt = (
        [np.zeros_like(p) for p in rp] + [np.zeros_like(p) for p in rp]
        + [np.zeros((), np.float32)]
        if optimizer == "adam"
        else []
    )

    for s in range(steps):
        out = step(*jp, *jopt, *block_args)
        l_jax = float(out[0])
        jp = list(out[1 : 1 + n])
        if optimizer == "adam":
            jopt = list(out[1 + n :])
            assert float(jopt[-1]) == s + 1
        l_ref = _ref_train_step(arch, loss, optimizer, rp, ropt, blk, lr)
        # multi-step f32 trajectories reassociate differently under XLA
        # fusion vs numpy; single-step gradients are compared tightly above
        assert l_ref == pytest.approx(l_jax, rel=1e-4, abs=1e-5), f"step {s}"

    for pj, pr in zip(jp, rp):
        np.testing.assert_allclose(
            pr, np.asarray(pj), rtol=5e-4, atol=5e-5,
            err_msg=f"{arch}/{optimizer}: params diverged after {steps} steps",
        )


def test_padded_rows_get_no_gradient_signal():
    # loss must be invariant to logits of masked rows: zero their grads
    blk = _mk_block(seed=7, live=3)
    params = _mk_params("gcn", seed=8)
    _, g = _jax_loss_and_grads("gcn", "softmax_ce", params, blk)
    _, gr = _ref_forward_backward("gcn", "softmax_ce", params, blk)
    for gj, grr in zip(g, gr):
        np.testing.assert_allclose(grr, gj, rtol=2e-4, atol=2e-5)
    # and the masked-mean denominator is the live count
    lval, _ = _ref_forward_backward("gcn", "softmax_ce", params, blk)
    assert np.isfinite(lval) and lval > 0
