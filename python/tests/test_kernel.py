"""L1 kernel correctness: Pallas kernels vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes (the system contract: any block shape the
Rust sampler can emit must agree with ref.py to f32 tolerance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline env without hypothesis (see Makefile)
    pytest.skip(
        "hypothesis not installed in this environment", allow_module_level=True
    )

from compile.kernels import aggregate as ag
from compile.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = dict(rtol=2e-3, atol=2e-3)  # f32 accumulation-order slack


# --------------------------------------------------------------------------
# block_aggregate
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 64, 16),
        (32, 256, 64),  # tiny dataset block shape
        (128, 128, 128),  # exactly one tile
        (129, 257, 130),  # off-tile remainders in every dim
        (256, 2048, 64),  # paper-scale block shape
    ],
)
def test_block_aggregate_shapes(m, k, n):
    a = _rand(0, (m, k), jnp.float32)
    x = _rand(1, (k, n), jnp.float32)
    np.testing.assert_allclose(
        ag.block_aggregate(a, x), ref.block_aggregate_ref(a, x), **TOL
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_aggregate_dtypes(dtype):
    a = _rand(0, (16, 96), dtype)
    x = _rand(1, (96, 24), dtype)
    got = ag.block_aggregate(a, x)
    want = ref.block_aggregate_ref(a, x)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=5e-2, atol=5e-2
    )


def test_block_aggregate_zero_rows_are_padding():
    """Zero rows in A (padding slots) must produce exactly-zero outputs."""
    a = np.zeros((8, 32), np.float32)
    a[0, :4] = 0.25
    x = np.asarray(_rand(3, (32, 12), jnp.float32))
    out = np.asarray(ag.block_aggregate(jnp.asarray(a), jnp.asarray(x)))
    assert np.all(out[1:] == 0.0)
    np.testing.assert_allclose(out[0], a[0] @ x, **TOL)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 192),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_aggregate_hypothesis(m, k, n, seed):
    a = _rand(seed, (m, k), jnp.float32)
    x = _rand(seed + 1, (k, n), jnp.float32)
    np.testing.assert_allclose(
        ag.block_aggregate(a, x), ref.block_aggregate_ref(a, x), **TOL
    )


@settings(max_examples=15, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 128]),
    bk=st.sampled_from([8, 32, 128]),
)
def test_block_aggregate_tile_sweep(bm, bn, bk):
    """Result must be tile-size independent (the perf knobs are safe)."""
    a = _rand(7, (48, 160), jnp.float32)
    x = _rand(8, (160, 40), jnp.float32)
    np.testing.assert_allclose(
        ag.block_aggregate(a, x, bm=bm, bn=bn, bk=bk),
        ref.block_aggregate_ref(a, x),
        **TOL,
    )


# --------------------------------------------------------------------------
# matmul_bias_act / fused layer
# --------------------------------------------------------------------------
@pytest.mark.parametrize("act", ["none", "relu", "leaky_relu"])
def test_matmul_bias_act(act):
    x = _rand(0, (40, 72), jnp.float32)
    w = _rand(1, (72, 24), jnp.float32)
    b = _rand(2, (24,), jnp.float32)
    np.testing.assert_allclose(
        ag.matmul_bias_act(x, w, b, act=act),
        ref.matmul_bias_act_ref(x, w, b, act=act),
        **TOL,
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 128),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "relu", "leaky_relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_act_hypothesis(m, k, n, act, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)
    np.testing.assert_allclose(
        ag.matmul_bias_act(x, w, b, act=act),
        ref.matmul_bias_act_ref(x, w, b, act=act),
        **TOL,
    )


def test_fused_gcn_layer():
    a = _rand(0, (32, 256), jnp.float32)
    x = _rand(1, (256, 64), jnp.float32)
    w = _rand(2, (64, 48), jnp.float32)
    b = _rand(3, (48,), jnp.float32)
    np.testing.assert_allclose(
        ag.fused_gcn_layer(a, x, w, b),
        ref.fused_gcn_layer_ref(a, x, w, b),
        rtol=5e-3,
        atol=5e-3,
    )


# --------------------------------------------------------------------------
# custom_vjp wrappers: gradients vs jnp autodiff of the oracle
# --------------------------------------------------------------------------
def _grad_check(fn, fn_ref, args, tol=5e-3):
    g = jax.grad(lambda *a: jnp.sum(fn(*a) ** 2), argnums=tuple(range(len(args))))(
        *args
    )
    gr = jax.grad(
        lambda *a: jnp.sum(fn_ref(*a) ** 2), argnums=tuple(range(len(args)))
    )(*args)
    for u, v in zip(g, gr):
        np.testing.assert_allclose(u, v, rtol=tol, atol=tol)


def test_aggregate_grad():
    a = _rand(0, (24, 80), jnp.float32)
    x = _rand(1, (80, 20), jnp.float32)
    _grad_check(ops.aggregate, ref.block_aggregate_ref, (a, x))


@pytest.mark.parametrize("act", ["none", "relu", "leaky_relu"])
def test_linear_grad(act):
    x = _rand(0, (24, 48), jnp.float32)
    w = _rand(1, (48, 16), jnp.float32)
    b = _rand(2, (16,), jnp.float32)
    _grad_check(
        lambda x, w, b: ops.linear(x, w, b, act),
        lambda x, w, b: ref.matmul_bias_act_ref(x, w, b, act=act),
        (x, w, b),
    )


def test_gcn_layer_grad():
    a = _rand(0, (16, 64), jnp.float32)
    x = _rand(1, (64, 24), jnp.float32)
    w = _rand(2, (24, 8), jnp.float32)
    b = _rand(3, (8,), jnp.float32)
    _grad_check(
        lambda a, x, w, b: ops.gcn_layer(a, x, w, b),
        lambda a, x, w, b: ref.fused_gcn_layer_ref(a, x, w, b),
        (a, x, w, b),
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 32),
    k=st.integers(2, 64),
    n=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_aggregate_grad_hypothesis(m, k, n, seed):
    a = _rand(seed, (m, k), jnp.float32)
    x = _rand(seed + 1, (k, n), jnp.float32)
    _grad_check(ops.aggregate, ref.block_aggregate_ref, (a, x))
