"""AOT pipeline tests: lowering produces loadable HLO text with the input
signature the Rust runtime expects, and the manifest is consistent.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_dataset_configs_are_consistent():
    for name, cfg in aot.DATASETS.items():
        assert cfg["loss"] in model.LOSSES, name
        assert all(a in model.ARCHS for a in cfg["archs"]), name
        assert cfg["b"] >= 1 and cfg["f1"] >= 1 and cfg["f2"] >= 1


@pytest.mark.parametrize("arch", ["gcn", "mlp"])
@pytest.mark.parametrize("opt", ["sgd", "adam"])
def test_lower_train_produces_hlo_text(arch, opt):
    cfg = aot.DATASETS["tiny"]
    text, n_params, n_opt, (n1, n2) = aot.lower_train(arch, "tiny", cfg, opt)
    assert text.startswith("HloModule")
    assert n1 == cfg["b"] * cfg["f1"]
    assert n2 == n1 * cfg["f2"]
    # the entry computation must keep every input (keep_unused=True):
    # params + opt + 8 block inputs
    n_inputs = n_params + n_opt + 8
    assert f"parameter({n_inputs - 1})" in text, "missing last parameter"
    assert f"parameter({n_inputs})" not in text, "too many parameters"


def test_lower_eval_signature():
    cfg = aot.DATASETS["tiny"]
    text, n_params, _ = aot.lower_eval("gcn", "tiny", cfg)
    n_inputs = n_params + 5
    assert f"parameter({n_inputs - 1})" in text
    assert f"parameter({n_inputs})" not in text


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "arts")
    aot.build(out, ["tiny"], ["mlp"])
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"mlp_adam_tiny", "mlp_sgd_tiny", "mlp_eval_tiny"}
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(out, a["file"]))
        dims = a["dims"]
        assert dims["n1"] == dims["b"] * dims["f1"]
        assert dims["n2"] == dims["n1"] * dims["f2"]
        if a["optimizer"] == "adam":
            assert a["n_opt"] == 2 * len(a["params"]) + 1
        else:
            assert a["n_opt"] == 0


def test_lowered_step_executes_and_matches_eager():
    """Round-trip: the lowered train step compiled with jax must agree with
    the eager step — the same check the Rust integration does via PJRT."""
    cfg = aot.DATASETS["tiny"]
    d, c, h, b = cfg["d"], cfg["c"], cfg["h"], cfg["b"]
    n1, n2 = b * cfg["f1"], b * cfg["f1"] * cfg["f2"]
    step, n_params, _ = model.make_train_step("gcn", cfg["loss"], "sgd", d, h, c)
    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), s.shape) * 0.1
        for i, s in enumerate(model.param_shape_structs("gcn", d, h, c))
    ]
    blocks = []
    for i, spec in enumerate(model.block_specs(b, n1, n2, d, c, cfg["loss"])):
        k = jax.random.fold_in(key, 100 + i)
        if spec.dtype == jnp.int32:
            blocks.append(jax.random.randint(k, spec.shape, 0, c))
        elif spec.shape == ():
            blocks.append(jnp.asarray(0.05, jnp.float32))
        else:
            blocks.append(jax.random.uniform(k, spec.shape))
    eager = step(*params, *blocks)
    jitted = jax.jit(step, keep_unused=True)(*params, *blocks)
    for a, bb in zip(eager, jitted):
        np.testing.assert_allclose(a, bb, rtol=5e-3, atol=5e-4)


def test_roofline_analysis_fits_vmem():
    from compile.kernels import roofline

    t = roofline.analyze("test", 256, 2048, 64)
    assert t.fits_vmem
    assert 0.0 < t.mxu_utilization <= 1.0
    big = roofline.TileAnalysis(8192, 8192, 8192, 2048, 2048, 2048)
    assert not big.fits_vmem
