"""AOT compile path: lower every (arch, entrypoint, dataset-shape) train/eval
step to HLO **text** + write ``artifacts/manifest.json`` for the Rust runtime.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py and its README).

Run once via ``make artifacts`` (no-op when inputs are unchanged); Python is
never on the training path.

Usage:
    python -m compile.aot --out-dir ../artifacts [--datasets tiny,arxiv-s]
                          [--archs gcn,sage] [--list]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
from jax._src.lib import xla_client as xc

from . import model

# --------------------------------------------------------------------------
# Dataset shape configs: synthetic analogs of the paper's datasets (Table 2),
# scaled for the CPU-PJRT testbed; the generator parameters that realize each
# analog live on the Rust side (graph/generators.rs + config/datasets.rs).
# d: input feature dim, c: classes, h: hidden, b: batch, f1/f2: fan-outs.
# --------------------------------------------------------------------------
DATASETS: Dict[str, Dict] = {
    # fast shapes for unit/integration tests and the quickstart example
    "tiny": dict(d=16, c=4, h=16, b=8, f1=4, f2=4, loss="softmax_ce",
                 archs=("gcn", "sage", "mlp")),
    # decoupled-label variant used by gap smoke-tests; same shape as tiny
    "tiny-hetero": dict(d=16, c=4, h=16, b=8, f1=4, f2=4, loss="softmax_ce",
                        archs=("gcn", "sage")),
    "flickr-s": dict(d=64, c=7, h=64, b=32, f1=8, f2=8, loss="softmax_ce",
                     archs=("gcn", "sage", "gat", "appnp")),
    "proteins-s": dict(d=16, c=16, h=64, b=32, f1=8, f2=8, loss="sigmoid_bce",
                       archs=("gcn", "sage", "gat", "appnp")),
    "arxiv-s": dict(d=32, c=16, h=64, b=32, f1=8, f2=8, loss="softmax_ce",
                    archs=("gcn", "sage", "gat", "appnp")),
    "reddit-s": dict(d=64, c=16, h=64, b=32, f1=8, f2=8, loss="softmax_ce",
                     archs=("gcn", "sage", "gat", "appnp")),
    "yelp-s": dict(d=32, c=12, h=64, b=32, f1=8, f2=8, loss="sigmoid_bce",
                   archs=("gcn", "mlp")),
    "products-s": dict(d=32, c=12, h=64, b=32, f1=8, f2=8, loss="softmax_ce",
                       archs=("sage", "gcn")),
}

OPTIMIZERS = ("adam", "sgd")


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train(arch: str, ds_name: str, cfg: Dict, optimizer: str):
    d, c, h, b = cfg["d"], cfg["c"], cfg["h"], cfg["b"]
    n1, n2 = b * cfg["f1"], b * cfg["f1"] * cfg["f2"]
    step, n_params, n_opt = model.make_train_step(
        arch, cfg["loss"], optimizer, d, h, c
    )
    pspecs = model.param_shape_structs(arch, d, h, c)
    ospecs = []
    if optimizer == "adam":
        ospecs = pspecs + pspecs + [jax.ShapeDtypeStruct((), jax.numpy.float32)]
    bspecs = model.block_specs(b, n1, n2, d, c, cfg["loss"])
    lowered = jax.jit(step, keep_unused=True).lower(*pspecs, *ospecs, *bspecs)
    return to_hlo_text(lowered), n_params, n_opt, (n1, n2)


def lower_eval(arch: str, ds_name: str, cfg: Dict):
    d, c, h, b = cfg["d"], cfg["c"], cfg["h"], cfg["b"]
    n1, n2 = b * cfg["f1"], b * cfg["f1"] * cfg["f2"]
    step, n_params = model.make_eval_step(arch, d, h, c)
    pspecs = model.param_shape_structs(arch, d, h, c)
    bspecs = model.block_specs(b, n1, n2, d, c, cfg["loss"])[:5]
    lowered = jax.jit(step, keep_unused=True).lower(*pspecs, *bspecs)
    return to_hlo_text(lowered), n_params, (n1, n2)


def build(out_dir: str, datasets: List[str], archs_filter: List[str] | None):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "artifacts": []}
    for ds in datasets:
        cfg = DATASETS[ds]
        d, c, h, b = cfg["d"], cfg["c"], cfg["h"], cfg["b"]
        archs = [a for a in cfg["archs"] if not archs_filter or a in archs_filter]
        for arch in archs:
            pspecs = model.param_specs(arch, d, h, c)
            pjson = [{"name": n, "shape": list(s)} for n, s in pspecs]
            for opt in OPTIMIZERS:
                name = f"{arch}_{opt}_{ds}"
                text, n_params, n_opt, (n1, n2) = lower_train(arch, ds, cfg, opt)
                fname = f"{name}.hlo.txt"
                with open(os.path.join(out_dir, fname), "w") as f:
                    f.write(text)
                manifest["artifacts"].append(
                    {
                        "name": name,
                        "file": fname,
                        "kind": "train",
                        "arch": arch,
                        "optimizer": opt,
                        "loss": cfg["loss"],
                        "dataset": ds,
                        "dims": {
                            "b": b, "n1": n1, "n2": n2,
                            "d": d, "h": h, "c": c,
                            "f1": cfg["f1"], "f2": cfg["f2"],
                        },
                        "params": pjson,
                        "n_opt": n_opt,
                    }
                )
                print(f"  wrote {fname} ({len(text)} chars)")
            name = f"{arch}_eval_{ds}"
            text, n_params, (n1, n2) = lower_eval(arch, ds, cfg)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "file": fname,
                    "kind": "eval",
                    "arch": arch,
                    "optimizer": "none",
                    "loss": cfg["loss"],
                    "dataset": ds,
                    "dims": {
                        "b": b, "n1": n1, "n2": n2,
                        "d": d, "h": h, "c": c,
                        "f1": cfg["f1"], "f2": cfg["f2"],
                    },
                    "params": pjson,
                    "n_opt": 0,
                }
            )
            print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--datasets", default=",".join(DATASETS.keys()))
    ap.add_argument("--archs", default="")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    datasets = [d for d in args.datasets.split(",") if d]
    unknown = [d for d in datasets if d not in DATASETS]
    if unknown:
        raise SystemExit(f"unknown datasets: {unknown}")
    if args.list:
        for ds, cfg in DATASETS.items():
            print(ds, cfg)
        return
    archs = [a for a in args.archs.split(",") if a] or None
    build(args.out_dir, datasets, archs)


if __name__ == "__main__":
    main()
