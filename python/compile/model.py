"""L2: GNN models (GCN / SAGE / GAT / APPNP / MLP) forward + backward + optimizer
step as pure JAX, built on the L1 Pallas kernels (``kernels.ops``).

This module is *build-time only*: ``aot.py`` lowers the jitted train/eval
steps to HLO text once; the Rust coordinator executes the artifacts via PJRT
for the whole training run.  Python never touches the training path.

Mini-batch block format (built by the Rust sampler, DESIGN.md §L2):

    A1  [B,  N1]   row-normalized aggregation operator, targets <- 1-hop
    A2  [N1, N2]   row-normalized aggregation operator, 1-hop  <- 2-hop
    X0  [B,  d]    target features        (self features, SAGE/APPNP/MLP)
    X1  [N1, d]    1-hop slot features
    X2  [N2, d]    2-hop slot features
    Y   [B] i32 (softmax_ce) or [B, C] f32 (sigmoid_bce)
    mask[B] f32    1.0 for real batch rows, 0.0 for padding

Zero rows of A* are padding slots; every model maps zero rows to zero
contributions.  All aggregation matmuls lower into the Pallas kernels.

Entry points lowered per (arch, optimizer, dataset-shape) by aot.py:

    train_step(params.., [opt..,] A1, A2, X0, X1, X2, Y, mask, lr)
        -> (loss, params'.., [opt'..])
    eval_step(params.., A1, A2, X0, X1, X2) -> (logits,)
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import ops

ARCHS = ("mlp", "gcn", "sage", "gat", "appnp")
LOSSES = ("softmax_ce", "sigmoid_bce")
OPTIMIZERS = ("sgd", "adam")

APPNP_TELEPORT = 0.1  # beta in Eq. 12
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# --------------------------------------------------------------------------
# Parameter specs (shared with the Rust side through the manifest)
# --------------------------------------------------------------------------
def param_specs(arch: str, d: int, h: int, c: int) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list for ``arch``; the manifest records this
    order and Rust packs/averages parameters positionally."""
    if arch == "mlp":
        return [("w1", (d, h)), ("b1", (h,)), ("w2", (h, c)), ("b2", (c,))]
    if arch == "gcn":
        return [("w1", (d, h)), ("b1", (h,)), ("w2", (h, c)), ("b2", (c,))]
    if arch == "sage":
        return [
            ("ws1", (d, h)),
            ("wn1", (d, h)),
            ("b1", (h,)),
            ("ws2", (h, c)),
            ("wn2", (h, c)),
            ("b2", (c,)),
        ]
    if arch == "gat":
        return [
            ("w1", (d, h)),
            ("asrc1", (h,)),
            ("adst1", (h,)),
            ("b1", (h,)),
            ("w2", (h, c)),
            ("asrc2", (c,)),
            ("adst2", (c,)),
            ("b2", (c,)),
        ]
    if arch == "appnp":
        return [("w1", (d, h)), ("b1", (h,)), ("w2", (h, c)), ("b2", (c,))]
    raise ValueError(f"unknown arch {arch!r}")


# --------------------------------------------------------------------------
# Architectures (Appendix A.2, Eq. 6-12, on sampled blocks)
# --------------------------------------------------------------------------
def _gat_layer(a, xr, xc, w, a_src, a_dst, b, act):
    """Masked dense GAT layer (Eq. 10-11).

    ``a`` is used only as a mask (entries > 0 = real edges); attention
    replaces the mean weights.  Rows with no neighbors produce zeros.
    """
    zc = ops.linear(xc, w, jnp.zeros((w.shape[1],), w.dtype), "none")  # [Cn,h]
    zr = ops.linear(xr, w, jnp.zeros((w.shape[1],), w.dtype), "none")  # [R, h]
    er = zc @ a_dst  # source-side term, per column node
    el = zr @ a_src  # target-side term, per row node
    e = el[:, None] + er[None, :]
    e = jnp.where(e > 0, e, 0.2 * e)  # LeakyReLU(0.2)
    adj = (a > 0).astype(e.dtype)
    neg = jnp.full_like(e, -1e30)
    e = jnp.where(adj > 0, e, neg)
    emax = jnp.max(e, axis=1, keepdims=True)
    ex = jnp.exp(e - jax.lax.stop_gradient(emax)) * adj
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-9)
    alpha = ex / denom
    out = ops.aggregate(alpha, zc) + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def forward(arch: str, params: Dict[str, jax.Array], blocks: Dict[str, jax.Array]):
    """Two-layer ``arch`` forward on a sampled block; returns logits [B, C]."""
    a1, a2 = blocks["a1"], blocks["a2"]
    x0, x1, x2 = blocks["x0"], blocks["x1"], blocks["x2"]
    p = params

    if arch == "mlp":
        h1 = ops.linear(x0, p["w1"], p["b1"], "relu")
        return ops.linear(h1, p["w2"], p["b2"], "none")

    if arch == "gcn":
        # Eq. 1: h = relu(mean_agg(X) @ W); aggregation is the Pallas kernel.
        h1 = ops.gcn_layer(a2, x2, p["w1"], p["b1"], act="relu")
        return ops.gcn_layer(a1, h1, p["w2"], p["b2"], act="none")

    if arch == "sage":
        # Eq. 7: h = relu(x W_s + mean_agg(X) W_n)
        n1 = ops.aggregate(a2, x2)
        h1 = jnp.maximum(
            ops.linear(x1, p["ws1"], p["b1"], "none")
            + ops.linear(n1, p["wn1"], jnp.zeros_like(p["b1"]), "none"),
            0.0,
        )
        n0 = ops.aggregate(a1, h1)
        # self-representation at level 0 re-encodes x0 (and its 1-hop mean)
        # through the layer-1 weights — standard for sampled SAGE blocks.
        h0_self = jnp.maximum(
            ops.linear(x0, p["ws1"], p["b1"], "none")
            + ops.linear(
                ops.aggregate(a1, x1), p["wn1"], jnp.zeros_like(p["b1"]), "none"
            ),
            0.0,
        )
        return ops.linear(h0_self, p["ws2"], p["b2"], "none") + ops.linear(
            n0, p["wn2"], jnp.zeros_like(p["b2"]), "none"
        )

    if arch == "gat":
        # layer-1 embeddings at the 1-hop slots (from 2-hop features) and at
        # the targets themselves (from 1-hop features), then attention again.
        h1 = _gat_layer(a2, x1, x2, p["w1"], p["asrc1"], p["adst1"], p["b1"], "relu")
        h0 = _gat_layer(a1, x0, x1, p["w1"], p["asrc1"], p["adst1"], p["b1"], "relu")
        return _gat_layer(a1, h0, h1, p["w2"], p["asrc2"], p["adst2"], p["b2"], "none")

    if arch == "appnp":
        # Eq. 12: graph-agnostic MLP prediction + 2 personalized-PageRank
        # propagation steps over the sampled block.
        def mlp(x):
            return ops.linear(
                ops.linear(x, p["w1"], p["b1"], "relu"), p["w2"], p["b2"], "none"
            )

        beta = APPNP_TELEPORT
        h2, h1v, h0 = mlp(x2), mlp(x1), mlp(x0)
        z1 = beta * h1v + (1.0 - beta) * ops.aggregate(a2, h2)
        z0 = beta * h0 + (1.0 - beta) * ops.aggregate(a1, z1)
        return z0

    raise ValueError(f"unknown arch {arch!r}")


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------
def loss_fn(loss: str, logits: jax.Array, y: jax.Array, mask: jax.Array):
    """Masked mean loss over the batch (Eq. 2 estimator)."""
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    if loss == "softmax_ce":
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)[:, 0]
        return jnp.sum(nll * mask) / denom
    if loss == "sigmoid_bce":
        z = logits
        # numerically stable BCE-with-logits
        bce = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
        return jnp.sum(jnp.mean(bce, axis=-1) * mask) / denom
    raise ValueError(f"unknown loss {loss!r}")


# --------------------------------------------------------------------------
# Train / eval steps (the lowered entry points)
# --------------------------------------------------------------------------
def _split(flat: Sequence[jax.Array], names: Sequence[str]) -> Dict[str, jax.Array]:
    assert len(flat) == len(names)
    return dict(zip(names, flat))


def make_train_step(arch: str, loss: str, optimizer: str, d: int, h: int, c: int):
    """Returns ``(fn, n_params, n_opt)`` where ``fn`` is the flat-signature
    train step lowered by aot.py.

    SGD:   p' = p - lr * g                                  (Alg. 2 line 8)
    Adam:  standard bias-corrected Adam on the local machine (App. A.2).
    """
    specs = param_specs(arch, d, h, c)
    names = [n for n, _ in specs]
    n_params = len(names)
    n_opt = 2 * n_params + 1 if optimizer == "adam" else 0

    def step(*args):
        params = list(args[:n_params])
        off = n_params
        if optimizer == "adam":
            m = list(args[off : off + n_params])
            v = list(args[off + n_params : off + 2 * n_params])
            t = args[off + 2 * n_params]
            off += n_opt
        a1, a2, x0, x1, x2, y, mask, lr = args[off : off + 8]
        blocks = {"a1": a1, "a2": a2, "x0": x0, "x1": x1, "x2": x2}

        def objective(plist):
            logits = forward(arch, _split(plist, names), blocks)
            return loss_fn(loss, logits, y, mask)

        lval, grads = jax.value_and_grad(objective)(params)

        if optimizer == "sgd":
            new = [p - lr * g for p, g in zip(params, grads)]
            return (lval, *new)

        t1 = t + 1.0
        new_m = [ADAM_B1 * mi + (1 - ADAM_B1) * g for mi, g in zip(m, grads)]
        new_v = [ADAM_B2 * vi + (1 - ADAM_B2) * g * g for vi, g in zip(v, grads)]
        mhat = [mi / (1 - ADAM_B1**t1) for mi in new_m]
        vhat = [vi / (1 - ADAM_B2**t1) for vi in new_v]
        new = [
            p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS)
            for p, mh, vh in zip(params, mhat, vhat)
        ]
        return (lval, *new, *new_m, *new_v, t1)

    return step, n_params, n_opt


def make_eval_step(arch: str, d: int, h: int, c: int):
    """Returns ``(fn, n_params)``; ``fn(params.., A1, A2, X0, X1, X2) ->
    (logits,)`` — the server-side validation / correction-metric path."""
    specs = param_specs(arch, d, h, c)
    names = [n for n, _ in specs]
    n_params = len(names)

    def step(*args):
        params = _split(list(args[:n_params]), names)
        a1, a2, x0, x1, x2 = args[n_params : n_params + 5]
        blocks = {"a1": a1, "a2": a2, "x0": x0, "x1": x1, "x2": x2}
        return (forward(arch, params, blocks),)

    return step, n_params


# --------------------------------------------------------------------------
# Shape helpers for lowering
# --------------------------------------------------------------------------
def block_specs(b: int, n1: int, n2: int, d: int, c: int, loss: str):
    """ShapeDtypeStructs of (A1, A2, X0, X1, X2, Y, mask, lr)."""
    f32 = jnp.float32
    y = (
        jax.ShapeDtypeStruct((b,), jnp.int32)
        if loss == "softmax_ce"
        else jax.ShapeDtypeStruct((b, c), f32)
    )
    return (
        jax.ShapeDtypeStruct((b, n1), f32),
        jax.ShapeDtypeStruct((n1, n2), f32),
        jax.ShapeDtypeStruct((b, d), f32),
        jax.ShapeDtypeStruct((n1, d), f32),
        jax.ShapeDtypeStruct((n2, d), f32),
        y,
        jax.ShapeDtypeStruct((b,), f32),
        jax.ShapeDtypeStruct((), f32),
    )


def param_shape_structs(arch: str, d: int, h: int, c: int):
    return [
        jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_specs(arch, d, h, c)
    ]
