"""L1 Pallas kernels: the GNN aggregation hot spot.

The paper's compute hot spot is sparse neighbor aggregation (cuSPARSE SpMM /
PyG scatter on the authors' GPUs).  On TPU, scatter/gather is hostile to the
MXU systolic array, so the standard re-think (DESIGN.md §Hardware-Adaptation)
is: the host (Rust sampler) builds a *row-normalized dense aggregation
operator* ``A`` for each mini-batch block, and aggregation becomes a dense
blocked matmul ``A @ X`` that the MXU eats natively.

Two kernels:

- ``block_aggregate(A, X)``     — tiled matmul with an f32 VMEM accumulator,
  grid ``(M/bm, N/bn, K/bk)``; the HBM->VMEM schedule the paper's GPU code
  expressed with threadblocks is expressed here with ``BlockSpec``.
- ``matmul_bias_act(X, W, b)``  — same loop nest with a fused
  bias + activation epilogue (one HBM round-trip instead of three); chained
  after ``block_aggregate`` this gives the fused GCN layer
  ``act((A @ X) @ W + b)``.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and these artifacts execute on the Rust CPU client.
Real-TPU perf is *estimated* from VMEM footprint + MXU utilization in
``roofline.py`` (see DESIGN.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (perf pass 1, EXPERIMENTS.md §Perf): MXU-native 128+
# multiples, sized to use the VMEM budget rather than the minimum.  Large BK
# amortizes the grid loop — on CPU-interpret each grid step is a while-loop
# iteration (pure overhead), on real TPU each is a DMA round-trip.  Working
# set at (256, 2048, 256): A 2 MiB + B 2 MiB + acc 0.25 MiB, ~8.5 MiB with
# input double-buffering — inside the ~16 MiB VMEM budget (roofline.py
# prints the exact footprint per shape).
DEF_BM = 256
DEF_BN = 256
DEF_BK = 2048


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-d array up to (rows, cols)."""
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _matmul_kernel(a_ref, b_ref, o_ref, *, nk: int, act: str, acc_dtype):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis.

    o_ref is revisited across the K steps and doubles as the accumulator
    (interpret mode has no multi-buffer hazard; on real TPU the same pattern
    works because the output block index map ignores the K axis, so the tile
    stays resident in VMEM across the K loop).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(acc_dtype)
    b = b_ref[...].astype(acc_dtype)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype).astype(
        o_ref.dtype
    )

    if act != "none":

        @pl.when(k == nk - 1)
        def _epilogue():
            x = o_ref[...]
            if act == "relu":
                x = jnp.maximum(x, 0.0)
            elif act == "leaky_relu":
                x = jnp.where(x > 0, x, 0.2 * x)
            o_ref[...] = x


def _bias_act_kernel(a_ref, b_ref, bias_ref, o_ref, *, nk: int, act: str, acc_dtype):
    """Matmul with fused bias-add + activation epilogue."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(acc_dtype)
    b = b_ref[...].astype(acc_dtype)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype).astype(
        o_ref.dtype
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        x = o_ref[...] + bias_ref[...].astype(o_ref.dtype)
        if act == "relu":
            x = jnp.maximum(x, 0.0)
        elif act == "leaky_relu":
            x = jnp.where(x > 0, x, 0.2 * x)
        o_ref[...] = x


def _tiled_matmul(
    a: jax.Array,
    b: jax.Array,
    bias: Optional[jax.Array],
    act: str,
    bm: int,
    bn: int,
    bk: int,
) -> jax.Array:
    """Shared driver: pad to tile multiples, run the grid, slice back."""
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0], (
        a.shape,
        b.shape,
    )
    m, k = a.shape
    _, n = b.shape
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    # Small operands: shrink tiles rather than blowing up the pad ratio.
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 128) if n > 128 else _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 128) if k > 128 else _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    a = _pad2(a, mp, kp)
    b = _pad2(b, kp, np_)
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [a, b]
    if bias is None:
        kernel = functools.partial(
            _matmul_kernel, nk=nk, act=act, acc_dtype=jnp.float32
        )
    else:
        bias2 = _pad2(bias.reshape(1, -1), 1, np_)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args.append(bias2)
        kernel = functools.partial(
            _bias_act_kernel, nk=nk, act=act, acc_dtype=jnp.float32
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,
    )(*args)
    return out[:m, :n]


def block_aggregate(
    a: jax.Array,
    x: jax.Array,
    *,
    bm: int = DEF_BM,
    bn: int = DEF_BN,
    bk: int = DEF_BK,
) -> jax.Array:
    """Neighbor aggregation ``A @ X`` for a sampled block.

    ``a`` is the row-normalized dense aggregation operator built by the Rust
    sampler (rows: target slots, cols: neighbor slots; zero rows = padding),
    ``x`` the gathered neighbor features.
    """
    return _tiled_matmul(a, x, None, "none", bm, bn, bk)


def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    bm: int = DEF_BM,
    bn: int = DEF_BN,
    bk: int = DEF_BK,
) -> jax.Array:
    """Fused ``act(x @ w + b)`` — dense transform with epilogue fusion."""
    return _tiled_matmul(x, w, b, act, bm, bn, bk)


def fused_gcn_layer(
    a: jax.Array,
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
) -> jax.Array:
    """One GCN layer ``act((A @ X) @ W + b)``.

    Aggregation first: for fan-out blocks ``A`` is (rows x cols) with
    cols >> rows, so ``(A@X)@W`` does ``rows*cols*d + rows*d*h`` FLOPs versus
    ``cols*d*h + rows*cols*h`` for ``A@(XW)`` — with rows << cols and d >= h
    the former touches less HBM; roofline.py quantifies both orders.
    """
    return matmul_bias_act(block_aggregate(a, x), w, b, act=act)
