"""L1 kernel perf model: VMEM footprint + MXU-utilization estimates.

``interpret=True`` Pallas gives CPU-numpy timings only — NOT a TPU proxy —
so per DESIGN.md §Perf the kernel is optimized *structurally*: pick tile
shapes whose working set fits VMEM with room for double-buffering and whose
matmul panels keep the 128x128 MXU full. This module prints that analysis
for the shipped block shapes (and is exercised by the pytest suite).

Usage: python -m compile.kernels.roofline
"""

from __future__ import annotations

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM, v4-ish
MXU_DIM = 128
F32 = 4


@dataclass
class TileAnalysis:
    m: int
    k: int
    n: int
    bm: int
    bk: int
    bn: int

    @property
    def tiles_bytes(self) -> int:
        """Working set of one grid step: A-tile + B-tile + out/acc tile."""
        return F32 * (self.bm * self.bk + self.bk * self.bn + self.bm * self.bn)

    @property
    def double_buffered_bytes(self) -> int:
        """Input tiles are double-buffered (overlap DMA with compute)."""
        return self.tiles_bytes + F32 * (self.bm * self.bk + self.bk * self.bn)

    @property
    def fits_vmem(self) -> bool:
        return self.double_buffered_bytes <= VMEM_BYTES

    @property
    def mxu_utilization(self) -> float:
        """Fraction of MXU lanes kept busy by the tile shape: a bm x bk @
        bk x bn matmul engages min(d, 128)/128 of each systolic dimension,
        discounted by padding waste on the real (m, k, n)."""

        def eff(dim: int, tile: int) -> float:
            lane = min(tile, MXU_DIM) / MXU_DIM
            # padding waste: last tile in the dim is partially full
            full = dim / tile
            used = full / -(-full // 1) if tile <= dim else dim / tile
            return lane * min(1.0, used)

        return eff(self.m, self.bm) * eff(self.k, self.bk) * eff(self.n, self.bn)

    @property
    def flops(self) -> int:
        return 2 * self.m * self.k * self.n

    @property
    def hbm_bytes(self) -> int:
        """Bytes moved assuming each input panel is read once per reuse
        pass: A read n/bn times, B read m/bm times, C written once."""
        reads_a = -(-self.n // self.bn) * self.m * self.k
        reads_b = -(-self.m // self.bm) * self.k * self.n
        return F32 * (reads_a + reads_b + self.m * self.n)

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.hbm_bytes


def analyze(name: str, m: int, k: int, n: int, bm: int = 128, bk: int = 128, bn: int = 128):
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    t = TileAnalysis(m, k, n, bm, bk, bn)
    print(
        f"{name:<38} {m:>5}x{k:<5}x{n:<4} tiles {bm:>3}x{bk:<3}x{bn:<3} "
        f"vmem={t.double_buffered_bytes/1024:7.0f}KiB fit={'Y' if t.fits_vmem else 'N'} "
        f"mxu={t.mxu_utilization:5.2f} AI={t.arithmetic_intensity:6.1f} flop/B"
    )
    return t


def main() -> None:
    print("kernel shape analysis (paper-scale block shapes, B=32 f=8x8):")
    b, n1, n2, d, h, c = 32, 256, 2048, 64, 64, 16
    # layer 1: aggregate A2 @ X2 then (.) @ W1
    analyze("block_aggregate(A2@X2)", n1, n2, d)
    analyze("matmul_bias_act(H@W1)", n1, d, h)
    # layer 2
    analyze("block_aggregate(A1@H1)", b, n1, h)
    analyze("matmul_bias_act(H@W2)", b, h, c)
    # fused-layer alternative order: A @ (X W) — more FLOPs when rows<<cols
    a2xw_first = analyze("alt-order X2@W1 then A2@(XW)", n2, d, h)
    agg_first = analyze("ship-order (A2@X2)@W1 total", n1, n2, d)
    flops_agg_first = agg_first.flops + 2 * n1 * d * h
    flops_xw_first = a2xw_first.flops + 2 * n1 * n2 * h
    print(
        f"\norder check: aggregate-first {flops_agg_first/1e6:.1f} MFLOP vs "
        f"transform-first {flops_xw_first/1e6:.1f} MFLOP "
        f"({'aggregate-first wins' if flops_agg_first < flops_xw_first else 'transform-first wins'} at d={d}, h={h})"
    )


if __name__ == "__main__":
    main()
