"""L1 Pallas kernels + pure-jnp oracles.

``ops`` exposes the differentiable wrappers the L2 model consumes; the raw
kernels live in ``aggregate``; the oracles in ``ref``.
"""

from . import aggregate, ops, ref  # noqa: F401
