"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the pytest/hypothesis suite checks the kernels
against (``assert_allclose``), and the baselines the roofline comparison
uses.  Keep them boring: one obvious jnp expression per kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_aggregate_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """Oracle for ``aggregate.block_aggregate``: plain dense matmul."""
    return jnp.dot(
        a.astype(jnp.float32), x.astype(jnp.float32), preferred_element_type=jnp.float32
    ).astype(jnp.promote_types(a.dtype, x.dtype))


def _act(x: jax.Array, act: str) -> jax.Array:
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "leaky_relu":
        return jnp.where(x > 0, x, 0.2 * x)
    if act == "none":
        return x
    raise ValueError(f"unknown act {act!r}")


def matmul_bias_act_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, act: str = "relu"
) -> jax.Array:
    """Oracle for ``aggregate.matmul_bias_act``."""
    y = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    y = y + b.astype(jnp.float32)[None, :]
    return _act(y, act).astype(jnp.promote_types(x.dtype, w.dtype))


def fused_gcn_layer_ref(
    a: jax.Array, x: jax.Array, w: jax.Array, b: jax.Array, *, act: str = "relu"
) -> jax.Array:
    """Oracle for ``aggregate.fused_gcn_layer``: act((A@X)@W + b)."""
    return matmul_bias_act_ref(block_aggregate_ref(a, x), w, b, act=act)
