"""Differentiable wrappers around the L1 Pallas kernels.

``pallas_call`` in interpret mode has no automatic VJP, so each kernel gets a
``jax.custom_vjp`` whose backward pass is expressed *in terms of the same
Pallas kernels* (matmul transposes) — the backward of the hot spot stays on
the hot path and lowers into the same tiled HLO as the forward.

    y = A @ X            =>  dA = g @ X^T,  dX = A^T @ g
    y = act(X @ W + b)   =>  dpre = g * act'(y);
                             dX = dpre @ W^T, dW = X^T @ dpre, db = sum(dpre)

(`act'` is recoverable from y for relu/leaky_relu because both are monotone
with sign(pre) == sign(y).)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import aggregate as ag


# --------------------------------------------------------------------------
# aggregate: y = A @ X
# --------------------------------------------------------------------------
@jax.custom_vjp
def aggregate(a: jax.Array, x: jax.Array) -> jax.Array:
    """Differentiable ``A @ X`` neighbor aggregation (Pallas)."""
    return ag.block_aggregate(a, x)


def _aggregate_fwd(a, x):
    return ag.block_aggregate(a, x), (a, x)


def _aggregate_bwd(res, g):
    a, x = res
    da = ag.block_aggregate(g, x.T)
    dx = ag.block_aggregate(a.T, g)
    return da, dx


aggregate.defvjp(_aggregate_fwd, _aggregate_bwd)


# --------------------------------------------------------------------------
# linear: y = act(X @ W + b)
# --------------------------------------------------------------------------
def _act_grad_from_y(y: jax.Array, act: str) -> jax.Array:
    if act == "relu":
        return (y > 0).astype(y.dtype)
    if act == "leaky_relu":
        return jnp.where(y > 0, 1.0, 0.2).astype(y.dtype)
    if act == "none":
        return jnp.ones_like(y)
    raise ValueError(f"unknown act {act!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none") -> jax.Array:
    """Differentiable fused ``act(x @ w + b)`` (Pallas, epilogue-fused)."""
    return ag.matmul_bias_act(x, w, b, act=act)


def _linear_fwd(x, w, b, act):
    y = ag.matmul_bias_act(x, w, b, act=act)
    return y, (x, w, y)


def _linear_bwd(act, res, g):
    x, w, y = res
    dpre = g * _act_grad_from_y(y, act)
    dx = ag.block_aggregate(dpre, w.T)
    dw = ag.block_aggregate(x.T, dpre)
    db = jnp.sum(dpre, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)


def gcn_layer(a, x, w, b, *, act: str = "relu") -> jax.Array:
    """Differentiable GCN layer ``act((A @ X) @ W + b)``."""
    return linear(aggregate(a, x), w, b, act)
