//! Offline facade over the `xla` (PJRT) crate.
//!
//! The offline build environment does not ship the real `xla` crate (it
//! links `xla_extension`, a large C++ PJRT distribution). This vendored
//! facade keeps `llcg` compiling and testable everywhere:
//!
//! - **`Literal`** is fully functional (host-side shape + bytes container,
//!   tuple support) — it is plain data and needs no PJRT.
//! - **`PjRtClient` / `PjRtLoadedExecutable` / `PjRtBuffer`** are
//!   *uninhabited*: `PjRtClient::cpu()` returns an error, so no value of
//!   these types can ever exist in a stub build, and their methods are
//!   statically unreachable (`match self._never {}`). The `llcg` runtime
//!   detects this and falls back to its native reference backend.
//!
//! To run real HLO artifacts, replace this path dependency with the actual
//! `xla` crate in the workspace `Cargo.toml`; `llcg` uses only the API
//! surface below, matched to xla-rs:
//!
//! ```text
//! PjRtClient::cpu() -> Result<PjRtClient>
//! client.compile(&XlaComputation) -> Result<PjRtLoadedExecutable>
//! client.buffer_from_host_literal(&Literal) -> Result<PjRtBuffer>
//! exe.execute::<Literal>(&[Literal]) -> Result<Vec<Vec<PjRtBuffer>>>
//! exe.execute_b(&[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>>   (untupled outputs)
//! buffer.to_literal_sync() -> Result<Literal>
//! HloModuleProto::from_text_file, XlaComputation::from_proto
//! Literal::{create_from_shape_and_untyped_data, scalar, to_vec, to_tuple, to_tuple1}
//! ```
//!
//! One extension beyond the xla-rs surface:
//! [`Literal::copy_from_untyped_data`] overwrites a literal's bytes in
//! place (the runtime's pinned block-input staging). When swapping in the
//! real crate, shim it with a one-line wrapper that rebuilds the literal
//! via `create_from_shape_and_untyped_data` — semantics are identical, the
//! facade version merely skips the allocation.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only here).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// The element dtypes llcg's artifacts use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn element_size_bytes(self) -> usize {
        4
    }
}

/// Host-side conversion for `Literal::to_vec`.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le_bytes4(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host literal: dense array (shape + row-major bytes) or a tuple.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build a dense literal from raw little-endian bytes (one copy).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let expect = shape.iter().product::<usize>() * ty.element_size_bytes();
        if data.len() != expect {
            return Err(Error(format!(
                "literal data is {} bytes, shape {:?} needs {}",
                data.len(),
                shape,
                expect
            )));
        }
        Ok(Literal {
            ty,
            shape: shape.to_vec(),
            bytes: data.to_vec(),
            tuple: None,
        })
    }

    /// Overwrite this literal's bytes in place (shape and dtype are fixed
    /// at creation). The pinned-staging fast path: no allocation, a single
    /// `memcpy`. Errors on tuples and on any length mismatch.
    pub fn copy_from_untyped_data(&mut self, data: &[u8]) -> Result<()> {
        if self.tuple.is_some() {
            return Err(Error("copy_from_untyped_data on a tuple literal".into()));
        }
        if data.len() != self.bytes.len() {
            return Err(Error(format!(
                "copy_from_untyped_data: {} bytes into a {}-byte literal (shape {:?})",
                data.len(),
                self.bytes.len(),
                self.shape
            )));
        }
        self.bytes.copy_from_slice(data);
        Ok(())
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            ty: ElementType::F32,
            shape: Vec::new(),
            bytes: v.to_le_bytes().to_vec(),
            tuple: None,
        }
    }

    /// Pack literals into a tuple literal.
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            ty: ElementType::F32, // unused for tuples
            shape: Vec::new(),
            bytes: Vec::new(),
            tuple: Some(elems),
        }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".into()));
        }
        if self.ty != T::TY {
            return Err(Error(format!(
                "to_vec type mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le_bytes4([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| Error("to_tuple on a non-tuple literal".into()))
    }

    /// Unpack a 1-element tuple (or pass a dense literal through).
    pub fn to_tuple1(self) -> Result<Literal> {
        match self.tuple {
            None => Ok(self),
            Some(mut elems) => {
                if elems.len() != 1 {
                    return Err(Error(format!(
                        "to_tuple1 on a {}-element tuple",
                        elems.len()
                    )));
                }
                Ok(elems.pop().expect("len checked"))
            }
        }
    }
}

/// Parsed HLO module (text is retained verbatim; parsing/verification is
/// the real backend's job).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation ready to compile.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            proto: proto.clone(),
        }
    }
}

/// Statically-uninhabited marker: stub PJRT values cannot be constructed.
#[derive(Clone, Copy)]
enum Never {}

/// PJRT client handle. In this stub build `cpu()` always errors, so the
/// type is uninhabited and every method below is unreachable.
pub struct PjRtClient {
    _never: Never,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(
            "PJRT backend unavailable: built against the vendored xla facade \
             (vendor/xla). Use the native runtime backend, or swap in the \
             real `xla` crate to execute HLO artifacts."
                .into(),
        ))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self._never {}
    }

    /// Copy a host literal into a device buffer.
    pub fn buffer_from_host_literal(&self, _lit: &Literal) -> Result<PjRtBuffer> {
        match self._never {}
    }
}

/// Compiled executable handle (uninhabited in the stub build).
pub struct PjRtLoadedExecutable {
    _never: Never,
}

impl PjRtLoadedExecutable {
    /// Execute with host literals; outputs per replica.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._never {}
    }

    /// Execute with device-resident buffers; tuple outputs come back
    /// **untupled** (one buffer per tuple element), so they can be fed
    /// straight back in as the next step's inputs without a host visit.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._never {}
    }
}

/// Device buffer handle (uninhabited in the stub build).
pub struct PjRtBuffer {
    _never: Never,
}

impl PjRtBuffer {
    /// Synchronous device→host copy.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self._never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert_eq!(lit.shape(), &[3]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
                .is_err()
        );
    }

    #[test]
    fn literal_in_place_overwrite() {
        let xs = [1.0f32, 2.0, 3.0];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let mut lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let ys = [-4.0f32, 5.5, 0.0];
        let ybytes: Vec<u8> = ys.iter().flat_map(|x| x.to_le_bytes()).collect();
        lit.copy_from_untyped_data(&ybytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), ys);
        // wrong length and tuples are rejected
        assert!(lit.copy_from_untyped_data(&ybytes[..8]).is_err());
        let mut t = Literal::tuple(vec![Literal::scalar(1.0)]);
        assert!(t.copy_from_untyped_data(&ybytes).is_err());
    }

    #[test]
    fn tuple_pack_unpack() {
        let a = Literal::scalar(1.0);
        let b = Literal::scalar(2.0);
        let t = Literal::tuple(vec![a, b]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[1].to_vec::<f32>().unwrap(), vec![2.0]);
    }

    #[test]
    fn client_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
