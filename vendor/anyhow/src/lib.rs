//! Std-only substitute for the `anyhow` crate (DESIGN.md §Substitutions).
//!
//! The offline build environment has no crates.io access, so this vendored
//! shim implements exactly the surface `llcg` uses: `Error`, `Result<T>`,
//! `anyhow!`, `bail!`, and the `Context` extension trait. Semantics follow
//! upstream `anyhow` where it matters:
//!
//! - `Error` deliberately does **not** implement `std::error::Error`, which
//!   is what makes the blanket `From<E: std::error::Error>` conversion
//!   coherent (same trick as upstream).
//! - `Display` shows the outermost message; `{:#}` shows the whole context
//!   chain joined by `": "`; `Debug` shows the chain as a `Caused by:` list.

use std::fmt;

/// A dynamic error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, e) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for e in causes {
                write!(f, "\n    {}", e.msg)?;
            }
        }
        Ok(())
    }
}

// `Error: !std::error::Error`, so this blanket impl does not collide with
// the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve source messages of std errors as chain entries.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        err.expect("at least one message")
    }
}

/// `anyhow::Result<T>` — alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(|| ..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($msg:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($msg, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("inner {}", 7))
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = fails().with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<String> = (|| {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        })();
        assert!(r.is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn bail_returns() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert!(f(0).is_err());
        assert_eq!(f(3).unwrap(), 3);
    }
}
