# LLCG build entry points.
#
#   make artifacts   AOT-compile the JAX/Pallas models to HLO-text artifacts
#                    (requires the python env; run once — the Rust runtime
#                    falls back to its native reference backend without it)
#   make check       tier-1 gate: release build + tests + clippy
#   make bench       perf benches; writes BENCH_<section>.json per section
#   make bench-cluster  just the sequential-vs-threaded engine benches
#                    (writes BENCH_cluster.json)
#   make bench-cluster-faults  robustness benches: time-to-target-loss at
#                    drop 0/0.02/0.1 and a mid-run crash with and without
#                    worker respawn (writes BENCH_cluster_faults.json)
#   make bench-cluster-transport  worker-wire benches: the same run over
#                    in-process threads vs real worker processes on loopback
#                    TCP and unix sockets, with measured wire bytes per round
#                    (builds the CLI first — worker spawns need it; writes
#                    BENCH_cluster_transport.json)
#   make bench-kernels  just the kernel-layer benches: scalar vs tiled vs
#                    tiled+pool at 1/2/4/8 threads, step latency per engine,
#                    staged-vs-pinned block upload (writes BENCH_kernels.json)
#   make bench-serve just the serving benches: cold (full 2-hop eval) vs
#                    cached query latency, batch=1 vs micro-batched, and
#                    sustained throughput at 1/2/4/8 server threads
#                    (writes BENCH_serve.json)
#   make bench-obs   instrumentation-overhead benches: disabled/enabled span
#                    cost, counter/histogram record cost, and an end-to-end
#                    round with tracing off vs on (writes BENCH_obs.json)
#   make test        quick test run

.PHONY: artifacts check fmt test bench bench-cluster bench-cluster-faults bench-cluster-transport bench-kernels bench-serve bench-obs clean

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

check:
	cargo build --release
	cargo test -q
	cargo clippy -- -D warnings

fmt:
	cargo fmt --all -- --check

test:
	cargo test -q

bench:
	cargo bench

bench-cluster:
	cargo bench -- cluster/

bench-cluster-faults:
	cargo bench -- cluster_faults

bench-cluster-transport:
	cargo build --release
	cargo bench -- cluster_transport

bench-kernels:
	cargo bench -- kernels

bench-serve:
	cargo bench -- serve

bench-obs:
	cargo bench -- obs/

clean:
	cargo clean
	rm -f BENCH_*.json
