//! The paper's headline scenario (Fig 2 / Fig 4): distributed training on
//! the Reddit analog with 8 machines, comparing
//!
//!   PSGD-PA   — parameter averaging only, cut-edges ignored (Alg. 1):
//!               converges to a *lower plateau* (the Thm-1 residual error);
//!   GGS       — global graph sampling: full accuracy, but transfers node
//!               features every mini-batch (100x the bytes);
//!   LLCG      — local training + server correction (Alg. 2): full accuracy
//!               at PSGD-PA's communication cost.
//!
//! Then the same LLCG workload is run on both execution engines — the
//! sequential driver and the multi-threaded `cluster` engine over a modeled
//! WAN — printing modeled vs measured round time side by side (the threaded
//! engine overlaps the per-worker transfers and compute; the sequential one
//! serializes them).
//!
//!     cargo run --release --example distributed_training [--fast]

use llcg::cluster::Engine;
use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (rt, _) = Runtime::load_or_native("artifacts")?;

    let mk_cfg = |alg: Algorithm| {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = if fast { "tiny-hetero" } else { "reddit-s" }.into();
        cfg.arch = "sage".into(); // paper's Reddit base arch (Table 2)
        cfg.algorithm = alg;
        cfg.parts = 8;
        cfg.rounds = if fast { 8 } else { 30 };
        cfg.schedule = match alg {
            // LLCG uses the exponentially growing local epochs of Alg. 2
            Algorithm::Llcg => Schedule::Exponential { k0: 8, rho: 1.1 },
            _ => Schedule::Fixed { k: 8 },
        };
        cfg.correction_steps = 2;
        cfg.server_lr = 0.05;
        cfg.eval_every = 5;
        cfg.eval_max_nodes = 384;
        cfg
    };

    // fast mode uses tiny artifacts (gcn/sage only built for tiny* = gcn…)
    // tiny-hetero shares the tiny shape config; its artifacts are "…_tiny".
    println!("scenario: {} machines, dataset={}", 8, mk_cfg(Algorithm::Llcg).dataset);
    println!(
        "\n{:<12} {:>9} {:>9} {:>14} {:>12}",
        "algorithm", "val", "test", "MB/round", "cut-ratio"
    );
    let mut results = Vec::new();
    for alg in [Algorithm::PsgdPa, Algorithm::Ggs, Algorithm::Llcg] {
        let mut cfg = mk_cfg(alg);
        if fast {
            // tiny-hetero uses the tiny-shaped artifacts via its dims; the
            // artifact key is {arch}_{opt}_{dataset}; for the fast path we
            // run the gcn/tiny artifacts on the tiny-hetero graph.
            cfg.dataset = "tiny-hetero".into();
            cfg.arch = "gcn".into();
        }
        let ds = driver::load_dataset(&cfg)?;
        let res = driver::run_experiment(&cfg, &ds, &rt)?;
        println!(
            "{:<12} {:>9.4} {:>9.4} {:>14.3} {:>12.3}",
            alg.name(),
            res.final_val,
            res.final_test,
            res.avg_round_mb(),
            res.cut_ratio
        );
        results.push(res);
    }

    let (psgd, ggs, llcg) = (&results[0], &results[1], &results[2]);
    println!("\npaper-shape checks:");
    println!(
        "  LLCG within {:.1} pts of GGS (paper: ~equal accuracy)",
        (ggs.final_val - llcg.final_val) * 100.0
    );
    println!(
        "  LLCG beats PSGD-PA by {:.1} pts (paper: the Thm-1 residual gap)",
        (llcg.final_val - psgd.final_val) * 100.0
    );
    println!(
        "  GGS moves {:.0}x more bytes/round than LLCG (paper: ~100-300x)",
        ggs.avg_round_bytes / llcg.avg_round_bytes
    );

    // --- engine comparison: sequential vs threaded cluster ------------------
    if rt.backend_name() != "native" {
        println!("\n(engine comparison needs the native backend — skipped under PJRT)");
        return Ok(());
    }
    println!("\nengine comparison: LLCG on a modeled WAN (20ms links, sleeps injected)");
    let mut base = mk_cfg(Algorithm::Llcg);
    if fast {
        base.dataset = "tiny-hetero".into();
        base.arch = "gcn".into();
    }
    base.rounds = if fast { 4 } else { 6 };
    base.eval_every = base.rounds; // eval once at the end
    base.net = "wan,scale=1".into();
    let ds = driver::load_dataset(&base)?;
    let mut engine_results = Vec::new();
    for engine in [Engine::Sequential, Engine::Cluster] {
        let mut cfg = base.clone();
        cfg.engine = engine;
        let res = driver::run_experiment(&cfg, &ds, &rt)?;
        engine_results.push(res);
    }
    let (seq, clu) = (&engine_results[0], &engine_results[1]);
    println!(
        "\n{:<7} {:>14} {:>14} {:>14} {:>14}",
        "round", "seq modeled", "seq measured", "clu modeled", "clu measured"
    );
    let mut seq_wall = 0f64;
    let mut clu_wall = 0f64;
    for (rs, rc) in seq.records.iter().zip(&clu.records) {
        seq_wall += rs.wall_time_s;
        clu_wall += rc.wall_time_s;
        println!(
            "{:<7} {:>13.3}s {:>13.3}s {:>13.3}s {:>13.3}s",
            rs.round, rs.net_time_s, rs.wall_time_s, rc.net_time_s, rc.wall_time_s
        );
    }
    println!(
        "\n  modeled per-round link time is engine-independent; measured wall-clock \
         shows the overlap:"
    );
    println!(
        "  sequential {seq_wall:.3}s vs cluster {clu_wall:.3}s -> {:.2}x threaded speedup",
        seq_wall / clu_wall
    );
    println!(
        "  losses identical: {} (sync cluster mode reproduces the driver bit-for-bit)",
        seq.records
            .iter()
            .zip(&clu.records)
            .all(|(a, b)| a.local_loss.to_bits() == b.local_loss.to_bits())
    );
    Ok(())
}
