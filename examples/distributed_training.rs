//! The paper's headline scenario (Fig 2 / Fig 4): distributed training on
//! the Reddit analog with 8 machines, comparing
//!
//!   PSGD-PA   — parameter averaging only, cut-edges ignored (Alg. 1):
//!               converges to a *lower plateau* (the Thm-1 residual error);
//!   GGS       — global graph sampling: full accuracy, but transfers node
//!               features every mini-batch (100x the bytes);
//!   LLCG      — local training + server correction (Alg. 2): full accuracy
//!               at PSGD-PA's communication cost.
//!
//! Then the same LLCG workload is run on both execution engines — the
//! sequential driver and the multi-threaded `cluster` engine over a modeled
//! WAN — printing modeled vs measured round time side by side (the threaded
//! engine overlaps the per-worker transfers and compute; the sequential one
//! serializes them).
//!
//! Both comparisons are `Sweep`s: the dataset is loaded once and the
//! partition assignment is computed once, shared across every point.
//!
//!     cargo run --release --example distributed_training [--fast]

use llcg::api::Sweep;
use llcg::config::ExperimentConfig;
use llcg::coordinator::{Algorithm, Schedule};
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (rt, _) = Runtime::load_or_native("artifacts")?;

    // fast mode uses tiny artifacts (the artifact key is
    // {arch}_{opt}_{dataset}; tiny-hetero shares the tiny shape config)
    let base = {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = if fast { "tiny-hetero" } else { "reddit-s" }.into();
        cfg.arch = if fast { "gcn" } else { "sage" }.into();
        cfg.parts = 8;
        cfg.rounds = if fast { 8 } else { 30 };
        cfg.schedule = Schedule::Fixed { k: 8 };
        cfg.correction_steps = 2;
        cfg.server_lr = 0.05;
        cfg.eval_every = 5;
        cfg.eval_max_nodes = 384;
        cfg
    };

    println!("scenario: {} machines, dataset={}", base.parts, base.dataset);
    println!(
        "\n{:<12} {:>9} {:>9} {:>14} {:>12}",
        "algorithm", "val", "test", "MB/round", "cut-ratio"
    );
    // LLCG uses the exponentially growing local epochs of Alg. 2
    let sweep = Sweep::points(&base)
        .point(&[("algorithm", "psgd-pa".to_string())])
        .point(&[("algorithm", "ggs".to_string())])
        .point(&[
            ("algorithm", "llcg".to_string()),
            ("rho", "1.1".to_string()),
        ]);
    let results = sweep.run(&rt, |_i, exp, res| {
        println!(
            "{:<12} {:>9.4} {:>9.4} {:>14.3} {:>12.3}",
            exp.config().algorithm.name(),
            res.final_val,
            res.final_test,
            res.avg_round_mb(),
            res.cut_ratio
        );
    })?;

    let (psgd, ggs, llcg) = (&results[0], &results[1], &results[2]);
    println!("\npaper-shape checks:");
    println!(
        "  LLCG within {:.1} pts of GGS (paper: ~equal accuracy)",
        (ggs.final_val - llcg.final_val) * 100.0
    );
    println!(
        "  LLCG beats PSGD-PA by {:.1} pts (paper: the Thm-1 residual gap)",
        (llcg.final_val - psgd.final_val) * 100.0
    );
    println!(
        "  GGS moves {:.0}x more bytes/round than LLCG (paper: ~100-300x)",
        ggs.avg_round_bytes / llcg.avg_round_bytes
    );

    // --- engine comparison: sequential vs threaded cluster ------------------
    if rt.backend_name() != "native" {
        println!("\n(engine comparison needs the native backend — skipped under PJRT)");
        return Ok(());
    }
    println!("\nengine comparison: LLCG on a modeled WAN (20ms links, sleeps injected)");
    let mut wan_base = base.clone();
    wan_base.algorithm = Algorithm::Llcg;
    wan_base.schedule = Schedule::Exponential { k0: 8, rho: 1.1 };
    wan_base.rounds = if fast { 4 } else { 6 };
    wan_base.eval_every = wan_base.rounds; // eval once at the end
    wan_base.net = "wan,scale=1".into();
    let engine_results = Sweep::over(&wan_base, "engine", &["sequential", "cluster"])
        .run(&rt, |_i, _exp, _res| {})?;
    let (seq, clu) = (&engine_results[0], &engine_results[1]);
    println!(
        "\n{:<7} {:>14} {:>14} {:>14} {:>14}",
        "round", "seq modeled", "seq measured", "clu modeled", "clu measured"
    );
    let mut seq_wall = 0f64;
    let mut clu_wall = 0f64;
    for (rs, rc) in seq.records.iter().zip(&clu.records) {
        seq_wall += rs.wall_time_s;
        clu_wall += rc.wall_time_s;
        println!(
            "{:<7} {:>13.3}s {:>13.3}s {:>13.3}s {:>13.3}s",
            rs.round, rs.net_time_s, rs.wall_time_s, rc.net_time_s, rc.wall_time_s
        );
    }
    println!(
        "\n  modeled per-round link time is engine-independent; measured wall-clock \
         shows the overlap:"
    );
    println!(
        "  sequential {seq_wall:.3}s vs cluster {clu_wall:.3}s -> {:.2}x threaded speedup",
        seq_wall / clu_wall
    );
    println!(
        "  losses identical: {} (sync cluster mode reproduces the driver bit-for-bit)",
        seq.records
            .iter()
            .zip(&clu.records)
            .all(|(a, b)| a.local_loss.to_bits() == b.local_loss.to_bits())
    );
    Ok(())
}
