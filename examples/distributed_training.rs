//! The paper's headline scenario (Fig 2 / Fig 4): distributed training on
//! the Reddit analog with 8 machines, comparing
//!
//!   PSGD-PA   — parameter averaging only, cut-edges ignored (Alg. 1):
//!               converges to a *lower plateau* (the Thm-1 residual error);
//!   GGS       — global graph sampling: full accuracy, but transfers node
//!               features every mini-batch (100x the bytes);
//!   LLCG      — local training + server correction (Alg. 2): full accuracy
//!               at PSGD-PA's communication cost.
//!
//!     cargo run --release --example distributed_training [--fast]

use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (rt, _) = Runtime::load_or_native("artifacts")?;

    let mk_cfg = |alg: Algorithm| {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = if fast { "tiny-hetero" } else { "reddit-s" }.into();
        cfg.arch = "sage".into(); // paper's Reddit base arch (Table 2)
        cfg.algorithm = alg;
        cfg.parts = 8;
        cfg.rounds = if fast { 8 } else { 30 };
        cfg.schedule = match alg {
            // LLCG uses the exponentially growing local epochs of Alg. 2
            Algorithm::Llcg => Schedule::Exponential { k0: 8, rho: 1.1 },
            _ => Schedule::Fixed { k: 8 },
        };
        cfg.correction_steps = 2;
        cfg.server_lr = 0.05;
        cfg.eval_every = 5;
        cfg.eval_max_nodes = 384;
        cfg
    };

    // fast mode uses tiny artifacts (gcn/sage only built for tiny* = gcn…)
    // tiny-hetero shares the tiny shape config; its artifacts are "…_tiny".
    println!("scenario: {} machines, dataset={}", 8, mk_cfg(Algorithm::Llcg).dataset);
    println!(
        "\n{:<12} {:>9} {:>9} {:>14} {:>12}",
        "algorithm", "val", "test", "MB/round", "cut-ratio"
    );
    let mut results = Vec::new();
    for alg in [Algorithm::PsgdPa, Algorithm::Ggs, Algorithm::Llcg] {
        let mut cfg = mk_cfg(alg);
        if fast {
            // tiny-hetero uses the tiny-shaped artifacts via its dims; the
            // artifact key is {arch}_{opt}_{dataset}; for the fast path we
            // run the gcn/tiny artifacts on the tiny-hetero graph.
            cfg.dataset = "tiny-hetero".into();
            cfg.arch = "gcn".into();
        }
        let ds = driver::load_dataset(&cfg)?;
        let res = driver::run_experiment(&cfg, &ds, &rt)?;
        println!(
            "{:<12} {:>9.4} {:>9.4} {:>14.3} {:>12.3}",
            alg.name(),
            res.final_val,
            res.final_test,
            res.avg_round_mb(),
            res.cut_ratio
        );
        results.push(res);
    }

    let (psgd, ggs, llcg) = (&results[0], &results[1], &results[2]);
    println!("\npaper-shape checks:");
    println!(
        "  LLCG within {:.1} pts of GGS (paper: ~equal accuracy)",
        (ggs.final_val - llcg.final_val) * 100.0
    );
    println!(
        "  LLCG beats PSGD-PA by {:.1} pts (paper: the Thm-1 residual gap)",
        (llcg.final_val - psgd.final_val) * 100.0
    );
    println!(
        "  GGS moves {:.0}x more bytes/round than LLCG (paper: ~100-300x)",
        ggs.avg_round_bytes / llcg.avg_round_bytes
    );
    Ok(())
}
