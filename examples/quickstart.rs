//! Quickstart: the smallest complete LLCG run.
//!
//! Generates the `tiny` synthetic dataset, partitions it with the METIS-like
//! partitioner, and trains a 2-layer GCN with LLCG (local training +
//! periodic averaging + global server correction) on 4 simulated machines.
//!
//!     make artifacts           # optional: AOT-compile the PJRT models
//!     cargo run --release --example quickstart
//!
//! Without artifacts the run uses the native reference backend.

use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Configure the run. Everything is also reachable via the `llcg run`
    //    CLI and JSON config files; the API mirrors those knobs.
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 4; // simulated machines
    cfg.rounds = 12; // communication rounds
    cfg.schedule = Schedule::Exponential { k0: 4, rho: 1.1 }; // K·ρ^r (Alg. 2)
    cfg.correction_steps = 1; // S (Alg. 2, server correction)
    cfg.lr = 0.01;

    // 2. Dataset + runtime (loads AOT artifacts; python is NOT involved).
    let ds = driver::load_dataset(&cfg)?;
    println!("dataset: {}", ds.stats());
    let (rt, _) = Runtime::load_or_native(&cfg.artifacts_dir)?;

    // 3. Train.
    let result = driver::run_experiment(&cfg, &ds, &rt)?;

    // 4. Inspect.
    println!("\nround  steps  local-loss  global-loss  val-F1");
    for r in &result.records {
        println!(
            "{:>5} {:>6} {:>11.4} {:>12.4} {:>7.4}",
            r.round, r.local_steps, r.local_loss, r.global_loss, r.val_score
        );
    }
    println!(
        "\nfinal: val={:.4} test={:.4}  edge-cut={:.1}%  comm={:.3} MB/round",
        result.final_val,
        result.final_test,
        result.cut_ratio * 100.0,
        result.avg_round_mb()
    );
    Ok(())
}
