//! Quickstart: the smallest complete LLCG run, through the typed
//! experiment API.
//!
//! Generates the `tiny` synthetic dataset, partitions it with the METIS-like
//! partitioner, and trains a 2-layer GCN with LLCG (local training +
//! periodic averaging + global server correction) on 4 simulated machines,
//! printing each round as its event streams in.
//!
//!     make artifacts           # optional: AOT-compile the PJRT models
//!     cargo run --release --example quickstart
//!
//! Without artifacts the run uses the native reference backend.

use llcg::api::{Event, ExperimentBuilder};
use llcg::coordinator::{Algorithm, Schedule};
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Describe the run. Every knob is also reachable via the `llcg run`
    //    CLI and JSON config files (`llcg run --help` lists the keys); the
    //    builder validates dataset/partitioner/arch names against the
    //    registries and loads the dataset.
    let exp = ExperimentBuilder::new()
        .dataset("tiny")
        .arch("gcn")
        .algorithm(Algorithm::Llcg)
        .parts(4) // simulated machines
        .rounds(12) // communication rounds
        .schedule(Schedule::Exponential { k0: 4, rho: 1.1 }) // K·ρ^r (Alg. 2)
        .correction_steps(1) // S (Alg. 2, server correction)
        .lr(0.01)
        .build()?;
    println!("dataset: {}", exp.dataset().stats());

    // 2. Runtime (loads AOT artifacts; python is NOT involved).
    let (rt, _) = Runtime::load_or_native(&exp.config().artifacts_dir)?;

    // 3. Train, consuming the event stream as it happens.
    println!("\nround  steps  local-loss  global-loss  val-F1");
    let result = exp.launch(&rt).stream(|ev| match ev {
        Event::RoundCompleted(r) => println!(
            "{:>5} {:>6} {:>11.4} {:>12.4} {:>7.4}",
            r.round, r.local_steps, r.local_loss, r.global_loss, r.val_score
        ),
        Event::Finished(res) => println!("\n(run finished: {} rounds)", res.records.len()),
        _ => {}
    })?;

    // 4. Inspect the final result.
    println!(
        "final: val={:.4} test={:.4}  edge-cut={:.1}%  comm={:.3} MB/round",
        result.final_val,
        result.final_test,
        result.cut_ratio * 100.0,
        result.avg_round_mb()
    );
    Ok(())
}
