//! Ablation of the *Global Server Correction* — the paper's core design
//! point (§3.2, Fig 5/6/9):
//!
//!   1. correction steps S ∈ {0, 1, 2, 4}      (S=0 degenerates to PSGD-PA)
//!   2. local epoch size K ∈ {1, 4, 16}        (Fig 5)
//!   3. correction batch: uniform vs max-cut    (Fig 9 — uniform should win
//!      or tie: biased batches give biased correction gradients)
//!
//!     cargo run --release --example ablation_correction [--dataset tiny-hetero]

use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, CorrectionBatch, Schedule};
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dataset = args
        .iter()
        .position(|a| a == "--dataset")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "tiny-hetero".to_string());
    let (rt, _) = Runtime::load_or_native("artifacts")?;

    let base = || {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = dataset.clone();
        cfg.arch = "sage".into();
        cfg.algorithm = Algorithm::Llcg;
        cfg.parts = 4;
        cfg.rounds = 15;
        cfg.schedule = Schedule::Fixed { k: 8 };
        cfg.eval_every = 5;
        cfg.eval_max_nodes = 256;
        cfg
    };

    let ds = driver::load_dataset(&base())?;
    println!("dataset: {}", ds.stats());

    println!("\n-- 1. correction steps S (S=0 == PSGD-PA) --");
    for s in [0usize, 1, 2, 4] {
        let mut cfg = base();
        cfg.correction_steps = s;
        let res = driver::run_experiment(&cfg, &ds, &rt)?;
        println!("  S={s}: val={:.4} test={:.4}", res.final_val, res.final_test);
    }

    println!("\n-- 2. local epoch size K (same round budget) --");
    for k in [1usize, 4, 16] {
        let mut cfg = base();
        cfg.schedule = Schedule::Fixed { k };
        cfg.correction_steps = 1;
        let res = driver::run_experiment(&cfg, &ds, &rt)?;
        println!(
            "  K={k:<3}: total-steps={:<4} val={:.4}",
            res.total_steps, res.final_val
        );
    }

    println!("\n-- 3. correction mini-batch selection (Fig 9) --");
    for (name, batch) in [
        ("uniform", CorrectionBatch::Uniform),
        ("max-cut-edges", CorrectionBatch::MaxCutEdges),
    ] {
        let mut cfg = base();
        cfg.correction_steps = 2;
        cfg.correction_batch = batch;
        let res = driver::run_experiment(&cfg, &ds, &rt)?;
        println!("  {name:<14}: val={:.4}", res.final_val);
    }
    Ok(())
}
