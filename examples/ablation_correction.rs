//! Ablation of the *Global Server Correction* — the paper's core design
//! point (§3.2, Fig 5/6/9):
//!
//!   1. correction steps S ∈ {0, 1, 2, 4}      (S=0 degenerates to PSGD-PA)
//!   2. local epoch size K ∈ {1, 4, 16}        (Fig 5)
//!   3. correction batch: uniform vs max-cut    (Fig 9 — uniform should win
//!      or tie: biased batches give biased correction gradients)
//!
//! Each ablation is a single-axis `Sweep::over`; the dataset and the
//! partition assignment are loaded/computed once and reused across every
//! point of every sweep axis.
//!
//!     cargo run --release --example ablation_correction [--dataset tiny-hetero]

use llcg::api::Sweep;
use llcg::config::ExperimentConfig;
use llcg::coordinator::{Algorithm, Schedule};
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dataset = args
        .iter()
        .position(|a| a == "--dataset")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "tiny-hetero".to_string());
    let (rt, _) = Runtime::load_or_native("artifacts")?;

    let base = {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = dataset.clone();
        cfg.arch = "sage".into();
        cfg.algorithm = Algorithm::Llcg;
        cfg.parts = 4;
        cfg.rounds = 15;
        cfg.schedule = Schedule::Fixed { k: 8 };
        cfg.eval_every = 5;
        cfg.eval_max_nodes = 256;
        cfg
    };

    println!("\n-- 1. correction steps S (S=0 == PSGD-PA) --");
    Sweep::over(&base, "correction_steps", &[0usize, 1, 2, 4]).run(&rt, |_i, exp, res| {
        println!(
            "  S={}: val={:.4} test={:.4}",
            exp.config().correction_steps,
            res.final_val,
            res.final_test
        );
    })?;

    println!("\n-- 2. local epoch size K (same round budget) --");
    let mut k_base = base.clone();
    k_base.correction_steps = 1;
    Sweep::over(&k_base, "local_steps", &[1usize, 4, 16]).run(&rt, |_i, exp, res| {
        let k = match exp.config().schedule {
            Schedule::Fixed { k } => k,
            Schedule::Exponential { k0, .. } => k0,
        };
        println!(
            "  K={k:<3}: total-steps={:<4} val={:.4}",
            res.total_steps, res.final_val
        );
    })?;

    println!("\n-- 3. correction mini-batch selection (Fig 9) --");
    let mut b_base = base.clone();
    b_base.correction_steps = 2;
    Sweep::over(&b_base, "correction_batch", &["uniform", "max_cut"]).run(
        &rt,
        |_i, exp, res| {
            println!(
                "  {:<14?}: val={:.4}",
                exp.config().correction_batch,
                res.final_val
            );
        },
    )?;
    Ok(())
}
