//! Train → publish → serve, live: the cluster engine trains LLCG on one
//! thread while the inference server answers queries on another, hot-swapping
//! to each round's improving snapshot as it is published.
//!
//!     cargo run --release --example serve_pipeline
//!
//! Pipeline:
//! 1. a training thread runs the threaded cluster engine with
//!    `Run::publish_to(hub)` — every round boundary publishes the freshly
//!    averaged + corrected global params as a `ModelSnapshot`;
//! 2. the main thread waits for the first snapshot, starts the
//!    micro-batching `serve::Server` over the hub, and issues queries while
//!    training is still running — watch the served snapshot `version`
//!    climb as the model improves under live traffic;
//! 3. after training finishes, a closed-loop load test measures sustained
//!    throughput and latency percentiles against the final model.
//!
//! Served scores are bit-identical to the training-side eval path at every
//! batch size and thread count (see `rust/src/serve/README.md`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use llcg::api::ExperimentBuilder;
use llcg::cluster::Engine;
use llcg::coordinator::{Algorithm, Schedule};
use llcg::graph::generators;
use llcg::runtime::Runtime;
use llcg::serve::{run_load, LoadMode, LoadSpec, ServeConfig, Server, SnapshotHub};

fn main() -> anyhow::Result<()> {
    // dataset shared by training and serving (one Arc, no reload)
    let ds = Arc::new(generators::by_name("tiny", 7).expect("tiny generator"));
    println!("dataset: {}", ds.stats());

    let hub = SnapshotHub::new();

    // 1. training thread: cluster engine, publishing every round boundary
    let trainer = {
        let ds = ds.clone();
        let hub = hub.clone();
        std::thread::spawn(move || {
            let (rt, _) =
                Runtime::load_or_native("target/native-artifacts").expect("native runtime");
            let exp = ExperimentBuilder::new()
                .with_dataset(ds)
                .arch("gcn")
                .algorithm(Algorithm::Llcg)
                .engine(Engine::Cluster)
                .parts(2)
                .rounds(10)
                .schedule(Schedule::Fixed { k: 4 })
                .correction_steps(1)
                .eval_every(2)
                .eval_max_nodes(64)
                .seed(7)
                .build()
                .expect("experiment builds");
            exp.launch(&rt)
                .publish_to(hub)
                .expect("gcn is servable")
                .finish()
                .expect("training run")
        })
    };

    // 2. wait for round 1's snapshot, then serve under live training
    let t0 = Instant::now();
    while hub.version() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(120), "no snapshot published");
        std::thread::sleep(Duration::from_millis(5));
    }
    let server = Server::start(
        hub.clone(),
        ds.clone(),
        ServeConfig {
            max_batch: 16,
            flush_us: 200,
            threads: 1, // training owns most cores while it runs
            queue: 256,
        },
    )?;
    let client = server.client();
    println!("\nserving while training (snapshot version climbs as rounds publish):");
    let probe = ds.splits.val[0];
    let mut last_version = 0;
    while !trainer.is_finished() {
        let scores = client.query(probe)?;
        if scores.version != last_version {
            last_version = scores.version;
            println!(
                "  node {probe}: pred={} (snapshot v{} / round {})",
                scores.pred,
                scores.version,
                hub.current().map(|s| s.round).unwrap_or(0)
            );
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let result = trainer.join().expect("training thread");
    println!(
        "training done: final val={:.4} test={:.4}; snapshots published: {}",
        result.final_val,
        result.final_test,
        hub.version()
    );

    // 3. closed-loop load test against the final model
    let nodes: Vec<u32> = (0..ds.n() as u32).collect();
    let report = run_load(
        &client,
        &nodes,
        &LoadSpec {
            mode: LoadMode::Closed,
            clients: 4,
            requests: 2000,
            seed: 7,
        },
    );
    println!("\nload test (closed loop, 4 clients): {report}");
    let stats = server.stats();
    println!(
        "server stats: {} requests in {} batches (mean batch {:.1}, max {}), {} hot-swaps",
        stats.requests,
        stats.batches,
        stats.mean_batch(),
        stats.max_batch,
        stats.swaps
    );
    drop(client);
    server.shutdown();
    Ok(())
}
