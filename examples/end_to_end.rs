//! End-to-end validation driver (DESIGN.md §End-to-end validation).
//!
//! Proves all layers compose on a real workload: generates the Reddit
//! analog (8k nodes, ~100k edges), partitions it across 8 simulated
//! machines with the METIS-like partitioner, trains a 2-layer GraphSAGE
//! (the paper's Reddit base arch) with LLCG for a full round budget via the
//! AOT-compiled PJRT artifacts, logs the loss curve + val score per round
//! to `runs/end_to_end.csv` straight from the event stream, and asserts the
//! paper-shape acceptance criteria:
//!
//!   (1) training loss decreases monotonically-ish (learning happens),
//!   (2) LLCG final score beats PSGD-PA (the correction earns its keep),
//!   (3) LLCG communicates the same bytes/round as PSGD-PA,
//!       orders of magnitude less than GGS.
//!
//! The baselines run through a `Sweep` (shared dataset + partition); the
//! LLCG run streams its events into the CSV logger as they happen.
//!
//!     cargo run --release --example end_to_end [--fast]

use llcg::api::{Event, ExperimentBuilder, Sweep};
use llcg::config::ExperimentConfig;
use llcg::coordinator::{Algorithm, Schedule};
use llcg::metrics::CsvLogger;
use llcg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let t0 = std::time::Instant::now();
    let (rt, _) = Runtime::load_or_native("artifacts")?;

    let base = {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = if fast { "tiny-hetero" } else { "reddit-s" }.into();
        cfg.arch = "sage".into();
        cfg.parts = 8;
        cfg.rounds = if fast { 10 } else { 40 };
        cfg.schedule = Schedule::Fixed { k: 8 };
        cfg.correction_steps = 4;
        cfg.eval_every = if fast { 2 } else { 4 };
        cfg.eval_max_nodes = 384;
        cfg
    };

    // ---- baselines through a sweep (dataset + partition loaded once) -------
    println!("\n[1/2] baselines via sweep: PSGD-PA (Alg. 1), then GGS…");
    let mut shared_ds = None;
    let baselines = Sweep::over(&base, "algorithm", &["psgd-pa", "ggs"])
        .run(&rt, |_i, exp, res| {
            shared_ds.get_or_insert_with(|| exp.dataset().clone());
            println!(
                "      {:<8} val={:.4} MB/round={:.3}",
                exp.config().algorithm.name(),
                res.final_val,
                res.avg_round_mb()
            );
        })?;
    let (psgd, ggs) = (&baselines[0], &baselines[1]);

    // ---- LLCG with the paper's exponential schedule, events -> CSV ---------
    println!("[2/2] LLCG (Alg. 2)…");
    let exp = ExperimentBuilder::from_config(base.clone())
        .with_dataset(shared_ds.expect("baselines loaded the dataset"))
        .algorithm(Algorithm::Llcg)
        .schedule(Schedule::Exponential {
            k0: 8,
            rho: 1.1, // the paper's ρ
        })
        .build()?;
    println!("end-to-end workload: {}", exp.dataset().stats());
    let mut log = CsvLogger::create("runs/end_to_end.csv")?;
    let header = [
        "round",
        "local_steps",
        "local_loss",
        "global_loss",
        "val",
        "cum_bytes",
    ];
    let mut log_err = None;
    let llcg = exp.launch(&rt).stream(|ev| {
        if let Event::RoundCompleted(r) = ev {
            let res = log.row(
                &header,
                &[
                    r.round.to_string(),
                    r.local_steps.to_string(),
                    format!("{:.6}", r.local_loss),
                    format!("{:.6}", r.global_loss),
                    format!("{:.6}", r.val_score),
                    r.cum_bytes.to_string(),
                ],
            );
            if let Err(e) = res {
                log_err.get_or_insert(e);
            }
        }
    })?;
    if let Some(e) = log_err {
        return Err(e.into());
    }
    println!(
        "      val={:.4} MB/round={:.3}",
        llcg.final_val,
        llcg.avg_round_mb()
    );
    println!("\nloss curve -> runs/end_to_end.csv");

    // ---- acceptance criteria -------------------------------------------------
    let losses: Vec<f64> = llcg
        .records
        .iter()
        .filter(|r| !r.global_loss.is_nan())
        .map(|r| r.global_loss)
        .collect();
    let first = losses.first().copied().unwrap_or(f64::NAN);
    let last = losses.last().copied().unwrap_or(f64::NAN);
    assert!(
        last < first * 0.8,
        "(1) FAIL: loss did not fall: {first:.4} -> {last:.4}"
    );
    println!("(1) PASS  loss {first:.4} -> {last:.4}");

    assert!(
        llcg.final_val >= psgd.final_val - 0.005,
        "(2) FAIL: LLCG {:.4} < PSGD-PA {:.4}",
        llcg.final_val,
        psgd.final_val
    );
    println!(
        "(2) PASS  LLCG {:.4} vs PSGD-PA {:.4} (GGS reference {:.4})",
        llcg.final_val, psgd.final_val, ggs.final_val
    );

    let ratio = ggs.avg_round_bytes / llcg.avg_round_bytes;
    assert!(
        (llcg.avg_round_bytes - psgd.avg_round_bytes).abs()
            < 0.01 * psgd.avg_round_bytes + 1.0,
        "(3) FAIL: LLCG bytes != PSGD-PA bytes"
    );
    assert!(ratio > 5.0, "(3) FAIL: GGS only {ratio:.1}x more bytes");
    println!("(3) PASS  comm: LLCG == PSGD-PA, GGS moves {ratio:.0}x more");

    println!(
        "\nend-to-end OK in {:.1}s ({} train steps executed)",
        t0.elapsed().as_secs_f64(),
        psgd.total_steps + ggs.total_steps + llcg.total_steps
    );
    Ok(())
}
