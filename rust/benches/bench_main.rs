//! Performance benchmark harness (`cargo bench`).
//!
//! Custom harness (`harness = false`): the offline environment has no
//! criterion (DESIGN.md §Substitutions). Reports mean/std/p50/p99 over
//! timed iterations after warmup, one section per perf-critical component:
//!
//!   graph-gen          dataset generator throughput
//!   partition          partitioners on reddit-s (Fig 1 substrate)
//!   sampler            block building, fresh allocations per batch
//!   sampler-arena      block building into a reused BlockArena
//!   runtime            train/eval step via the host-literal path (baseline:
//!                      full state round-trips host<->device every step)
//!   runtime-resident   train/eval step on device-resident state
//!   round              end-to-end round latency (Fig 1 speedup source)
//!   comm               parameter averaging
//!   kernels            scalar vs tiled vs tiled+pool kernels at 1/2/4/8
//!                      threads, whole-step latency per engine, and
//!                      staged-vs-pinned block-input upload
//!                      (`make bench-kernels` -> BENCH_kernels.json)
//!   obs                instrumentation overhead: disabled/enabled span
//!                      cost, counter + histogram record cost, and the same
//!                      end-to-end round with tracing off vs on
//!                      (`make bench-obs` -> BENCH_obs.json)
//!
//! Filter with `cargo bench -- <substring>`. On exit every section is also
//! written as machine-readable `BENCH_<section>.json` (mean/p50/p99 per
//! row, stamped with the obs schema version) so the perf trajectory can be
//! tracked across commits.
//!
//! Runs against `artifacts/` (PJRT) when present and loadable, otherwise
//! against the generated native-backend manifest — the section layout and
//! JSON schema are identical either way.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use llcg::api::ExperimentBuilder;
use llcg::coordinator::Algorithm;
use llcg::graph::generators;
use llcg::partition;
use llcg::runtime::{ModelState, Runtime};
use llcg::sampler::{BlockArena, BlockBuilder, Fanout};
use llcg::util::{stats::Summary, Json, Pcg64};

struct Bench {
    filter: Option<String>,
    rows: Vec<(String, Summary)>,
}

impl Bench {
    fn new() -> Bench {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        Bench {
            filter,
            rows: Vec::new(),
        }
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter
            .as_deref()
            .map(|f| name.contains(f))
            .unwrap_or(true)
    }

    /// Time `f` for `iters` iterations after `warmup` runs.
    fn run(&mut self, name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3); // ms
        }
        let s = Summary::of(&samples);
        println!(
            "{name:<44} {:>9.3} ms ±{:>8.3}  p50={:>9.3}  p99={:>9.3}  (n={})",
            s.mean, s.std, s.p50, s.p99, s.n
        );
        self.rows.push((name.to_string(), s));
    }

    fn mean_of(&self, name: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.mean)
    }

    /// Write one `BENCH_<section>.json` per section (name prefix up to '/').
    fn write_json(&self) {
        let mut sections: BTreeMap<&str, Vec<&(String, Summary)>> = BTreeMap::new();
        for row in &self.rows {
            let sec = row.0.split('/').next().unwrap_or("misc");
            sections.entry(sec).or_default().push(row);
        }
        for (sec, rows) in sections {
            let j = Json::obj(vec![
                ("schema", Json::num(llcg::obs::SCHEMA_VERSION as f64)),
                ("section", Json::str(sec)),
                ("unit", Json::str("ms")),
                (
                    "rows",
                    Json::arr(
                        rows.iter()
                            .map(|(name, s)| {
                                Json::obj(vec![
                                    ("name", Json::str(name.as_str())),
                                    ("n", Json::num(s.n as f64)),
                                    ("mean", Json::num(s.mean)),
                                    ("std", Json::num(s.std)),
                                    ("p50", Json::num(s.p50)),
                                    ("p90", Json::num(s.p90)),
                                    ("p95", Json::num(s.p95)),
                                    ("p99", Json::num(s.p99)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]);
            let path = format!("BENCH_{sec}.json");
            if let Err(e) = std::fs::write(&path, j.to_string_pretty()) {
                eprintln!("failed to write {path}: {e}");
            } else {
                eprintln!("wrote {path}");
            }
        }
    }
}

fn main() {
    let mut b = Bench::new();
    println!(
        "{:<44} {:>12} {:>9} {:>14} {:>11}",
        "benchmark", "mean", "std", "p50", "p99"
    );

    // ---- graph generation --------------------------------------------------
    b.run("graph-gen/tiny(n=300)", 1, 10, || {
        std::hint::black_box(generators::by_name("tiny", 0).unwrap());
    });
    b.run("graph-gen/reddit-s(n=8000,deg=25)", 1, 3, || {
        std::hint::black_box(generators::by_name("reddit-s", 0).unwrap());
    });

    // ---- partitioners -------------------------------------------------------
    let ds = generators::by_name("reddit-s", 0).unwrap();
    for name in ["random", "bfs", "ldg", "metis"] {
        let p = partition::by_name(name).unwrap();
        let mut rng = Pcg64::new(1);
        b.run(&format!("partition/{name}(reddit-s,P=8)"), 1, 3, || {
            std::hint::black_box(p.partition(&ds.graph, 8, &mut rng));
        });
    }

    // ---- sampler / block building ------------------------------------------
    let mut rng = Pcg64::new(2);
    let bb = BlockBuilder::new(32, 8, 8, ds.d, 16, false);
    let train = ds.splits.train.clone();
    b.run("sampler/block-build(B=32,f=8x8,reddit-s)", 3, 50, || {
        let batch = rng.sample_without_replacement(&train, 32);
        std::hint::black_box(bb.build(&batch, &ds.graph, &ds, &mut rng));
    });
    let mut bb_full = bb.clone();
    bb_full.fanout = Fanout::Full;
    b.run("sampler/block-build-full-neighbors", 3, 50, || {
        let batch = rng.sample_without_replacement(&train, 32);
        std::hint::black_box(bb_full.build(&batch, &ds.graph, &ds, &mut rng));
    });

    // same workloads through a reused arena (the driver's hot path)
    let mut arena = BlockArena::new();
    b.run("sampler-arena/block-build(B=32,f=8x8,reddit-s)", 3, 50, || {
        let batch = rng.sample_without_replacement(&train, 32);
        std::hint::black_box(bb.build_into(&mut arena, &batch, &ds.graph, &ds, &mut rng));
    });
    let mut arena_full = BlockArena::new();
    b.run("sampler-arena/block-build-full-neighbors", 3, 50, || {
        let batch = rng.sample_without_replacement(&train, 32);
        std::hint::black_box(bb_full.build_into(
            &mut arena_full,
            &batch,
            &ds.graph,
            &ds,
            &mut rng,
        ));
    });
    if let (Some(fresh), Some(reused)) = (
        b.mean_of("sampler/block-build(B=32,f=8x8,reddit-s)"),
        b.mean_of("sampler-arena/block-build(B=32,f=8x8,reddit-s)"),
    ) {
        println!("  -> arena reuse speedup: {:.2}x", fresh / reused);
    }

    // ---- runtime: step latency ------------------------------------------------
    match Runtime::load_or_native("artifacts") {
        Err(e) => eprintln!("(no runtime available — skipping runtime benches: {e:#})"),
        Ok((rt, adir)) => {
            eprintln!("runtime backend: {} (artifacts: {adir})", rt.backend_name());
            for (ds_name, arch) in [("tiny", "gcn"), ("reddit-s", "sage"), ("reddit-s", "gat")]
            {
                let train_name = Runtime::train_name(arch, "adam", ds_name);
                if rt.meta(&train_name).is_err() || rt.warmup(&train_name).is_err() {
                    continue;
                }
                let data = generators::by_name(ds_name, 0).unwrap();
                let meta = rt.meta(&train_name).unwrap().clone();
                let mut rng = Pcg64::new(3);
                let mut state = ModelState::init(&meta, &mut rng);
                let bb = BlockBuilder::new(
                    meta.dims.b,
                    meta.dims.f1,
                    meta.dims.f2,
                    meta.dims.d,
                    meta.dims.c,
                    meta.multilabel(),
                );
                let batch = rng.sample_without_replacement(&data.splits.train, meta.dims.b);
                let blk = bb.build(&batch, &data.graph, &data, &mut rng);
                let iters = if ds_name == "tiny" { 40 } else { 15 };

                // baseline: full state serialized host<->device every step
                let lit_row = format!("runtime/train-step({arch},{ds_name})");
                b.run(&lit_row, 2, iters, || {
                    std::hint::black_box(
                        rt.train_step(&train_name, &mut state, &blk, 0.01).unwrap(),
                    );
                });
                // device-resident: upload once, step in place
                let mut dev = rt.upload(&train_name, &state).unwrap();
                let res_row = format!("runtime-resident/train-step({arch},{ds_name})");
                b.run(&res_row, 2, iters, || {
                    std::hint::black_box(rt.train_step_device(&mut dev, &blk, 0.01).unwrap());
                });
                if let (Some(lit), Some(res)) = (b.mean_of(&lit_row), b.mean_of(&res_row)) {
                    println!("  -> device-resident speedup: {:.2}x", lit / res);
                }

                let eval_name = Runtime::eval_name(arch, ds_name);
                if rt.meta(&eval_name).is_ok() && rt.warmup(&eval_name).is_ok() {
                    b.run(
                        &format!("runtime/eval-step({arch},{ds_name})"),
                        2,
                        iters,
                        || {
                            std::hint::black_box(
                                rt.eval_step(&eval_name, &state.params, &blk).unwrap(),
                            );
                        },
                    );
                    let mut devp = rt.upload_params(&eval_name, &state.params).unwrap();
                    b.run(
                        &format!("runtime-resident/eval-step({arch},{ds_name})"),
                        2,
                        iters,
                        || {
                            std::hint::black_box(rt.eval_step_device(&mut devp, &blk).unwrap());
                        },
                    );
                }
            }

            // ---- end-to-end round (Fig 1 / Table 1 substrate) --------------------
            // built once through the session API (dataset loaded one time,
            // shared by both variants); each timed iteration is launch+run
            let rt2 = Runtime::load(&adir).unwrap();
            let data = Arc::new(generators::by_name("tiny", 0).unwrap());
            let mk_round = |eval_every: usize| {
                ExperimentBuilder::new()
                    .with_dataset(data.clone())
                    .arch("gcn")
                    .algorithm(Algorithm::Llcg)
                    .parts(4)
                    .rounds(1)
                    .set("local_steps", "4")
                    .unwrap()
                    .eval_every(eval_every)
                    .eval_max_nodes(64)
                    .build()
                    .unwrap()
            };
            let exp_eval = mk_round(1);
            b.run("round/llcg(tiny,P=4,K=4)+eval", 1, 8, || {
                std::hint::black_box(exp_eval.launch(&rt2).finish().unwrap());
            });
            let exp_no_eval = mk_round(10); // skip eval inside the single round
            b.run("round/llcg(tiny,P=4,K=4)no-eval", 1, 8, || {
                std::hint::black_box(exp_no_eval.launch(&rt2).finish().unwrap());
            });
        }
    }

    // ---- comm: parameter averaging -------------------------------------------
    let mut rng = Pcg64::new(4);
    let states: Vec<ModelState> = (0..8)
        .map(|_| ModelState {
            params: vec![
                llcg::runtime::Tensor::glorot(&[64, 64], &mut rng),
                llcg::runtime::Tensor::glorot(&[64, 16], &mut rng),
            ],
            opt: vec![],
        })
        .collect();
    b.run("comm/average-params(8 workers, 5k params)", 5, 200, || {
        let refs: Vec<&ModelState> = states.iter().collect();
        std::hint::black_box(ModelState::average_params(&refs));
    });
    let mut acc: Vec<llcg::runtime::Tensor> = Vec::new();
    b.run("comm/average-params-into(8 workers, 5k params)", 5, 200, || {
        let refs: Vec<&ModelState> = states.iter().collect();
        ModelState::average_params_into(&mut acc, &refs);
        std::hint::black_box(&acc);
    });

    // ---- kernels: scalar vs tiled vs tiled+pool ------------------------------
    // Raw kernel shapes from the reddit-s sage hot path (n1=256, d=h=64,
    // n2=2048, band f2=8), then whole-step latency under each kernel engine,
    // then the staged-vs-pinned block-input upload. All variants produce
    // bit-identical results; only the clock differs.
    if b.enabled("kernels/") {
        use llcg::runtime::kernels::{self, KernelCtx};

        let mut krng = Pcg64::new(7);
        let dense = |rng: &mut Pcg64, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
        };
        let threads: &[usize] = &[1, 2, 4, 8];

        // dense matmul: agg2 @ w1 shape (256x64 @ 64x64)
        let (m, k, n) = (256usize, 64usize, 64usize);
        let a = dense(&mut krng, m * k);
        let w = dense(&mut krng, k * n);
        let mut out = vec![0.0f32; m * n];
        b.run("kernels/matmul(256x64x64)-scalar", 3, 60, || {
            kernels::matmul_ref(&a, &w, &mut out, m, k, n);
            std::hint::black_box(&out);
        });
        for &t in threads {
            let kc = KernelCtx::new(t);
            b.run(&format!("kernels/matmul(256x64x64)-tiled(t={t})"), 3, 60, || {
                kernels::matmul(&kc, &a, &w, &mut out, m, k, n);
                std::hint::black_box(&out);
            });
        }

        // gradient matmul: xᵀ @ dh reduction over 256 rows into 64x64
        let g = dense(&mut krng, m * n);
        let mut wgrad = vec![0.0f32; k * n];
        b.run("kernels/matmul_at_b(256x64x64)-scalar", 3, 60, || {
            kernels::matmul_at_b_ref(&a, &g, &mut wgrad, m, k, n, false);
            std::hint::black_box(&wgrad);
        });
        for &t in threads {
            let kc = KernelCtx::new(t);
            b.run(
                &format!("kernels/matmul_at_b(256x64x64)-tiled(t={t})"),
                3,
                60,
                || {
                    kernels::matmul_at_b(&kc, &a, &g, &mut wgrad, m, k, n, false);
                    std::hint::black_box(&wgrad);
                },
            );
        }

        // banded aggregation: A2 @ x2 at reddit-s shape (256x2048, band 8)
        let (bm, bband) = (256usize, 8usize);
        let bk = bm * bband;
        let mut a2 = vec![0.0f32; bm * bk];
        for i in 0..bm {
            for s in 0..bband {
                a2[i * bk + i * bband + s] = 1.0 / bband as f32;
            }
        }
        let x2 = dense(&mut krng, bk * 64);
        let mut agg = vec![0.0f32; bm * 64];
        b.run("kernels/aggregate(256x2048,band=8)-scalar", 3, 30, || {
            kernels::matmul_ref(&a2, &x2, &mut agg, bm, bk, 64);
            std::hint::black_box(&agg);
        });
        for &t in threads {
            let kc = KernelCtx::new(t);
            b.run(
                &format!("kernels/aggregate(256x2048,band=8)-banded(t={t})"),
                3,
                30,
                || {
                    kernels::matmul_banded(&kc, &a2, &x2, &mut agg, bm, bk, 64, bband);
                    std::hint::black_box(&agg);
                },
            );
        }

        // whole-step latency under each kernel engine (the acceptance row:
        // tiled+pooled vs scalar on the same device-resident sage step)
        match Runtime::load_or_native("artifacts") {
            Err(e) => eprintln!("(no runtime — skipping kernel step benches: {e:#})"),
            Ok((rt, _adir)) => {
                let train_name = Runtime::train_name("sage", "adam", "reddit-s");
                if rt.backend_name() != "native" {
                    eprintln!("(kernel step benches need the native backend — skipped)");
                } else if rt.meta(&train_name).is_ok() && rt.warmup(&train_name).is_ok() {
                    let data = generators::by_name("reddit-s", 0).unwrap();
                    let meta = rt.meta(&train_name).unwrap().clone();
                    let mut rng = Pcg64::new(9);
                    let state = ModelState::init(&meta, &mut rng);
                    let sbb = BlockBuilder::new(
                        meta.dims.b,
                        meta.dims.f1,
                        meta.dims.f2,
                        meta.dims.d,
                        meta.dims.c,
                        meta.multilabel(),
                    );
                    let batch =
                        rng.sample_without_replacement(&data.splits.train, meta.dims.b);
                    let blk = sbb.build(&batch, &data.graph, &data, &mut rng);

                    rt.set_kernel_scalar(true);
                    let mut dev = rt.upload(&train_name, &state).unwrap();
                    let scalar_row = "kernels/train-step(sage,reddit-s)-scalar";
                    b.run(scalar_row, 2, 20, || {
                        std::hint::black_box(
                            rt.train_step_device(&mut dev, &blk, 0.01).unwrap(),
                        );
                    });
                    rt.set_kernel_scalar(false);
                    let mut best: Option<(usize, f64)> = None;
                    for &t in threads {
                        rt.set_kernel_threads(t);
                        let row = format!("kernels/train-step(sage,reddit-s)-tiled(t={t})");
                        b.run(&row, 2, 20, || {
                            std::hint::black_box(
                                rt.train_step_device(&mut dev, &blk, 0.01).unwrap(),
                            );
                        });
                        if let Some(mean) = b.mean_of(&row) {
                            if best.map(|(_, m)| mean < m).unwrap_or(true) {
                                best = Some((t, mean));
                            }
                        }
                    }
                    if let (Some(scalar), Some((t, tiled))) = (b.mean_of(scalar_row), best) {
                        println!(
                            "  -> tiled+pool step speedup vs scalar: {:.2}x (best t={t})",
                            scalar / tiled
                        );
                    }

                    // block-input staging: fresh literals vs pinned overwrite
                    b.run("kernels/block-upload-staged(reddit-s)", 3, 60, || {
                        std::hint::black_box(
                            llcg::runtime::fresh_block_literals(meta.multilabel(), true, &blk)
                                .unwrap(),
                        );
                    });
                    let mut pinned = llcg::runtime::BlockLits::new();
                    pinned.stage(meta.multilabel(), true, &blk).unwrap(); // allocate once
                    b.run("kernels/block-upload-pinned(reddit-s)", 3, 60, || {
                        std::hint::black_box(pinned.stage(meta.multilabel(), true, &blk).unwrap());
                    });
                    if let (Some(staged), Some(pin)) = (
                        b.mean_of("kernels/block-upload-staged(reddit-s)"),
                        b.mean_of("kernels/block-upload-pinned(reddit-s)"),
                    ) {
                        println!("  -> pinned block staging speedup: {:.2}x", staged / pin);
                    }
                }
            }
        }
    }

    // ---- serve: cached inference vs the uncached eval path -------------------
    // The serving acceptance rows (`make bench-serve` -> BENCH_serve.json):
    // cold = the eval path's full 2-hop block build + forward per query
    // (params pre-uploaded — the best the uncached path can do); cached =
    // the per-snapshot embedding cache + one output-layer step. Same bits,
    // very different clocks. Throughput rows push the same request count
    // through each path (lower ms = higher sustained throughput).
    if b.enabled("serve/") {
        use llcg::runtime::KernelCtx;
        use llcg::serve::{
            run_load, EmbeddingCache, InferenceEngine, LoadMode, LoadSpec, ModelSnapshot,
            ServeConfig, Server, SnapshotHub,
        };

        match Runtime::load_or_native("artifacts") {
            Err(e) => eprintln!("(no runtime available — skipping serve benches: {e:#})"),
            Ok((rt, _adir)) => {
                if rt.backend_name() != "native" {
                    eprintln!("(serve benches need the native backend — skipped)");
                } else {
                    let data = Arc::new(generators::by_name("reddit-s", 0).unwrap());
                    let train_meta = rt.meta("gcn_adam_reddit-s").unwrap().clone();
                    let eval_name = "gcn_eval_reddit-s";
                    let em = rt.meta(eval_name).unwrap().clone();
                    let mut rng = Pcg64::new(11);
                    let state = ModelState::init(&train_meta, &mut rng);
                    let snap = Arc::new(
                        ModelSnapshot::for_artifact(&train_meta, &state.params, 1).unwrap(),
                    );
                    let val = data.splits.val.clone();
                    let threads: &[usize] = &[1, 2, 4, 8];

                    // cache build cost (paid once per published snapshot)
                    let kc1 = KernelCtx::new(0);
                    b.run("serve/cache-build(gcn,reddit-s)", 1, 5, || {
                        std::hint::black_box(
                            EmbeddingCache::build(&snap, &data, &kc1).unwrap().bytes(),
                        );
                    });

                    // cold baseline: full 2-hop eval block + forward per query
                    let mut bb = BlockBuilder::new(
                        em.dims.b,
                        em.dims.f1,
                        em.dims.f2,
                        em.dims.d,
                        em.dims.c,
                        em.multilabel(),
                    );
                    bb.fanout = Fanout::Full;
                    bb.sample_ratio = 1.0;
                    let mut dev = rt.upload_params(eval_name, &state.params).unwrap();
                    let mut arena = BlockArena::new();
                    let mut qrng = Pcg64::new(13);
                    let cold_row = "serve/query-batch1-uncached(gcn,reddit-s)";
                    b.run(cold_row, 3, 60, || {
                        let v = *qrng.choose(&val);
                        let blk = bb.build_into(&mut arena, &[v], &data.graph, &data, &mut qrng);
                        std::hint::black_box(rt.eval_step_device(&mut dev, blk).unwrap().len());
                    });

                    // cached engine: batch=1 and micro-batched, per thread count
                    for &t in threads {
                        let mut engine = InferenceEngine::new(
                            snap.clone(),
                            data.clone(),
                            KernelCtx::new(t),
                        )
                        .unwrap();
                        let mut r2 = Pcg64::new(17);
                        let one_row = format!("serve/query-batch1-cached(t={t})");
                        b.run(&one_row, 5, 200, || {
                            let v = *r2.choose(&val);
                            std::hint::black_box(engine.score_batch(&[v]).unwrap().len());
                        });
                        if t == 1 {
                            if let (Some(cold), Some(one)) =
                                (b.mean_of(cold_row), b.mean_of(&one_row))
                            {
                                println!(
                                    "  -> embedding cache query speedup (batch=1, t=1): {:.2}x",
                                    cold / one
                                );
                            }
                        }
                        b.run(&format!("serve/query-microbatch32-cached(t={t})"), 5, 100, || {
                            let batch = r2.sample_without_replacement(&val, 32);
                            std::hint::black_box(engine.score_batch(&batch).unwrap().len());
                        });
                    }

                    // sustained throughput: N requests through each path
                    let n_req = 256usize;
                    let unc_row = format!("serve/throughput-uncached-batch1(n={n_req})");
                    let mut r3 = Pcg64::new(19);
                    b.run(&unc_row, 1, 3, || {
                        for _ in 0..n_req {
                            let v = *r3.choose(&val);
                            let blk =
                                bb.build_into(&mut arena, &[v], &data.graph, &data, &mut r3);
                            std::hint::black_box(
                                rt.eval_step_device(&mut dev, blk).unwrap().len(),
                            );
                        }
                    });
                    let hub = SnapshotHub::new();
                    hub.publish(ModelSnapshot::for_artifact(&train_meta, &state.params, 1).unwrap());
                    for &t in threads {
                        let server = Server::start(
                            hub.clone(),
                            data.clone(),
                            ServeConfig {
                                max_batch: 32,
                                flush_us: 200,
                                threads: t,
                                queue: 1024,
                                shed: false,
                            },
                        )
                        .unwrap();
                        let client = server.client();
                        let spec = LoadSpec {
                            mode: LoadMode::Closed,
                            clients: 4,
                            requests: n_req,
                            seed: 23,
                        };
                        let srv_row =
                            format!("serve/throughput-server-microbatch(n={n_req},clients=4,t={t})");
                        b.run(&srv_row, 1, 3, || {
                            let rep = run_load(&client, &val, &spec);
                            assert_eq!(rep.completed, n_req, "load run dropped requests");
                            std::hint::black_box(rep.throughput_rps);
                        });
                        if let (Some(unc), Some(srv)) =
                            (b.mean_of(&unc_row), b.mean_of(&srv_row))
                        {
                            println!(
                                "  -> micro-batched+cached throughput vs uncached batch=1 \
                                 (t={t}): {:.2}x",
                                unc / srv
                            );
                        }
                        drop(client);
                        server.shutdown();
                    }
                }
            }
        }
    }

    // ---- cluster: sequential vs threaded engine wall-clock -------------------
    // Measured end-to-end run time of the same LLCG workload under the
    // sequential driver vs the multi-threaded cluster engine, at P = 2/4/8.
    // `net=ideal` shows pure compute overlap (bounded by the machine's
    // cores); `net=wan,scale=1` injects the modeled transfer times as real
    // sleeps, so the threaded engine's communication overlap shows up in
    // measured wall-clock the way it would on a real cluster.
    // (setup is skipped entirely when the filter excludes the section)
    if b.enabled("cluster/") {
        match Runtime::load_or_native("artifacts") {
            Err(e) => eprintln!("(no runtime available — skipping cluster benches: {e:#})"),
            Ok((rt, _adir)) => {
                if rt.backend_name() != "native" {
                    eprintln!("(cluster engine needs the native backend — skipping cluster benches)");
                } else if rt.meta("sage_adam_reddit-s").is_err() {
                    eprintln!("(no sage/reddit-s artifact — skipping cluster benches)");
                } else {
                    eprintln!(
                        "cluster benches: {} cpu cores available (ideal-net speedup is capped by this)",
                        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
                    );
                    // the dataset is loaded once and shared by all 12
                    // (engine, P, net) experiments via the session API
                    let data = Arc::new(generators::by_name("reddit-s", 0).unwrap());
                    for &netspec in &["ideal", "wan,scale=1"] {
                        let label = if netspec == "ideal" { "ideal" } else { "wan" };
                        for &pn in &[2usize, 4, 8] {
                            let mk = |engine: llcg::cluster::Engine| {
                                ExperimentBuilder::new()
                                    .with_dataset(data.clone())
                                    .arch("sage")
                                    .algorithm(Algorithm::Llcg)
                                    .parts(pn)
                                    .rounds(2)
                                    .set("local_steps", "4")
                                    .unwrap()
                                    .correction_steps(2)
                                    .eval_every(100) // no per-round eval
                                    .eval_max_nodes(32)
                                    .engine(engine)
                                    .net(netspec)
                                    .build()
                                    .unwrap()
                            };
                            let seq_exp = mk(llcg::cluster::Engine::Sequential);
                            let clu_exp = mk(llcg::cluster::Engine::Cluster);
                            let seq_row = format!("cluster/sequential(P={pn},net={label})");
                            b.run(&seq_row, 1, 3, || {
                                std::hint::black_box(seq_exp.launch(&rt).finish().unwrap());
                            });
                            let clu_row = format!("cluster/threaded(P={pn},net={label})");
                            b.run(&clu_row, 1, 3, || {
                                std::hint::black_box(clu_exp.launch(&rt).finish().unwrap());
                            });
                            if let (Some(seq), Some(clu)) =
                                (b.mean_of(&seq_row), b.mean_of(&clu_row))
                            {
                                println!(
                                    "  -> threaded speedup at P={pn}, net={label}: {:.2}x",
                                    seq / clu
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    // ---- cluster-faults: robustness cost of injected failures ----------------
    // End-to-end LLCG runs on the cluster engine under message loss
    // (drop ∈ {0, 0.02, 0.1}) and a mid-run crash with/without respawn.
    // Each row's timing is the full run; the trailing println reports the
    // first round at which the global loss reaches the fault-free run's
    // final loss (+5%), so BENCH_cluster_faults.json + stdout together give
    // time-to-target under each failure mode.
    // (`make bench-cluster-faults` -> BENCH_cluster_faults.json)
    if b.enabled("cluster_faults/") {
        match Runtime::load_or_native("artifacts") {
            Err(e) => {
                eprintln!("(no runtime available — skipping cluster-faults benches: {e:#})")
            }
            Ok((rt, _adir)) => {
                if rt.backend_name() != "native" {
                    eprintln!(
                        "(cluster engine needs the native backend — skipping cluster-faults benches)"
                    );
                } else if rt.meta("gcn_adam_reddit-s").is_err() {
                    eprintln!("(no gcn/reddit-s artifact — skipping cluster-faults benches)");
                } else {
                    let data = Arc::new(generators::by_name("reddit-s", 0).unwrap());
                    let rounds = 6usize;
                    let mk = |net: &str, respawn: bool| {
                        ExperimentBuilder::new()
                            .with_dataset(data.clone())
                            .arch("gcn")
                            .algorithm(Algorithm::Llcg)
                            .parts(4)
                            .rounds(rounds)
                            .set("local_steps", "4")
                            .unwrap()
                            .correction_steps(2)
                            .eval_every(100) // no per-round eval
                            .eval_max_nodes(32)
                            .engine(llcg::cluster::Engine::Cluster)
                            .net(net)
                            .respawn(respawn)
                            .build()
                            .unwrap()
                    };
                    // the fault-free run sets the bar every variant must reach
                    let clean = mk("ideal", true).launch(&rt).finish().unwrap();
                    let target = clean.records.last().unwrap().global_loss * 1.05;
                    let report = |tag: &str, res: &llcg::coordinator::driver::RunResult| {
                        let hit = res
                            .records
                            .iter()
                            .find(|r| r.global_loss <= target)
                            .map(|r| r.round);
                        match hit {
                            Some(r) => println!(
                                "  -> {tag}: target loss {target:.4} reached at round {r}/{rounds} \
                                 (drops={}, respawns={})",
                                res.total_drops, res.total_respawns
                            ),
                            None => println!(
                                "  -> {tag}: target loss {target:.4} NOT reached in {rounds} rounds \
                                 (final {:.4}, drops={}, respawns={})",
                                res.records.last().map(|r| r.global_loss).unwrap_or(f64::NAN),
                                res.total_drops,
                                res.total_respawns
                            ),
                        }
                    };
                    for &(label, net) in &[
                        ("0", "ideal"),
                        ("0.02", "drop=0.02"),
                        ("0.1", "drop=0.1"),
                    ] {
                        let exp = mk(net, true);
                        let row = format!("cluster_faults/llcg(P=4,drop={label})");
                        let mut last = None;
                        b.run(&row, 1, 3, || {
                            last = Some(exp.launch(&rt).finish().unwrap());
                        });
                        if let Some(res) = &last {
                            report(&format!("drop={label}"), res);
                        }
                    }
                    for &respawn in &[true, false] {
                        let exp = mk("crash=1@3", respawn);
                        let row = format!("cluster_faults/llcg(P=4,crash=1@3,respawn={respawn})");
                        let mut last = None;
                        b.run(&row, 1, 3, || {
                            last = Some(exp.launch(&rt).finish().unwrap());
                        });
                        if let Some(res) = &last {
                            report(&format!("crash=1@3 respawn={respawn}"), res);
                        }
                    }
                }
            }
        }
    }

    // ---- cluster-transport: modeled threads vs real processes ---------------
    // The same tiny LLCG run on the cluster engine over each worker wire:
    // in-process threads (modeled net, zero wire bytes), loopback TCP, and
    // unix-domain sockets — both remote rows spawn real `llcg worker`
    // processes per iteration, so the row prices process startup + handshake
    // + per-round framing against the in-process baseline. The trailing
    // printlns report the measured wire bytes per round from the RunResult.
    // (`make bench-cluster-transport` -> BENCH_cluster_transport.json)
    if b.enabled("cluster_transport/") {
        match Runtime::load_or_native("artifacts") {
            Err(e) => {
                eprintln!("(no runtime available — skipping cluster-transport benches: {e:#})")
            }
            Ok((rt, adir)) => {
                if rt.backend_name() != "native" {
                    eprintln!(
                        "(cluster engine needs the native backend — skipping cluster-transport benches)"
                    );
                } else if rt.meta("gcn_adam_tiny").is_err() {
                    eprintln!("(no gcn/tiny artifact — skipping cluster-transport benches)");
                } else {
                    let data = Arc::new(generators::by_name("tiny", 0).unwrap());
                    let mk = |transport: &str| {
                        ExperimentBuilder::new()
                            .with_dataset(data.clone())
                            .arch("gcn")
                            .algorithm(Algorithm::Llcg)
                            .parts(2)
                            .rounds(2)
                            .set("local_steps", "4")
                            .unwrap()
                            .correction_steps(2)
                            .eval_every(100) // no per-round eval
                            .eval_max_nodes(32)
                            .engine(llcg::cluster::Engine::Cluster)
                            // worker processes rebuild the runtime from the
                            // config; pin them to the artifacts this rt uses
                            .set("artifacts_dir", &adir)
                            .unwrap()
                            .transport(transport)
                            .build()
                            .unwrap()
                    };
                    let exp = mk("inprocess");
                    b.run("cluster_transport/inprocess(tiny,P=2)", 1, 3, || {
                        std::hint::black_box(exp.launch(&rt).finish().unwrap());
                    });
                    // remote rows need the CLI binary for worker spawns; a
                    // bench invocation's current_exe() is the bench harness
                    let exe = std::env::var("LLCG_WORKER_EXE").ok().or_else(|| {
                        ["target/release/llcg", "target/debug/llcg"]
                            .iter()
                            .find(|p| std::path::Path::new(p).is_file())
                            .map(|s| s.to_string())
                    });
                    match exe {
                        None => eprintln!(
                            "(no llcg binary under target/ and LLCG_WORKER_EXE unset — \
                             skipping remote transport rows; `cargo build --release` first)"
                        ),
                        Some(exe) => {
                            std::env::set_var("LLCG_WORKER_EXE", exe);
                            let mut specs = vec!["tcp"];
                            if cfg!(unix) {
                                specs.push("uds");
                            }
                            for spec in specs {
                                let exp = mk(spec);
                                let mut last = None;
                                b.run(&format!("cluster_transport/{spec}(tiny,P=2)"), 1, 3, || {
                                    last = Some(exp.launch(&rt).finish().unwrap());
                                });
                                if let Some(res) = &last {
                                    let up: u64 =
                                        res.records.iter().map(|r| r.wire_bytes_up).sum();
                                    let down: u64 =
                                        res.records.iter().map(|r| r.wire_bytes_down).sum();
                                    let n = res.records.len().max(1) as u64;
                                    println!(
                                        "  -> {spec}: measured wire bytes/round: \
                                         down={} up={} (modeled {} B/round)",
                                        down / n,
                                        up / n,
                                        res.avg_round_bytes as u64
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    // ---- obs: instrumentation overhead ---------------------------------------
    // Micro rows price the primitives (a disabled span must stay at one
    // relaxed load + branch), then the same end-to-end LLCG round runs with
    // tracing off vs on so BENCH_obs.json carries the acceptance number:
    // the off-row must sit within noise of an uninstrumented build, and the
    // on/off ratio is the real cost of `--trace`.
    // (`make bench-obs` -> BENCH_obs.json)
    if b.enabled("obs/") {
        use llcg::obs;

        obs::set_enabled(false);
        b.run("obs/span-disabled(x10k)", 3, 50, || {
            for _ in 0..10_000 {
                std::hint::black_box(obs::span("bench.obs"));
            }
        });
        obs::set_enabled(true);
        b.run("obs/span-enabled(x10k)", 3, 30, || {
            for _ in 0..10_000 {
                std::hint::black_box(obs::span("bench.obs"));
            }
        });
        obs::set_enabled(false);
        let drained = obs::take_spans().len();
        println!("  -> drained {drained} bench spans");
        let c = obs::counter("bench.counter");
        b.run("obs/counter-inc(x10k)", 3, 50, || {
            for _ in 0..10_000 {
                c.inc();
            }
        });
        let h = obs::histogram("bench.hist-record(x10k)");
        b.run("obs/histogram-record(x10k)", 3, 50, || {
            for _ in 0..10_000 {
                h.record_ns(std::hint::black_box(1_234));
            }
        });
        // one Prometheus scrape of /metrics = one registry render
        b.run("obs/prometheus-render", 3, 50, || {
            std::hint::black_box(obs::prometheus_text().len());
        });

        match Runtime::load_or_native("artifacts") {
            Err(e) => eprintln!("(no runtime available — skipping obs round benches: {e:#})"),
            Ok((rt, _adir)) => {
                if rt.meta("gcn_adam_tiny").is_err() || rt.warmup("gcn_adam_tiny").is_err() {
                    eprintln!("(no gcn/tiny artifact — skipping obs round benches)");
                } else {
                    let data = Arc::new(generators::by_name("tiny", 0).unwrap());
                    let exp = ExperimentBuilder::new()
                        .with_dataset(data)
                        .arch("gcn")
                        .algorithm(Algorithm::Llcg)
                        .parts(4)
                        .rounds(1)
                        .set("local_steps", "4")
                        .unwrap()
                        .eval_every(1)
                        .eval_max_nodes(64)
                        .build()
                        .unwrap();
                    let off_row = "obs/round-trace-off(tiny,P=4,K=4)";
                    obs::set_enabled(false);
                    b.run(off_row, 1, 8, || {
                        std::hint::black_box(exp.launch(&rt).finish().unwrap());
                    });
                    let on_row = "obs/round-trace-on(tiny,P=4,K=4)";
                    obs::set_enabled(true);
                    b.run(on_row, 1, 8, || {
                        std::hint::black_box(exp.launch(&rt).finish().unwrap());
                        // draining is part of what --trace pays, and keeps the
                        // sink bounded across iterations
                        std::hint::black_box(obs::take_spans().len());
                    });
                    obs::set_enabled(false);
                    let _ = obs::take_spans();
                    if let (Some(off), Some(on)) = (b.mean_of(off_row), b.mean_of(on_row)) {
                        println!(
                            "  -> tracing-on overhead vs off: {:+.2}%",
                            (on / off - 1.0) * 100.0
                        );
                    }
                    // the training monitors (`--listen`): divergence math +
                    // two extra correction-probe evals per round
                    let mon_row = "obs/round-monitors-on(tiny,P=4,K=4)";
                    obs::monitor::reset();
                    obs::monitor::set_enabled(true);
                    b.run(mon_row, 1, 8, || {
                        std::hint::black_box(exp.launch(&rt).finish().unwrap());
                    });
                    obs::monitor::set_enabled(false);
                    obs::monitor::reset();
                    if let (Some(off), Some(mon)) = (b.mean_of(off_row), b.mean_of(mon_row)) {
                        println!(
                            "  -> monitors-on overhead vs off: {:+.2}%",
                            (mon / off - 1.0) * 100.0
                        );
                    }
                }
            }
        }
    }

    b.write_json();
    println!("\n{} benchmarks complete.", b.rows.len());
}
