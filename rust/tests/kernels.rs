//! Kernel-layer determinism contract at the system level: full train/eval
//! steps and whole runs must be bit-identical across kernel thread counts
//! {1, 2, 7} and against the scalar reference kernels, the pinned block
//! staging must match the fresh-literal path, the device-side eval
//! reductions must reproduce the logits-download metrics exactly, and the
//! parallel optimizer-update passes must match their sequential reference
//! at pool-engaging sizes.
//! (Kernel-vs-reference parity on odd shapes lives in the unit tests of
//! `runtime::kernels`; pool lifecycle tests in `runtime::pool`.)

use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::metrics;
use llcg::runtime::{ModelState, Runtime};
use llcg::sampler::BlockBuilder;
use llcg::util::Pcg64;

fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

/// Train a few device-resident steps and return (losses, params) bits.
fn run_steps(rt: &Runtime, ds_name: &str, name: &str, seed: u64) -> (Vec<u32>, Vec<Vec<u32>>) {
    let ds = generators::by_name(ds_name, 0).unwrap();
    let meta = rt.meta(name).unwrap().clone();
    let bb = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        meta.multilabel(),
    );
    let mut init_rng = Pcg64::new(seed);
    let mut state = ModelState::init(&meta, &mut init_rng);
    let mut rng = Pcg64::new(seed + 1);
    let targets: Vec<u32> = ds.splits.train[..meta.dims.b].to_vec();
    let mut dev = rt.upload(name, &state).unwrap();
    let mut losses = Vec::new();
    for _ in 0..4 {
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        losses.push(
            rt.train_step_device(&mut dev, &blk, 0.02)
                .unwrap()
                .to_bits(),
        );
    }
    rt.download_into(&dev, &mut state).unwrap();
    let params = state
        .params
        .iter()
        .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
        .collect();
    (losses, params)
}

#[test]
fn steps_are_bit_identical_across_thread_counts_and_scalar() {
    let rt = native_rt();
    // tiny ships gcn/sage/mlp; appnp's smallest shape lives on flickr-s
    for (ds_name, arch) in [
        ("tiny", "gcn"),
        ("tiny", "sage"),
        ("tiny", "mlp"),
        ("flickr-s", "appnp"),
    ] {
        let name = format!("{arch}_adam_{ds_name}");
        rt.set_kernel_scalar(true);
        rt.set_kernel_threads(1);
        let want = run_steps(&rt, ds_name, &name, 31);
        rt.set_kernel_scalar(false);
        for threads in [1usize, 2, 7] {
            rt.set_kernel_threads(threads);
            assert_eq!(rt.kernel_threads(), threads);
            let got = run_steps(&rt, ds_name, &name, 31);
            assert_eq!(want, got, "{arch} t={threads}: diverged from scalar");
        }
    }
    rt.set_kernel_threads(0); // back to auto; later tests share the runtime dir
}

#[test]
fn whole_run_is_bit_identical_across_kernel_thread_counts() {
    // the engine-level consequence of kernel determinism: the sequential
    // driver at kernel_threads=1 and =7 produces the same RunResult bits
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 2;
    cfg.rounds = 2;
    cfg.schedule = Schedule::Fixed { k: 2 };
    cfg.correction_steps = 1;
    cfg.eval_max_nodes = 32;
    cfg.seed = 5;
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let mut results = Vec::new();
    for threads in [1usize, 7] {
        let rt = native_rt();
        cfg.kernel_threads = threads;
        results.push(driver::run_experiment(&cfg, &ds, &rt).unwrap());
    }
    let (a, b) = (&results[0], &results[1]);
    assert_eq!(a.final_val.to_bits(), b.final_val.to_bits());
    assert_eq!(a.final_test.to_bits(), b.final_test.to_bits());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.local_loss.to_bits(), rb.local_loss.to_bits());
        assert_eq!(ra.global_loss.to_bits(), rb.global_loss.to_bits());
        assert_eq!(ra.val_score.to_bits(), rb.val_score.to_bits());
    }
}

#[test]
fn eval_split_matches_logits_download_path() {
    // device-side reductions (argmax / pos-bits / per-row loss) vs the full
    // logits download + metrics::* — bit-for-bit, on a multiclass and a
    // multilabel dataset
    let rt = native_rt();
    for (ds_name, arch) in [("tiny", "gcn"), ("yelp-s", "gcn")] {
        let ds = generators::by_name(ds_name, 3).unwrap();
        let eval_name = Runtime::eval_name(arch, ds_name);
        let meta = rt.meta(&eval_name).unwrap().clone();
        let train_meta = rt
            .meta(&Runtime::train_name(arch, "adam", ds_name))
            .unwrap()
            .clone();
        let mut rng = Pcg64::new(17);
        let state = ModelState::init(&train_meta, &mut rng);
        let bb = BlockBuilder::new(
            meta.dims.b,
            meta.dims.f1,
            meta.dims.f2,
            meta.dims.d,
            meta.dims.c,
            meta.multilabel(),
        );
        let ids: Vec<u32> = ds.splits.val.iter().copied().take(50).collect();
        assert!(!ids.is_empty());
        // both paths consume the same rng stream (Full fanout draws none)
        let logits = driver::eval_logits(
            &rt,
            &eval_name,
            &state.params,
            &ds,
            &ids,
            &bb,
            &mut Pcg64::new(1),
        )
        .unwrap();
        let want_score = driver::score(&ds, &logits, meta.dims.c, &ids);
        let want_loss = metrics::mean_loss(&logits, meta.dims.c, &ds.labels, &ids);
        let (score, loss) = driver::eval_split(
            &rt,
            &eval_name,
            &state.params,
            &ds,
            &ids,
            &bb,
            &mut Pcg64::new(1),
            true,
        )
        .unwrap();
        assert_eq!(
            want_score.to_bits(),
            score.to_bits(),
            "{ds_name}: score diverged"
        );
        assert_eq!(
            want_loss.to_bits(),
            loss.to_bits(),
            "{ds_name}: mean loss diverged"
        );
    }
}

#[test]
fn parallel_optimizer_updates_match_scalar_reference_at_scale() {
    // tiny-model tensors stay under the pool-engagement threshold, so the
    // whole-step tests above exercise the inline path; this drives the
    // update kernels at production-sized tensors where the pool really
    // splits the index space, against the scalar-reference path
    use llcg::runtime::kernels::{adam_update, sgd_update, KernelCtx};
    use llcg::runtime::ThreadPool;
    use std::sync::Arc;

    let n = 80_000usize;
    let mut rng = Pcg64::new(43);
    let dense = |rng: &mut Pcg64, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
    };
    let p0 = dense(&mut rng, n);
    let g0 = dense(&mut rng, n);
    let g1 = dense(&mut rng, n);
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

    // reference: the scalar flag routes both kernels to the sequential loop
    let scalar = KernelCtx::with_pool(Arc::new(ThreadPool::new(4)), true);
    let run = |kc: &KernelCtx| {
        let mut p = p0.clone();
        sgd_update(kc, &mut p, &g0, 0.03);
        let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
        for (t, g) in [&g0, &g1].into_iter().enumerate() {
            let t1 = (t + 1) as f32;
            let bc1 = 1.0 - llcg::runtime::native::ADAM_B1.powf(t1);
            let bc2 = 1.0 - llcg::runtime::native::ADAM_B2.powf(t1);
            adam_update(
                kc,
                &mut p,
                &mut m,
                &mut v,
                g,
                0.01,
                bc1,
                bc2,
                llcg::runtime::native::ADAM_B1,
                llcg::runtime::native::ADAM_B2,
                llcg::runtime::native::ADAM_EPS,
            );
        }
        (bits(&p), bits(&m), bits(&v))
    };
    let want = run(&scalar);
    for threads in [1usize, 2, 7] {
        let got = run(&KernelCtx::new(threads));
        assert_eq!(want, got, "optimizer updates diverged at t={threads}");
    }
}

#[test]
fn cluster_and_sequential_agree_at_mixed_kernel_thread_counts() {
    // the strongest form of the contract: different engines AND different
    // kernel-thread settings, still bit-for-bit equal losses
    let rt = native_rt();
    let mut seq_cfg = ExperimentConfig::default();
    seq_cfg.dataset = "tiny".into();
    seq_cfg.algorithm = Algorithm::Llcg;
    seq_cfg.parts = 3;
    seq_cfg.rounds = 2;
    seq_cfg.schedule = Schedule::Fixed { k: 2 };
    seq_cfg.correction_steps = 1;
    seq_cfg.eval_max_nodes = 32;
    seq_cfg.seed = 9;
    seq_cfg.kernel_threads = 5;
    let mut clu_cfg = seq_cfg.clone();
    clu_cfg.engine = llcg::cluster::Engine::Cluster;
    clu_cfg.kernel_threads = 2;
    let ds = generators::by_name("tiny", seq_cfg.seed).unwrap();
    let a = driver::run_experiment(&seq_cfg, &ds, &rt).unwrap();
    let b = driver::run_experiment(&clu_cfg, &ds, &rt).unwrap();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(
            ra.local_loss.to_bits(),
            rb.local_loss.to_bits(),
            "round {}: kernel-thread counts must not leak into numerics",
            ra.round
        );
        assert_eq!(ra.val_score.to_bits(), rb.val_score.to_bits());
    }
    assert_eq!(a.final_test.to_bits(), b.final_test.to_bits());
}
