//! Live-telemetry-plane acceptance tests:
//!
//! - the HTTP exposition server answers `/metrics`, `/health`, `/run`, and
//!   `/series` **while a cluster run is in flight**, with valid NaN-free
//!   Prometheus text and schema-stamped JSON;
//! - the paper-grounded divergence monitor sees what Thm 4.3–4.4 predict:
//!   on the same seed, cross-worker parameter divergence is strictly lower
//!   with Global Server Corrections enabled than with them disabled.
//!
//! The monitor switch + history and the metrics registry are process-global
//! state, so every test takes `test_lock()` and resets both behind it.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};

use llcg::api::{Event, ExperimentBuilder};
use llcg::cluster::Engine;
use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::obs;
use llcg::runtime::Runtime;
use llcg::util::Json;

fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // a previous test may have panicked with the monitors live
    obs::monitor::set_enabled(false);
    obs::monitor::reset();
    guard
}

fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 4;
    cfg.rounds = 4;
    cfg.schedule = Schedule::Fixed { k: 3 };
    cfg.correction_steps = 2;
    cfg.eval_every = 2;
    cfg.eval_max_nodes = 64;
    cfg.seed = 7;
    cfg
}

fn run_with(cfg: &ExperimentConfig, rt: &Runtime) -> driver::RunResult {
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    driver::run_experiment(cfg, &ds, rt).unwrap()
}

/// Minimal HTTP/1.1 GET against the exporter: returns (head, body).
fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect exporter");
    write!(s, "GET {path} HTTP/1.1\r\nHost: llcg-test\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read response");
    let (head, body) = out.split_once("\r\n\r\n").expect("no header break");
    (head.to_string(), body.to_string())
}

#[test]
fn endpoints_answer_during_a_live_cluster_run() {
    let _l = test_lock();
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;

    // the plane the CLI assembles for `--listen`: exporter + sampler +
    // monitors, health/events fed from the run's event stream
    let exporter = obs::Exporter::bind("127.0.0.1:0").expect("bind exporter");
    let addr = exporter.addr();
    let sampler = obs::Sampler::start(5, 512);
    exporter.attach_series(sampler.ring());
    obs::monitor::reset();
    obs::monitor::set_enabled(true);
    // guarantee at least one histogram so the bucket exposition is exercised
    let rtt = obs::histogram("test.telemetry.rtt");
    rtt.reset();
    rtt.record_ns(1_234_567);
    let mut health = obs::RunHealth::new(cfg.engine.name(), cfg.parts, cfg.rounds);
    health.state = "running".into();
    exporter.set_health(health.clone());

    let ds = Arc::new(generators::by_name(&cfg.dataset, cfg.seed).unwrap());
    let mut mid: Option<(String, String)> = None;
    let res = ExperimentBuilder::from_config(cfg.clone())
        .with_dataset(ds)
        .build()
        .unwrap()
        .launch(&rt)
        .stream(|ev| {
            exporter.push_event(ev.to_json());
            if let Event::RoundCompleted(r) = ev {
                health.last_round = r.round;
                exporter.set_health(health.clone());
                if r.round == 2 && mid.is_none() {
                    // scrape mid-run, exactly like a Prometheus poll
                    mid = Some((http_get(addr, "/metrics").1, http_get(addr, "/health").1));
                }
            }
        })
        .unwrap();
    obs::monitor::set_enabled(false);
    let ring = sampler.stop();
    assert_eq!(res.records.len(), cfg.rounds);

    // ---- /metrics, captured while round 3 had not started yet
    let (metrics, health_body) = mid.expect("round 2 never completed");
    assert!(!metrics.is_empty(), "empty exposition mid-run");
    assert!(!metrics.contains("NaN"), "exposition must be NaN-free:\n{metrics}");
    assert!(metrics.contains("# TYPE"), "no TYPE lines:\n{metrics}");
    for want in [
        "llcg_monitor_divergence_max",
        "llcg_monitor_divergence_mean",
        "llcg_test_telemetry_rtt_bucket{le=\"+Inf\"} 1",
        "llcg_test_telemetry_rtt_count 1",
    ] {
        assert!(metrics.contains(want), "`{want}` missing from:\n{metrics}");
    }

    // ---- /health, same moment: the run self-reports as live at round 2
    let h = Json::parse(&health_body).expect("health JSON parses");
    assert_eq!(h.req("schema").as_f64().unwrap() as u64, obs::SCHEMA_VERSION);
    assert_eq!(h.req("state").as_str(), Some("running"));
    assert_eq!(h.req("last_round").as_f64(), Some(2.0));
    assert_eq!(h.req("parts").as_f64(), Some(cfg.parts as f64));
    assert_eq!(h.req("rounds").as_f64(), Some(cfg.rounds as f64));
    let meta = h.req("meta");
    assert_eq!(
        meta.req("pid").as_f64(),
        Some(std::process::id() as f64),
        "health meta names the wrong process"
    );

    // ---- /run: the event tail replays the stream we pushed
    let (_, run_body) = http_get(addr, "/run");
    let r = Json::parse(&run_body).expect("run JSON parses");
    let events = r.req("events").as_array().unwrap();
    assert!(!events.is_empty());
    let completed = events
        .iter()
        .filter(|e| e.req("event").as_str() == Some("round_completed"))
        .count();
    assert_eq!(completed, cfg.rounds, "event tail misses round boundaries");

    // ---- /series: the sampler caught the monitor gauges moving
    let (_, series_body) = http_get(addr, "/series");
    let s = Json::parse(&series_body).expect("series JSON parses");
    assert_eq!(s.req("schema").as_f64().unwrap() as u64, obs::SCHEMA_VERSION);
    let samples = s.req("samples").as_array().unwrap();
    assert!(!samples.is_empty(), "no samples after a multi-round run");
    let last = samples.last().unwrap().req("values");
    assert!(
        last.get("monitor.divergence_max").and_then(Json::as_f64).is_some(),
        "series samples miss the divergence gauge: {last:?}"
    );
    // the stopped ring and the live route agree
    assert_eq!(
        samples.len(),
        ring.to_json().req("samples").as_array().unwrap().len()
    );

    // one divergence observation per round landed in the history
    assert_eq!(obs::monitor::divergence_history().len(), cfg.rounds);
    obs::monitor::reset();
    exporter.shutdown();
}

/// The acceptance check grounded in Thm 4.3–4.4: the Global Server
/// Correction exists to cancel the residual error that worker drift
/// creates, and a corrected global model sits closer to the optimum, so
/// the same seed must show strictly lower cross-worker divergence with
/// corrections on (`rho > 0`) than off.
#[test]
fn corrections_keep_cross_worker_divergence_strictly_lower() {
    let _l = test_lock();
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.rounds = 6;
    cfg.correction_steps = 5;

    let mean_divergence = |cfg: &ExperimentConfig| -> f64 {
        obs::monitor::reset();
        obs::monitor::set_enabled(true);
        let _ = run_with(cfg, &rt);
        obs::monitor::set_enabled(false);
        let hist = obs::monitor::divergence_history();
        assert_eq!(hist.len(), cfg.rounds, "one divergence sample per round");
        assert!(hist.iter().all(|d| d.max >= d.mean && d.mean >= 0.0));
        hist.iter().map(|d| d.mean).sum::<f64>() / hist.len() as f64
    };

    let corrected = mean_divergence(&cfg);
    let mut plain = cfg.clone();
    plain.correction_steps = 0;
    let uncorrected = mean_divergence(&plain);
    obs::monitor::reset();

    assert!(
        corrected > 0.0 && uncorrected > 0.0,
        "partitioned workers must actually drift apart \
         (corrected {corrected}, uncorrected {uncorrected})"
    );
    assert!(
        corrected < uncorrected,
        "corrections did not reduce cross-worker divergence: \
         {corrected} (rho > 0) vs {uncorrected} (rho = 0)"
    );
}
