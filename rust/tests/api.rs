//! Integration tests for the `llcg::api` layer: builder validation against
//! the registries, session/event streaming, sweep dataset+partition reuse
//! (bit-parity with standalone runs), and the single-source config schema.

use std::sync::Arc;

use llcg::api::{keys, registry, Event, ExperimentBuilder, Sweep};
use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::runtime::Runtime;

/// Native-backend runtime (fast, no artifacts needed; manifest generated
/// under `target/`).
fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 4;
    cfg.rounds = 3;
    cfg.schedule = Schedule::Fixed { k: 2 };
    cfg.correction_steps = 1;
    cfg.eval_every = 2;
    cfg.eval_max_nodes = 64;
    cfg.seed = 11;
    cfg
}

// ---------------------------------------------------------------------------
// sweep reuse vs standalone runs
// ---------------------------------------------------------------------------

#[test]
fn sweep_points_match_standalone_runs_bit_for_bit() {
    // the sweep shares one loaded dataset + one partition assignment
    // across its points; every point must still equal a from-scratch
    // `run_experiment` exactly
    let rt = native_rt();
    let base = base_cfg();
    let algos = ["psgd-pa", "llcg"];
    let results = Sweep::over(&base, "algorithm", &algos)
        .run(&rt, |_i, _exp, _res| {})
        .unwrap();
    assert_eq!(results.len(), algos.len());
    for (i, alg) in algos.iter().enumerate() {
        let mut cfg = base.clone();
        cfg.apply_override("algorithm", alg).unwrap();
        let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
        let direct = driver::run_experiment(&cfg, &ds, &rt).unwrap();
        assert_eq!(direct.records.len(), results[i].records.len(), "{alg}");
        for (ra, rb) in direct.records.iter().zip(&results[i].records) {
            assert_eq!(
                ra.local_loss.to_bits(),
                rb.local_loss.to_bits(),
                "{alg} round {}",
                ra.round
            );
            assert_eq!(ra.val_score.to_bits(), rb.val_score.to_bits(), "{alg}");
            assert_eq!(ra.comm.total(), rb.comm.total(), "{alg}");
        }
        assert_eq!(direct.final_val.to_bits(), results[i].final_val.to_bits());
        assert_eq!(direct.final_test.to_bits(), results[i].final_test.to_bits());
        assert_eq!(direct.cut_ratio.to_bits(), results[i].cut_ratio.to_bits());
    }
}

#[test]
fn sweep_cross_covers_the_grid_in_order() {
    let rt = native_rt();
    let mut base = base_cfg();
    base.rounds = 1;
    base.eval_every = 1;
    let sweep = Sweep::over(&base, "parts", &[2usize, 4]).cross("local_steps", &[1usize, 2]);
    assert_eq!(sweep.len(), 4);
    let mut seen = Vec::new();
    sweep
        .run(&rt, |i, exp, res| {
            seen.push((i, exp.config().parts, res.records[0].local_steps));
        })
        .unwrap();
    assert_eq!(seen, vec![(0, 2, 1), (1, 2, 2), (2, 4, 1), (3, 4, 2)]);
}

// ---------------------------------------------------------------------------
// session API shape
// ---------------------------------------------------------------------------

#[test]
fn event_stream_is_ordered_and_complete() {
    let rt = native_rt();
    let exp = ExperimentBuilder::from_config(base_cfg()).build().unwrap();
    let mut kinds: Vec<&'static str> = Vec::new();
    let mut last_round = 0usize;
    let result = exp
        .launch(&rt)
        .stream(|ev| {
            kinds.push(ev.kind());
            if let Event::RoundCompleted(r) = ev {
                assert_eq!(r.round, last_round + 1, "rounds complete in order");
                last_round = r.round;
            }
        })
        .unwrap();
    assert_eq!(last_round, 3);
    assert_eq!(result.records.len(), 3);
    assert_eq!(kinds.first(), Some(&"round_started"));
    assert_eq!(kinds.last(), Some(&"finished"));
    assert_eq!(
        kinds.iter().filter(|&&k| k == "round_completed").count(),
        3
    );
    // llcg corrects every round; eval fires on rounds 2 and 3 (cadence +
    // final round)
    assert_eq!(
        kinds.iter().filter(|&&k| k == "correction_applied").count(),
        3
    );
    assert_eq!(
        kinds.iter().filter(|&&k| k == "eval_completed").count(),
        2
    );
    // one worker completion per (worker, round)
    let cfg = base_cfg();
    assert_eq!(
        kinds
            .iter()
            .filter(|&&k| k == "worker_round_completed")
            .count(),
        cfg.parts * 3
    );
}

#[test]
fn run_experiment_wrapper_matches_the_session_api() {
    // the legacy entry point is a thin wrapper over the session machinery
    // and must produce identical numbers
    let rt = native_rt();
    let cfg = base_cfg();
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    let legacy = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    let session = ExperimentBuilder::from_config(cfg)
        .with_dataset(Arc::new(ds))
        .build()
        .unwrap()
        .launch(&rt)
        .finish()
        .unwrap();
    assert_eq!(legacy.final_val.to_bits(), session.final_val.to_bits());
    assert_eq!(legacy.final_test.to_bits(), session.final_test.to_bits());
    for (ra, rb) in legacy.records.iter().zip(&session.records) {
        assert_eq!(ra.local_loss.to_bits(), rb.local_loss.to_bits());
    }
}

// ---------------------------------------------------------------------------
// schema-driven CLI surface
// ---------------------------------------------------------------------------

#[test]
fn schema_covers_every_config_knob_and_help_lists_them() {
    // typo'd keys name the full table; the help text is generated from it
    let mut cfg = ExperimentConfig::default();
    let err = cfg.apply_override("foo", "bar").unwrap_err();
    for name in keys::key_names() {
        assert!(err.contains(name), "unknown-key error misses {name}");
        assert!(
            keys::help_table().contains(&name.replace('_', "-")),
            "help table misses {name}"
        );
    }
    // strict booleans on the CLI path (satellite: no silent false)
    assert!(cfg.apply_override("correction_full_neighbors", "TRUE").is_err());
    assert!(cfg.apply_override("correction-full-neighbors", "1").is_ok());
    assert!(cfg.correction_full_neighbors);
}

#[test]
fn builder_rejects_unknown_names_with_registry_lists() {
    let err = ExperimentBuilder::new()
        .dataset("ogbn-papers100M")
        .build()
        .err()
        .unwrap();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown dataset"), "{msg}");
    for name in registry::with(|r| r.dataset_names()) {
        assert!(msg.contains(&name), "error misses registered dataset {name}");
    }
}
