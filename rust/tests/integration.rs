//! Integration tests over the full stack: runtime + sampler + coordinator.
//!
//! Runs against the AOT/PJRT artifacts (`make artifacts`) when they are
//! present and loadable; otherwise against the generated native-backend
//! manifest — same tests, same assertions, no skipping.
//!
//! Kept on the `tiny` shape config so the whole file runs in seconds.

use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::metrics;
use llcg::runtime::{ModelState, Runtime};
use llcg::sampler::{BlockBuilder, Fanout};
use llcg::util::Pcg64;

fn artifacts_dir() -> String {
    // tests run from the crate root; prefers artifacts/, falls back to the
    // native manifest under target/
    let (_rt, dir) = Runtime::load_or_native("artifacts")
        .expect("no runtime backend available (neither artifacts nor native)");
    dir
}

fn tiny_setup() -> (llcg::graph::Dataset, Runtime) {
    let ds = generators::by_name("tiny", 0).unwrap();
    let rt = Runtime::load(artifacts_dir()).unwrap();
    (ds, rt)
}

fn builder_for(rt: &Runtime, name: &str) -> BlockBuilder {
    let meta = rt.meta(name).unwrap();
    BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        meta.multilabel(),
    )
}

// ---------------------------------------------------------------------------
// runtime-level
// ---------------------------------------------------------------------------
#[test]
fn train_step_decreases_loss_on_repeated_batch() {
    let (ds, rt) = tiny_setup();
    let name = "gcn_sgd_tiny";
    let meta = rt.meta(name).unwrap().clone();
    let mut rng = Pcg64::new(1);
    let mut state = ModelState::init(&meta, &mut rng);
    let bb = builder_for(&rt, name);
    let targets: Vec<u32> = ds.splits.train[..meta.dims.b].to_vec();
    let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
    let first = rt.train_step(name, &mut state, &blk, 0.1).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = rt.train_step(name, &mut state, &blk, 0.1).unwrap();
    }
    assert!(
        last < first * 0.8,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn adam_step_threads_time_counter() {
    let (ds, rt) = tiny_setup();
    let name = "gcn_adam_tiny";
    let meta = rt.meta(name).unwrap().clone();
    let mut rng = Pcg64::new(2);
    let mut state = ModelState::init(&meta, &mut rng);
    assert_eq!(state.opt.len(), 2 * state.params.len() + 1);
    let bb = builder_for(&rt, name);
    let targets: Vec<u32> = ds.splits.train[..4].to_vec();
    let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
    for i in 1..=3 {
        rt.train_step(name, &mut state, &blk, 0.01).unwrap();
        let t = state.opt.last().unwrap().data[0];
        assert_eq!(t, i as f32, "adam t counter wrong after step {i}");
    }
}

#[test]
fn eval_step_returns_logits_and_is_deterministic() {
    let (ds, rt) = tiny_setup();
    let train = rt.meta("gcn_sgd_tiny").unwrap().clone();
    let mut rng = Pcg64::new(3);
    let state = ModelState::init(&train, &mut rng);
    let bb = builder_for(&rt, "gcn_eval_tiny");
    let targets: Vec<u32> = (0..8).collect();
    let mut rng_a = Pcg64::new(7);
    let mut rng_b = Pcg64::new(7);
    let blk_a = bb.build(&targets, &ds.graph, &ds, &mut rng_a);
    let blk_b = bb.build(&targets, &ds.graph, &ds, &mut rng_b);
    let la = rt.eval_step("gcn_eval_tiny", &state.params, &blk_a).unwrap();
    let lb = rt.eval_step("gcn_eval_tiny", &state.params, &blk_b).unwrap();
    assert_eq!(la.len(), 8 * train.dims.c);
    assert_eq!(la, lb, "same seed must give identical logits");
    assert!(la.iter().all(|x| x.is_finite()));
}

#[test]
fn sgd_matches_manual_update_direction() {
    // after one sgd step with small lr, params move; with lr=0 they don't
    let (ds, rt) = tiny_setup();
    let name = "gcn_sgd_tiny";
    let meta = rt.meta(name).unwrap().clone();
    let mut rng = Pcg64::new(4);
    let state0 = ModelState::init(&meta, &mut rng);
    let bb = builder_for(&rt, name);
    let targets: Vec<u32> = ds.splits.train[..8].to_vec();
    let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);

    let mut state_zero = state0.clone();
    rt.train_step(name, &mut state_zero, &blk, 0.0).unwrap();
    for (a, b) in state_zero.params.iter().zip(&state0.params) {
        assert_eq!(a.data, b.data, "lr=0 must be a no-op on params");
    }

    let mut state_step = state0.clone();
    rt.train_step(name, &mut state_step, &blk, 0.1).unwrap();
    let moved: f64 = state_step
        .params
        .iter()
        .zip(&state0.params)
        .map(|(a, b)| {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| ((x - y) as f64).abs())
                .sum::<f64>()
        })
        .sum();
    assert!(moved > 0.0, "lr=0.1 must move params");
}

#[test]
fn all_tiny_archs_run() {
    let (ds, rt) = tiny_setup();
    let mut rng = Pcg64::new(5);
    for arch in ["gcn", "sage", "mlp"] {
        let name = format!("{arch}_adam_tiny");
        let meta = rt.meta(&name).unwrap().clone();
        let mut state = ModelState::init(&meta, &mut rng);
        let bb = builder_for(&rt, &name);
        let targets: Vec<u32> = ds.splits.train[..8].to_vec();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        let loss = rt.train_step(&name, &mut state, &blk, 0.01).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "{arch}: bad loss {loss}");
    }
}

// ---------------------------------------------------------------------------
// device-resident path parity (tentpole invariant: residency is a pure
// optimization — both paths must produce the same training trajectory)
// ---------------------------------------------------------------------------
#[test]
fn device_resident_matches_literal_path() {
    let (ds, rt) = tiny_setup();
    for name in ["gcn_sgd_tiny", "gcn_adam_tiny", "sage_adam_tiny"] {
        let meta = rt.meta(name).unwrap().clone();
        let mut rng = Pcg64::new(31);
        let init = ModelState::init(&meta, &mut rng);
        let bb = builder_for(&rt, name);
        // distinct blocks replayed in the same order on both paths
        let mut brng = Pcg64::new(33);
        let blocks: Vec<_> = (0..4)
            .map(|i| {
                let lo = i * meta.dims.b;
                let targets: Vec<u32> = ds.splits.train[lo..lo + meta.dims.b].to_vec();
                bb.build(&targets, &ds.graph, &ds, &mut brng)
            })
            .collect();

        // literal path: full host round-trip per step
        let mut lit = init.clone();
        let mut lit_losses = Vec::new();
        for s in 0..12 {
            lit_losses.push(rt.train_step(name, &mut lit, &blocks[s % 4], 0.05).unwrap());
        }

        // device-resident path: upload once, 12 steps, download once
        let mut res = init.clone();
        let mut dev = rt.upload(name, &res).unwrap();
        let mut res_losses = Vec::new();
        for s in 0..12 {
            res_losses.push(rt.train_step_device(&mut dev, &blocks[s % 4], 0.05).unwrap());
        }
        assert_eq!(dev.steps(), 12);
        rt.download_into(&dev, &mut res).unwrap();

        for (i, (a, b)) in lit_losses.iter().zip(&res_losses).enumerate() {
            assert!(
                (a - b).abs() <= 1e-6,
                "{name}: loss diverged at step {i}: {a} vs {b}"
            );
        }
        for (ti, (a, b)) in lit.params.iter().zip(&res.params).enumerate() {
            assert_eq!(a.shape, b.shape);
            for (j, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-6,
                    "{name}: param tensor {ti} elem {j}: {x} vs {y}"
                );
            }
        }
        for (ti, (a, b)) in lit.opt.iter().zip(&res.opt).enumerate() {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!((x - y).abs() <= 1e-6, "{name}: opt tensor {ti}: {x} vs {y}");
            }
        }
    }
}

#[test]
fn device_resident_eval_matches_literal() {
    let (ds, rt) = tiny_setup();
    let train = rt.meta("gcn_sgd_tiny").unwrap().clone();
    let mut rng = Pcg64::new(41);
    let state = ModelState::init(&train, &mut rng);
    let bb = builder_for(&rt, "gcn_eval_tiny");
    let targets: Vec<u32> = (0..8).collect();
    let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
    let lit = rt.eval_step("gcn_eval_tiny", &state.params, &blk).unwrap();
    let mut dev = rt.upload_params("gcn_eval_tiny", &state.params).unwrap();
    let res = rt.eval_step_device(&mut dev, &blk).unwrap();
    assert_eq!(lit, res, "resident eval logits must match literal path");
}

#[test]
fn device_state_rejects_wrong_artifact_kind() {
    let (_ds, rt) = tiny_setup();
    let meta = rt.meta("gcn_adam_tiny").unwrap().clone();
    let mut rng = Pcg64::new(43);
    let state = ModelState::init(&meta, &mut rng);
    // eval upload with a full train state (opt tensors) is fine param-wise...
    let dev = rt.upload_params("gcn_eval_tiny", &state.params).unwrap();
    // ...but training on an eval artifact must fail
    let ds = generators::by_name("tiny", 0).unwrap();
    let bb = builder_for(&rt, "gcn_eval_tiny");
    let blk = bb.build(&[0, 1, 2], &ds.graph, &ds, &mut rng);
    let mut dev2 = dev;
    assert!(rt.train_step_device(&mut dev2, &blk, 0.01).is_err());
    // and uploading mismatched param counts must fail
    assert!(rt.upload_params("sage_adam_tiny", &state.params).is_err());
}

// ---------------------------------------------------------------------------
// coordinator-level
// ---------------------------------------------------------------------------
fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.parts = 4;
    cfg.rounds = 6;
    cfg.schedule = Schedule::Fixed { k: 3 };
    cfg.eval_max_nodes = 64;
    cfg.artifacts_dir = artifacts_dir();
    cfg
}

#[test]
fn llcg_learns_on_tiny() {
    let cfg = base_cfg();
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    assert_eq!(res.records.len(), 6);
    let first_loss = res.records[0].global_loss;
    let last_loss = res.records.last().unwrap().global_loss;
    assert!(last_loss < first_loss, "{first_loss} -> {last_loss}");
    assert!(res.final_val > 0.4, "val {}", res.final_val);
}

#[test]
fn runs_are_deterministic_given_seed() {
    let cfg = base_cfg();
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let a = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    let b = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.local_loss, rb.local_loss);
        assert_eq!(ra.val_score, rb.val_score);
    }
    assert_eq!(a.final_test, b.final_test);
}

#[test]
fn comm_accounting_psgd_vs_ggs() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::PsgdPa;
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let psgd = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    cfg.algorithm = Algorithm::Ggs;
    let ggs = driver::run_experiment(&cfg, &ds, &rt).unwrap();

    // PSGD-PA: bytes = 2 * P * |θ| per round, zero feature bytes
    let meta = rt.meta("gcn_adam_tiny").unwrap();
    let expected = 2 * cfg.parts as u64 * meta.param_bytes();
    for r in &psgd.records {
        assert_eq!(r.comm.feature_bytes, 0);
        assert_eq!(r.comm.down_bytes + r.comm.up_bytes, expected);
    }
    // GGS moves strictly more bytes (features on top of params)
    assert!(ggs.avg_round_bytes > psgd.avg_round_bytes);
    assert!(ggs.records.iter().any(|r| r.comm.feature_bytes > 0));
}

#[test]
fn llcg_comm_equals_psgd_comm() {
    // the headline claim: LLCG costs the same bytes per round as PSGD-PA
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::PsgdPa;
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let psgd = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    cfg.algorithm = Algorithm::Llcg;
    cfg.correction_steps = 2;
    let llcg = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    assert_eq!(
        psgd.records[0].comm.total(),
        llcg.records[0].comm.total(),
        "server correction must add no communication"
    );
}

#[test]
fn fullsync_runs_one_step_per_round() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::FullSync;
    cfg.schedule = Schedule::Fixed { k: 7 }; // must be ignored
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    assert!(res.records.iter().all(|r| r.local_steps == 1));
}

#[test]
fn subgraph_approx_storage_counted_once() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::SubgraphApprox;
    cfg.approx_storage = 0.1;
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    assert!(res.records[0].comm.feature_bytes > 0, "storage not counted");
    for r in &res.records[1..] {
        assert_eq!(r.comm.feature_bytes, 0, "storage counted more than once");
    }
}

#[test]
fn exponential_schedule_reduces_rounds_for_same_steps() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::Llcg;
    cfg.schedule = Schedule::Exponential { k0: 2, rho: 1.5 };
    cfg.rounds = 8;
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    let steps: Vec<usize> = res.records.iter().map(|r| r.local_steps).collect();
    assert!(steps.windows(2).all(|w| w[1] >= w[0]), "{steps:?}");
    assert!(*steps.last().unwrap() > steps[0]);
}

#[test]
fn single_machine_equals_parts_one() {
    let mut cfg = base_cfg();
    cfg.parts = 1;
    cfg.algorithm = Algorithm::PsgdPa;
    let ds = generators::by_name("tiny", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    assert_eq!(res.cut_ratio, 0.0);
    assert!(res.final_val > 0.4);
}

#[test]
fn multilabel_pipeline_runs() {
    // proteins-s artifacts may be absent in a tiny-only build; guard.
    let rt = Runtime::load(artifacts_dir()).unwrap();
    if rt.meta("gcn_adam_proteins-s").is_err() {
        eprintln!("skipping: proteins-s artifacts not built");
        return;
    }
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "proteins-s".into();
    cfg.arch = "gcn".into();
    cfg.parts = 2;
    cfg.rounds = 2;
    cfg.schedule = Schedule::Fixed { k: 2 };
    cfg.eval_max_nodes = 64;
    cfg.artifacts_dir = artifacts_dir();
    let ds = generators::by_name("proteins-s", cfg.seed).unwrap();
    let rt = Runtime::load(&cfg.artifacts_dir).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    assert!(res.final_val.is_finite());
}

// ---------------------------------------------------------------------------
// metrics consistency through the full path
// ---------------------------------------------------------------------------
#[test]
fn eval_logits_chunking_consistent() {
    let (ds, rt) = tiny_setup();
    let meta = rt.meta("gcn_sgd_tiny").unwrap().clone();
    let mut rng = Pcg64::new(11);
    let state = ModelState::init(&meta, &mut rng);
    let mut bb = builder_for(&rt, "gcn_eval_tiny");
    bb.fanout = Fanout::Full;
    let ids: Vec<u32> = (0..19).collect(); // 2 full chunks + remainder
    let logits = driver::eval_logits(
        &rt,
        "gcn_eval_tiny",
        &state.params,
        &ds,
        &ids,
        &bb,
        &mut Pcg64::new(1),
    )
    .unwrap();
    assert_eq!(logits.len(), 19 * meta.dims.c);
    let f1 = metrics::micro_f1(&logits, meta.dims.c, &ds.labels, &ids);
    assert!((0.0..=1.0).contains(&f1));
}
