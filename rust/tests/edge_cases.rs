//! Edge-case and failure-injection tests across modules: degenerate graphs,
//! empty parts, short batches, malformed configs/manifests, metric corner
//! cases, and the gradient-extraction path.

use llcg::config::ExperimentConfig;
use llcg::coordinator::{discrepancy, driver, Algorithm, Schedule};
use llcg::graph::{generators, CsrGraph, Dataset, Labels, Splits};
use llcg::metrics;
use llcg::partition::{self, Partitioner};
use llcg::runtime::{ModelState, Runtime};
use llcg::sampler::{BlockBuilder, EMPTY};
use llcg::util::{Json, Pcg64};

fn artifacts() -> Option<Runtime> {
    // PJRT artifacts when available, else the generated native manifest —
    // these tests run in every environment
    Runtime::load_or_native("artifacts").ok().map(|(rt, _)| rt)
}

// ---------------------------------------------------------------------------
// degenerate graphs
// ---------------------------------------------------------------------------
#[test]
fn edgeless_graph_everything_still_works() {
    let g = CsrGraph::from_edges(10, &[]);
    assert_eq!(g.num_edges(), 0);
    assert_eq!(g.avg_degree(), 0.0);
    let mut rng = Pcg64::new(1);
    for name in ["random", "bfs", "ldg", "metis"] {
        let a = partition::by_name(name).unwrap().partition(&g, 3, &mut rng);
        assert_eq!(a.len(), 10);
        assert_eq!(g.edge_cut(&a), 0);
    }
}

#[test]
fn isolated_node_gets_self_only_block() {
    let g = CsrGraph::from_edges(3, &[(0, 1)]); // node 2 isolated
    let ds = Dataset {
        name: "iso".into(),
        graph: g,
        features: vec![1.0; 3 * 2],
        d: 2,
        labels: Labels::MultiClass(vec![0, 1, 0]),
        splits: Splits {
            train: vec![0, 1, 2],
            val: vec![],
            test: vec![],
        },
    };
    let bb = BlockBuilder::new(2, 3, 3, 2, 2, false);
    let mut rng = Pcg64::new(2);
    let blk = bb.build(&[2], &ds.graph, &ds, &mut rng);
    // slot 0 = self, all neighbor slots EMPTY, row still normalized (1 slot)
    assert_eq!(blk.nodes_l1[0], 2);
    assert_eq!(&blk.nodes_l1[1..3], &[EMPTY, EMPTY]);
    let row: f32 = blk.a1[..blk.n1].iter().sum();
    assert!((row - 1.0).abs() < 1e-6);
}

#[test]
fn star_graph_partitioners_terminate() {
    // pathological for heavy-edge matching: one hub
    let edges: Vec<(u32, u32)> = (1..500u32).map(|v| (0, v)).collect();
    let g = CsrGraph::from_edges(500, &edges);
    let mut rng = Pcg64::new(3);
    let a = partition::by_name("metis").unwrap().partition(&g, 4, &mut rng);
    assert_eq!(a.len(), 500);
}

// ---------------------------------------------------------------------------
// empty / skewed parts in the driver
// ---------------------------------------------------------------------------
#[test]
fn run_with_more_parts_than_train_clusters() {
    // tiny has 150 train nodes; P=32 leaves some parts nearly empty —
    // the round loop must survive empty-part workers.
    let Some(rt) = artifacts() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.parts = 32;
    cfg.rounds = 2;
    cfg.schedule = Schedule::Fixed { k: 1 };
    cfg.eval_max_nodes = 32;
    let ds = generators::by_name("tiny", 0).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    assert_eq!(res.records.len(), 2);
    assert!(res.final_val.is_finite());
}

#[test]
fn batch_larger_than_worker_train_set_is_padded() {
    let Some(rt) = artifacts() else { return };
    let meta = rt.meta("gcn_sgd_tiny").unwrap().clone();
    let ds = generators::by_name("tiny", 0).unwrap();
    let bb = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        false,
    );
    let mut rng = Pcg64::new(4);
    let mut state = ModelState::init(&meta, &mut rng);
    let blk = bb.build(&[3], &ds.graph, &ds, &mut rng); // 1 of 8 slots real
    let loss = rt.train_step("gcn_sgd_tiny", &mut state, &blk, 0.1).unwrap();
    assert!(loss.is_finite());
}

// ---------------------------------------------------------------------------
// config / manifest failure injection
// ---------------------------------------------------------------------------
#[test]
fn config_rejects_bad_values() {
    let bad = [
        r#"{"algorithm": "warp-drive"}"#,
        r#"{"correction_batch": "sideways"}"#,
        r#"{"parts": "eight"}"#,
        r#"{"lr": true}"#,
        r#"{"no_such_key": 1}"#,
    ];
    for b in bad {
        let j = Json::parse(b).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {b}");
    }
}

#[test]
fn runtime_load_missing_dir_fails_with_hint() {
    let msg = match Runtime::load("/nonexistent/path") {
        Ok(_) => panic!("load of missing dir should fail"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn runtime_unknown_artifact_fails() {
    let Some(rt) = artifacts() else { return };
    assert!(rt.meta("no_such_artifact").is_err());
}

#[test]
fn driver_rejects_dataset_artifact_mismatch() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    // dataset generated with different feature dim than the artifact
    let mut ds = generators::by_name("tiny", 0).unwrap();
    ds.d = 8;
    ds.features.truncate(ds.n() * 8);
    assert!(driver::run_experiment(&cfg, &ds, &rt).is_err());
}

// ---------------------------------------------------------------------------
// metrics corner cases
// ---------------------------------------------------------------------------
#[test]
fn metrics_empty_ids() {
    let labels = Labels::MultiClass(vec![0, 1]);
    assert_eq!(metrics::micro_f1(&[], 2, &labels, &[]), 0.0);
    assert_eq!(metrics::roc_auc(&[], 2, &labels, &[]), 0.0);
    assert_eq!(metrics::mean_loss(&[], 2, &labels, &[]), 0.0);
}

#[test]
fn auc_skips_single_class_columns() {
    // class 1 has no positives among ids -> skipped, not NaN
    let labels = Labels::MultiClass(vec![0, 0, 0]);
    let logits = vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0];
    let auc = metrics::roc_auc(&logits, 2, &labels, &[0, 1, 2]);
    assert!(auc.is_finite());
}

#[test]
fn multilabel_f1_all_negative_predictions() {
    let labels = Labels::MultiLabel {
        data: vec![0.0, 0.0, 0.0, 0.0],
        c: 2,
    };
    let logits = vec![-5.0, -5.0, -5.0, -5.0];
    // no positives anywhere -> define 0.0, not NaN
    let f1 = metrics::micro_f1(&logits, 2, &labels, &[0, 1]);
    assert_eq!(f1, 0.0);
}

// ---------------------------------------------------------------------------
// gradient extraction / discrepancy
// ---------------------------------------------------------------------------
#[test]
fn gradient_extraction_is_finite_and_nonzero() {
    let Some(rt) = artifacts() else { return };
    let ds = generators::by_name("tiny", 0).unwrap();
    let meta = rt.meta("gcn_sgd_tiny").unwrap().clone();
    let mut rng = Pcg64::new(5);
    let params = ModelState::init(&meta, &mut rng).params;
    let bb = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        false,
    );
    let g = discrepancy::estimate_gradient(
        &rt,
        "gcn_sgd_tiny",
        &params,
        &ds,
        &ds.graph,
        &ds.splits.train,
        &bb,
        2,
        &mut rng,
    )
    .unwrap();
    assert!(g.iter().all(|x| x.is_finite()));
    let norm: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
    assert!(norm > 0.0, "zero gradient at init");
}

#[test]
fn discrepancy_kappa_larger_for_worse_partitions() {
    let Some(rt) = artifacts() else { return };
    if rt.meta("gcn_sgd_tiny-hetero").is_err() {
        eprintln!("skipping: tiny-hetero artifacts not built");
        return;
    }
    let ds = generators::by_name("tiny-hetero", 0).unwrap();
    let meta = rt.meta("gcn_sgd_tiny-hetero").unwrap().clone();
    let mut rng = Pcg64::new(6);
    let params = ModelState::init(&meta, &mut rng).params;
    let assign_metis = partition::by_name("metis")
        .unwrap()
        .partition(&ds.graph, 4, &mut rng);
    let d = discrepancy::measure(
        &rt,
        "gcn",
        "tiny-hetero",
        &params,
        &ds,
        &assign_metis,
        4,
        3,
        7,
    )
    .unwrap();
    assert!(d.kappa_a >= 0.0 && d.kappa_x >= 0.0 && d.sigma_bias >= 0.0);
    assert!(d.kappa() > 0.0, "decoupled dataset must have nonzero kappa");
}

// ---------------------------------------------------------------------------
// run-result serialization
// ---------------------------------------------------------------------------
#[test]
fn run_result_json_roundtrips() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.rounds = 2;
    cfg.schedule = Schedule::Fixed { k: 1 };
    cfg.eval_max_nodes = 16;
    cfg.algorithm = Algorithm::PsgdPa;
    let ds = generators::by_name("tiny", 0).unwrap();
    let res = driver::run_experiment(&cfg, &ds, &rt).unwrap();
    let j = res.to_json();
    let parsed = Json::parse(&j.to_string_pretty()).unwrap();
    assert_eq!(parsed.req("algorithm").as_str(), Some("psgd-pa"));
    assert_eq!(
        parsed.req("rounds").as_array().map(|a| a.len()),
        Some(2usize)
    );
}
