//! Remote-transport tests: real `llcg worker` OS processes over TCP and
//! unix-domain sockets must reproduce the sequential driver bit-for-bit in
//! sync mode (losses, eval scores, comm accounting, and the published
//! serving snapshots), survive a SIGKILLed worker through the respawn
//! path, and checkpoint/resume exactly under async staleness.
//!
//! Every test spawns worker processes from this build's own `llcg` binary
//! (via `LLCG_WORKER_EXE` — `current_exe()` inside the test harness would
//! name the harness, not the CLI). Always native-backend, like the
//! in-process cluster tests.

use llcg::api::ExperimentBuilder;
use llcg::cluster::{Engine, RoundMode};
use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::runtime::Runtime;
use llcg::serve::SnapshotHub;

/// Point worker spawns at this build's `llcg` binary (idempotent; every
/// test sets the same value, so the once-guard only avoids redundant
/// `setenv` calls from parallel tests).
fn point_worker_exe_at_this_build() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| std::env::set_var("LLCG_WORKER_EXE", env!("CARGO_BIN_EXE_llcg")));
}

/// A native-backend runtime (worker processes rebuild it from the same
/// artifacts dir, which `base_cfg` pins to this path).
fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 4;
    cfg.rounds = 4;
    cfg.schedule = Schedule::Fixed { k: 3 };
    cfg.correction_steps = 2;
    cfg.eval_every = 2;
    cfg.eval_max_nodes = 64;
    cfg.seed = 7;
    // worker processes re-derive the runtime from the config, so the config
    // must name the same artifacts the test's server runtime loads
    cfg.artifacts_dir = "target/native-artifacts".into();
    cfg
}

fn run_with(cfg: &ExperimentConfig, rt: &Runtime) -> driver::RunResult {
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    driver::run_experiment(cfg, &ds, rt).unwrap()
}

fn bits(data: &[f32]) -> Vec<u32> {
    data.iter().map(|f| f.to_bits()).collect()
}

/// Sync mode over a real socket must be indistinguishable — to the bit —
/// from the sequential engine, while the measured wire counters prove the
/// params actually crossed a socket.
fn assert_remote_matches_sequential(spec: &str, rt: &Runtime) {
    let mut seq_cfg = base_cfg();
    // a non-ideal (but non-sleeping) modeled net also checks the modeled
    // time stays engine- and transport-independent
    seq_cfg.net = "lan".into();
    let mut rem_cfg = seq_cfg.clone();
    rem_cfg.engine = Engine::Cluster;
    rem_cfg.transport = spec.into();

    let a = run_with(&seq_cfg, rt);
    let b = run_with(&rem_cfg, rt);
    assert_eq!(a.transport, "inprocess");
    assert_eq!(b.transport, spec);
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.local_steps, rb.local_steps);
        assert_eq!(
            ra.local_loss.to_bits(),
            rb.local_loss.to_bits(),
            "round {}: local loss {} vs {} over {spec}",
            ra.round,
            ra.local_loss,
            rb.local_loss
        );
        assert_eq!(
            ra.global_loss.to_bits(),
            rb.global_loss.to_bits(),
            "round {}: global loss over {spec}",
            ra.round
        );
        assert_eq!(
            ra.val_score.to_bits(),
            rb.val_score.to_bits(),
            "round {}: val over {spec}",
            ra.round
        );
        assert_eq!(ra.comm.down_bytes, rb.comm.down_bytes, "round {}", ra.round);
        assert_eq!(ra.comm.up_bytes, rb.comm.up_bytes, "round {}", ra.round);
        assert_eq!(
            ra.comm.feature_bytes, rb.comm.feature_bytes,
            "round {}",
            ra.round
        );
        assert_eq!(ra.cum_bytes, rb.cum_bytes, "round {}", ra.round);
        assert_eq!(
            ra.net_time_s.to_bits(),
            rb.net_time_s.to_bits(),
            "round {}: modeled net time must be transport-independent",
            ra.round
        );
        // the modeled accounting above is identical; the *measured* wire
        // bytes separate the transports: zero when no socket exists
        assert_eq!(ra.wire_bytes_down, 0, "round {}: sequential has no wire", ra.round);
        assert_eq!(ra.wire_bytes_up, 0, "round {}: sequential has no wire", ra.round);
        assert!(
            rb.wire_bytes_down > 0,
            "round {}: no measured broadcast bytes over {spec}",
            rb.round
        );
        assert!(
            rb.wire_bytes_up > 0,
            "round {}: no measured upload bytes over {spec}",
            rb.round
        );
    }
    assert_eq!(a.final_val.to_bits(), b.final_val.to_bits());
    assert_eq!(a.final_test.to_bits(), b.final_test.to_bits());
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.cut_ratio.to_bits(), b.cut_ratio.to_bits());
    assert_eq!(b.total_drops, 0);
    assert_eq!(b.total_respawns, 0);
}

#[test]
fn tcp_sync_matches_sequential_bit_for_bit() {
    point_worker_exe_at_this_build();
    let rt = native_rt();
    assert_remote_matches_sequential("tcp", &rt);
}

#[cfg(unix)]
#[test]
fn uds_sync_matches_sequential_bit_for_bit() {
    point_worker_exe_at_this_build();
    let rt = native_rt();
    assert_remote_matches_sequential("uds", &rt);
}

#[test]
fn tcp_publishes_the_same_serving_snapshots_as_sequential() {
    point_worker_exe_at_this_build();
    let rt = native_rt();
    let rounds = base_cfg().rounds;
    let mut published: Vec<Vec<Vec<u32>>> = Vec::new();
    for (engine, transport) in [(Engine::Sequential, "inprocess"), (Engine::Cluster, "tcp")] {
        let mut cfg = base_cfg();
        cfg.engine = engine;
        cfg.transport = transport.into();
        let exp = ExperimentBuilder::from_config(cfg).build().unwrap();
        let hub = SnapshotHub::new();
        exp.launch(&rt)
            .publish_to(hub.clone())
            .unwrap()
            .finish()
            .unwrap();
        assert_eq!(
            hub.version(),
            rounds as u64,
            "{transport}: one publish per round boundary"
        );
        let snap = hub.current().unwrap();
        assert_eq!(snap.round, rounds);
        published.push(snap.params.iter().map(|t| bits(&t.data)).collect());
    }
    // sync-mode bit parity extends to what a live server would actually see
    assert_eq!(
        published[0], published[1],
        "sequential and tcp-cluster runs published different snapshots"
    );
}

#[test]
fn sigkilled_worker_respawns_and_the_run_completes() {
    point_worker_exe_at_this_build();
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    // SIGKILL the real worker-1 process as round 2 is broadcast; the
    // supervisor must respawn a fresh process from the current global
    // params and finish every round
    cfg.transport = "tcp,kill=1@2".into();
    // trace the run: workers flush spans at every round boundary, so the
    // doomed process's round-1 telemetry must survive its SIGKILL
    llcg::obs::set_enabled(true);
    let _ = llcg::transport::take_remote_spans();
    let res = run_with(&cfg, &rt);
    llcg::obs::set_enabled(false);
    let remote = llcg::transport::take_remote_spans();
    let _ = llcg::obs::take_spans();
    assert!(
        remote.iter().any(|(track, spans)| track == "worker-1"
            && spans.iter().any(|s| s.name == "worker.round" && s.round == 1)),
        "round-1 spans from the SIGKILLed worker-1 process were lost (tracks: {:?})",
        remote.iter().map(|(t, s)| (t.as_str(), s.len())).collect::<Vec<_>>()
    );
    assert_eq!(res.transport, "tcp");
    assert_eq!(res.records.len(), cfg.rounds, "all rounds complete despite the kill");
    assert!(
        res.total_respawns >= 1,
        "the killed worker process never respawned"
    );
    assert_eq!(
        res.records.last().unwrap().quorum,
        cfg.parts,
        "full strength restored by the final round"
    );
    assert!(res.final_val.is_finite());
    assert!(res.final_test.is_finite());
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("llcg_transport_ckpt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn async_checkpoint_resume_is_bit_exact_over_tcp() {
    point_worker_exe_at_this_build();
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.transport = "tcp".into();
    cfg.round_mode = RoundMode::AsyncStaleness { tau: 1 };
    // one worker: async folds land in arrival order, so P = 1 is the
    // largest fleet whose stream is reproducible bit-for-bit across runs
    cfg.parts = 1;
    let full = run_with(&cfg, &rt);
    assert_eq!(full.records.len(), cfg.rounds);

    // the same run writing a mid-run checkpoint must not drift: the async
    // engine stalls admissions at the boundary instead of reordering work
    let dir = ckpt_dir("async_tcp");
    let mut ck_cfg = cfg.clone();
    ck_cfg.checkpoint_every = 2;
    ck_cfg.checkpoint_dir = dir.display().to_string();
    let with_ck = run_with(&ck_cfg, &rt);
    for (a, b) in full.records.iter().zip(&with_ck.records) {
        assert_eq!(
            a.local_loss.to_bits(),
            b.local_loss.to_bits(),
            "round {}: the checkpoint barrier perturbed the async run",
            a.round
        );
        assert_eq!(a.val_score.to_bits(), b.val_score.to_bits());
        assert_eq!(a.cum_bytes, b.cum_bytes);
    }
    assert!(dir.join("round_2").join("meta.json").is_file());

    // resuming from round 2 replays rounds 3..4 bit-for-bit, over a fresh
    // worker process restored from the checkpointed optimizer state
    let mut res_cfg = cfg.clone();
    res_cfg.resume = dir.join("round_2").display().to_string();
    let resumed = run_with(&res_cfg, &rt);
    assert_eq!(resumed.records.len(), 2, "rounds 3 and 4 remain");
    for (a, b) in full.records[2..].iter().zip(&resumed.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(
            a.local_loss.to_bits(),
            b.local_loss.to_bits(),
            "round {}: resume forked the async local stream",
            a.round
        );
        assert_eq!(
            a.val_score.to_bits(),
            b.val_score.to_bits(),
            "round {}: resume forked the eval stream",
            a.round
        );
    }
    assert_eq!(full.final_val.to_bits(), resumed.final_val.to_bits());
    assert_eq!(full.final_test.to_bits(), resumed.final_test.to_bits());

    // an async-written checkpoint carries barrier state the sync engine
    // cannot honor; it must refuse with a pointer at the right mode
    let mut sync_cfg = cfg.clone();
    sync_cfg.round_mode = RoundMode::Sync;
    sync_cfg.resume = dir.join("round_2").display().to_string();
    let ds = generators::by_name(&sync_cfg.dataset, sync_cfg.seed).unwrap();
    let err = driver::run_experiment(&sync_cfg, &ds, &rt).unwrap_err();
    assert!(
        format!("{err:#}").contains("async"),
        "wrong refusal for a sync resume of an async checkpoint: {err:#}"
    );
}
