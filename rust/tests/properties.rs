//! Property-based tests over coordinator invariants (routing of nodes to
//! partitions, batching, block/state structure), driven by the in-repo
//! `testkit` harness (proptest substitute — DESIGN.md §Substitutions).
//!
//! These are pure-Rust properties: no PJRT artifacts required.

use llcg::graph::{CsrGraph, Dataset, Labels, Splits};
use llcg::partition::{self, Partitioner};
use llcg::runtime::{ModelState, Tensor};
use llcg::sampler::{BatchIter, BlockBuilder, EMPTY};
use llcg::testkit::{check, GraphCase, GraphStrategy, Pair, UsizeRange};
use llcg::util::Pcg64;

fn graph_of(case: &GraphCase) -> CsrGraph {
    CsrGraph::from_edges(case.n, &case.edges)
}

fn dataset_of(g: CsrGraph, d: usize, c: usize, seed: u64) -> Dataset {
    let n = g.n;
    let mut rng = Pcg64::new(seed);
    let features = (0..n * d).map(|_| rng.normal_f32()).collect();
    let labels = Labels::MultiClass(
        (0..n).map(|_| rng.gen_range(c as u64) as u16).collect(),
    );
    let splits = Splits::random(n, 0.6, 0.2, &mut rng);
    Dataset {
        name: "prop".into(),
        graph: g,
        features,
        d,
        labels,
        splits,
    }
}

// ---------------------------------------------------------------------------
// graph invariants
// ---------------------------------------------------------------------------
#[test]
fn prop_csr_is_symmetric_and_deduped() {
    let strat = GraphStrategy {
        max_n: 60,
        max_extra_edges: 200,
    };
    check(101, 60, &strat, |case| {
        let g = graph_of(case);
        for v in 0..g.n as u32 {
            let nbrs = g.neighbors(v);
            // sorted + deduped
            if nbrs.windows(2).any(|w| w[0] >= w[1]) {
                return false;
            }
            // symmetric, no self-loops
            if nbrs.iter().any(|&u| u == v) {
                return false;
            }
            if !nbrs.iter().all(|&u| g.neighbors(u).contains(&v)) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_edge_cut_bounded_by_total_edges() {
    let strat = Pair(
        GraphStrategy {
            max_n: 50,
            max_extra_edges: 120,
        },
        UsizeRange(1, 6),
    );
    check(102, 50, &strat, |(case, parts)| {
        let g = graph_of(case);
        let mut rng = Pcg64::new(7);
        let a = partition::RandomPartitioner.partition(&g, *parts, &mut rng);
        g.edge_cut(&a) <= g.num_edges()
    });
}

#[test]
fn prop_induced_views_partition_the_edge_set() {
    // sum over parts of induced edges + 2*cut == total directed edges
    let strat = Pair(
        GraphStrategy {
            max_n: 40,
            max_extra_edges: 100,
        },
        UsizeRange(1, 5),
    );
    check(103, 50, &strat, |(case, parts)| {
        let g = graph_of(case);
        let mut rng = Pcg64::new(11);
        let a = partition::LdgPartitioner.partition(&g, *parts, &mut rng);
        let mut induced = 0usize;
        for p in 0..*parts as u32 {
            induced += g.induced_view(&a, p).indices.len();
        }
        induced + 2 * g.edge_cut(&a) == g.indices.len()
    });
}

// ---------------------------------------------------------------------------
// partitioner invariants (routing)
// ---------------------------------------------------------------------------
#[test]
fn prop_every_partitioner_is_total_and_bounded() {
    let strat = Pair(
        GraphStrategy {
            max_n: 50,
            max_extra_edges: 150,
        },
        UsizeRange(1, 6),
    );
    check(104, 40, &strat, |(case, parts)| {
        let g = graph_of(case);
        for name in ["random", "hash", "bfs", "ldg", "metis"] {
            let mut rng = Pcg64::new(13);
            let a = partition::by_name(name)
                .unwrap()
                .partition(&g, *parts, &mut rng);
            if a.len() != g.n {
                return false;
            }
            if !a.iter().all(|&x| (x as usize) < *parts) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_balanced_partitioners_respect_capacity() {
    let strat = Pair(
        GraphStrategy {
            max_n: 80,
            max_extra_edges: 150,
        },
        UsizeRange(2, 6),
    );
    check(105, 40, &strat, |(case, parts)| {
        let g = graph_of(case);
        for name in ["random", "bfs", "ldg"] {
            let mut rng = Pcg64::new(17);
            let a = partition::by_name(name)
                .unwrap()
                .partition(&g, *parts, &mut rng);
            let q = partition::quality(&g, &a, *parts);
            // cap used by the implementations is ceil(n/parts)(+1)
            let cap = g.n.div_ceil(*parts) + 1;
            if q.sizes.iter().any(|&s| s > cap) {
                return false;
            }
        }
        true
    });
}

// ---------------------------------------------------------------------------
// batching invariants
// ---------------------------------------------------------------------------
#[test]
fn prop_batch_iter_partitions_ids() {
    let strat = Pair(UsizeRange(1, 200), UsizeRange(1, 40));
    check(106, 100, &strat, |(n, b)| {
        let ids: Vec<u32> = (0..*n as u32).collect();
        let mut rng = Pcg64::new(23);
        let batches: Vec<Vec<u32>> = BatchIter::new(&ids, *b, &mut rng).collect();
        // all batches <= b, only last may be short
        for (i, batch) in batches.iter().enumerate() {
            if batch.len() > *b {
                return false;
            }
            if i + 1 < batches.len() && batch.len() != *b {
                return false;
            }
        }
        // exact cover
        let mut all: Vec<u32> = batches.into_iter().flatten().collect();
        all.sort_unstable();
        all == ids
    });
}

// ---------------------------------------------------------------------------
// block-builder invariants (state fed to the HLO step)
// ---------------------------------------------------------------------------
#[test]
fn prop_blocks_are_well_formed() {
    let strat = Pair(
        GraphStrategy {
            max_n: 60,
            max_extra_edges: 200,
        },
        Pair(UsizeRange(1, 8), Pair(UsizeRange(1, 5), UsizeRange(1, 5))),
    );
    check(107, 40, &strat, |(case, (b, (f1, f2)))| {
        let g = graph_of(case);
        let ds = dataset_of(g, 6, 3, 31);
        let bb = BlockBuilder::new(*b, *f1, *f2, 6, 3, false);
        let mut rng = Pcg64::new(37);
        let k = (*b).min(ds.n());
        let targets: Vec<u32> = (0..k as u32).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);

        // shape invariants
        if blk.a1.len() != blk.b * blk.n1 || blk.a2.len() != blk.n1 * blk.n2 {
            return false;
        }
        // rows: real targets sum to 1, padding rows to 0
        for i in 0..blk.b {
            let s: f32 = blk.a1[i * blk.n1..(i + 1) * blk.n1].iter().sum();
            let want_real = i < k;
            if want_real && (s - 1.0).abs() > 1e-4 {
                return false;
            }
            if !want_real && s != 0.0 {
                return false;
            }
        }
        // every level-2 row of a real slot sums to 1
        for j in 0..blk.n1 {
            let s: f32 = blk.a2[j * blk.n2..(j + 1) * blk.n2].iter().sum();
            if blk.nodes_l1[j] == EMPTY {
                if s != 0.0 {
                    return false;
                }
            } else if (s - 1.0).abs() > 1e-4 {
                return false;
            }
        }
        // slot nodes must be real neighbors (or self)
        for (i, &t) in targets.iter().enumerate() {
            for s in 0..*f1 {
                let v = blk.nodes_l1[i * f1 + s];
                if v == EMPTY {
                    continue;
                }
                if s == 0 {
                    if v != t {
                        return false;
                    }
                } else if !ds.graph.neighbors(t).contains(&v) {
                    return false;
                }
            }
        }
        // features of EMPTY slots are zero
        for (j, &v) in blk.nodes_l2.iter().enumerate() {
            if v == EMPTY && blk.x2[j * 6..(j + 1) * 6].iter().any(|&x| x != 0.0) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_remote_bytes_monotone_in_parts() {
    // with more parts, at least as many touched nodes are remote
    let strat = GraphStrategy {
        max_n: 60,
        max_extra_edges: 200,
    };
    check(108, 30, &strat, |case| {
        let g = graph_of(case);
        let ds = dataset_of(g, 4, 2, 41);
        let bb = BlockBuilder::new(4, 3, 3, 4, 2, false);
        let mut rng = Pcg64::new(43);
        let targets: Vec<u32> = (0..4u32.min(ds.n() as u32)).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        let a2: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let a4: Vec<u32> = (0..ds.n() as u32).map(|v| v % 4).collect();
        blk.remote_feature_bytes(&a4, 0) >= blk.remote_feature_bytes(&a2, 0)
    });
}

// ---------------------------------------------------------------------------
// model-state invariants
// ---------------------------------------------------------------------------
#[test]
fn prop_param_averaging_is_idempotent_and_linear() {
    let strat = UsizeRange(1, 64);
    check(109, 60, &strat, |&len| {
        let mut rng = Pcg64::new(47);
        let mut mk = |scale: f32| ModelState {
            params: vec![Tensor {
                shape: vec![len],
                data: (0..len).map(|_| rng.normal_f32() * scale).collect(),
            }],
            opt: vec![],
        };
        let a = mk(1.0);
        let b = mk(2.0);
        // average of identical copies is identity
        let same = ModelState::average_params(&[&a, &a, &a]);
        if same[0]
            .data
            .iter()
            .zip(&a.params[0].data)
            .any(|(&x, &y)| (x - y).abs() > 1e-6)
        {
            return false;
        }
        // avg(a, b) == (a + b) / 2
        let avg = ModelState::average_params(&[&a, &b]);
        avg[0]
            .data
            .iter()
            .zip(a.params[0].data.iter().zip(&b.params[0].data))
            .all(|(&m, (&x, &y))| (m - (x + y) / 2.0).abs() < 1e-5)
    });
}
