//! Cluster-engine tests: sync-mode bit parity with the sequential driver —
//! over both `RunResult`s and the streamed `Event` sequences — bounded
//! staleness, pipelined correction, `RunControl` early-stop, queued-loss
//! readback, and the modeled network's engine-independence.
//!
//! Always runs against the native backend (the cluster engine requires it);
//! the manifest is generated under `target/` if absent.

use std::sync::Arc;

use llcg::api::{Event, ExperimentBuilder};
use llcg::cluster::{Engine, RoundMode};
use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::runtime::{ModelState, Runtime};
use llcg::sampler::BlockBuilder;
use llcg::util::Pcg64;

/// A native-backend runtime (cluster workers must be able to rebuild it on
/// their own threads, which PJRT cannot do). Asking `load_or_native` for the
/// native dir directly routes around any PJRT artifacts in the checkout.
fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 4;
    cfg.rounds = 4;
    cfg.schedule = Schedule::Fixed { k: 3 };
    cfg.correction_steps = 2;
    cfg.eval_every = 2;
    cfg.eval_max_nodes = 64;
    cfg.seed = 7;
    cfg
}

fn run_with(cfg: &ExperimentConfig, rt: &Runtime) -> driver::RunResult {
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    driver::run_experiment(cfg, &ds, rt).unwrap()
}

// ---------------------------------------------------------------------------
// sync mode: exact reproduction of the sequential driver
// ---------------------------------------------------------------------------

#[test]
fn cluster_sync_matches_sequential_bit_for_bit() {
    let rt = native_rt();
    let mut seq_cfg = base_cfg();
    // a non-ideal (but non-sleeping) net also checks that the modeled time
    // is engine-independent: same bytes, same deterministic jitter stream
    seq_cfg.net = "lan".into();
    let mut clu_cfg = seq_cfg.clone();
    clu_cfg.engine = Engine::Cluster;

    let a = run_with(&seq_cfg, &rt);
    let b = run_with(&clu_cfg, &rt);
    assert_eq!(a.engine, "sequential");
    assert_eq!(b.engine, "cluster");
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.local_steps, rb.local_steps);
        assert_eq!(
            ra.local_loss.to_bits(),
            rb.local_loss.to_bits(),
            "round {}: local loss {} vs {}",
            ra.round,
            ra.local_loss,
            rb.local_loss
        );
        assert_eq!(
            ra.global_loss.to_bits(),
            rb.global_loss.to_bits(),
            "round {}: global loss",
            ra.round
        );
        assert_eq!(
            ra.val_score.to_bits(),
            rb.val_score.to_bits(),
            "round {}: val",
            ra.round
        );
        assert_eq!(ra.comm.down_bytes, rb.comm.down_bytes, "round {}", ra.round);
        assert_eq!(ra.comm.up_bytes, rb.comm.up_bytes, "round {}", ra.round);
        assert_eq!(
            ra.comm.feature_bytes, rb.comm.feature_bytes,
            "round {}",
            ra.round
        );
        assert_eq!(ra.cum_bytes, rb.cum_bytes, "round {}", ra.round);
        assert_eq!(
            ra.net_time_s.to_bits(),
            rb.net_time_s.to_bits(),
            "round {}: modeled net time must be engine-independent",
            ra.round
        );
    }
    assert_eq!(a.final_val.to_bits(), b.final_val.to_bits());
    assert_eq!(a.final_test.to_bits(), b.final_test.to_bits());
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.cut_ratio.to_bits(), b.cut_ratio.to_bits());
}

#[test]
fn cluster_sync_matches_sequential_for_ggs_feature_bytes() {
    // GGS exercises the RemoteFeatures message path
    let rt = native_rt();
    let mut seq_cfg = base_cfg();
    seq_cfg.algorithm = Algorithm::Ggs;
    seq_cfg.rounds = 2;
    let mut clu_cfg = seq_cfg.clone();
    clu_cfg.engine = Engine::Cluster;
    let a = run_with(&seq_cfg, &rt);
    let b = run_with(&clu_cfg, &rt);
    assert!(a.records.iter().any(|r| r.comm.feature_bytes > 0));
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.comm.feature_bytes, rb.comm.feature_bytes);
        assert_eq!(ra.local_loss.to_bits(), rb.local_loss.to_bits());
    }
}

#[test]
fn cluster_survives_empty_worker_shards() {
    // more parts than train clusters -> some workers own no train nodes
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.parts = 32;
    cfg.rounds = 2;
    cfg.eval_max_nodes = 32;
    let res = run_with(&cfg, &rt);
    assert_eq!(res.records.len(), 2);
    assert!(res.final_val.is_finite());
}

// ---------------------------------------------------------------------------
// event-level parity + run control (session API)
// ---------------------------------------------------------------------------

/// Exact digest of an event: kind + full payload (float payloads by bits).
fn event_summary(ev: &Event) -> String {
    match ev {
        Event::RoundStarted { round, local_steps } => {
            format!("round_started r={round} k={local_steps}")
        }
        Event::WorkerRoundCompleted { round, part, .. } => {
            // the identity (round, part) — and the part-ordered position in
            // the stream — are engine-independent; the compute/net times
            // are measurements and deliberately excluded from the digest
            format!("worker_round r={round} p={part}")
        }
        Event::CorrectionApplied { round, steps } => {
            format!("correction_applied r={round} s={steps}")
        }
        Event::EvalCompleted {
            round,
            val_score,
            global_loss,
        } => format!(
            "eval_completed r={round} val={:016x} loss={:016x}",
            val_score.to_bits(),
            global_loss.to_bits()
        ),
        Event::RoundCompleted(r) => format!(
            "round_completed r={} k={} ll={:016x} gl={:016x} val={:016x} bytes={} cum={}",
            r.round,
            r.local_steps,
            r.local_loss.to_bits(),
            r.global_loss.to_bits(),
            r.val_score.to_bits(),
            r.comm.total(),
            r.cum_bytes
        ),
        Event::Finished(res) => format!(
            "finished rounds={} val={:016x} test={:016x}",
            res.records.len(),
            res.final_val.to_bits(),
            res.final_test.to_bits()
        ),
    }
}

fn collect_events(rt: &Runtime, cfg: &ExperimentConfig) -> Vec<String> {
    let ds = Arc::new(generators::by_name(&cfg.dataset, cfg.seed).unwrap());
    let exp = ExperimentBuilder::from_config(cfg.clone())
        .with_dataset(ds)
        .build()
        .unwrap();
    let mut evs = Vec::new();
    exp.launch(rt)
        .stream(|ev| evs.push(event_summary(ev)))
        .unwrap();
    evs
}

#[test]
fn engines_emit_identical_sync_event_streams() {
    let rt = native_rt();
    let mut seq_cfg = base_cfg();
    seq_cfg.net = "lan".into();
    let mut clu_cfg = seq_cfg.clone();
    clu_cfg.engine = Engine::Cluster;

    let a = collect_events(&rt, &seq_cfg);
    let b = collect_events(&rt, &clu_cfg);
    assert_eq!(a, b, "sync-mode event streams must match kind-for-kind and bit-for-bit");

    // the stream has the documented shape: every round starts and
    // completes, LLCG corrects every round, eval fires on the cadence,
    // and the stream ends with `finished`
    let count = |prefix: &str| a.iter().filter(|s| s.starts_with(prefix)).count();
    assert_eq!(count("round_started"), seq_cfg.rounds);
    assert_eq!(count("round_completed"), seq_cfg.rounds);
    assert_eq!(
        count("worker_round"),
        seq_cfg.rounds * seq_cfg.parts,
        "one WorkerRoundCompleted per worker per round"
    );
    assert_eq!(count("correction_applied"), seq_cfg.rounds);
    assert_eq!(count("eval_completed"), 2, "eval_every=2 over 4 rounds");
    assert_eq!(count("finished"), 1);
    assert!(a.last().unwrap().starts_with("finished"));
    // worker events sit between their RoundStarted and RoundCompleted, in
    // part order (0..P) on both engines
    let first_round: Vec<&String> = a
        .iter()
        .skip_while(|s| !s.starts_with("round_started r=1 "))
        .take_while(|s| !s.starts_with("round_completed"))
        .filter(|s| s.starts_with("worker_round"))
        .collect();
    let want: Vec<String> = (0..seq_cfg.parts)
        .map(|p| format!("worker_round r=1 p={p}"))
        .collect();
    assert_eq!(
        first_round.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        want.iter().map(|s| s.as_str()).collect::<Vec<_>>()
    );
}

#[test]
fn run_control_stops_at_the_next_round_boundary() {
    let rt = native_rt();
    for engine in [Engine::Sequential, Engine::Cluster] {
        let mut cfg = base_cfg();
        cfg.engine = engine;
        cfg.rounds = 6;
        let ds = Arc::new(generators::by_name(&cfg.dataset, cfg.seed).unwrap());
        let exp = ExperimentBuilder::from_config(cfg)
            .with_dataset(ds)
            .build()
            .unwrap();
        let run = exp.launch(&rt);
        let control = run.control();
        assert!(!control.stop_requested());
        let mut completed = 0usize;
        let res = run
            .stream(|ev| {
                if matches!(ev, Event::RoundCompleted(_)) {
                    completed += 1;
                    if completed == 2 {
                        control.stop();
                    }
                }
            })
            .unwrap();
        // stopped after round 2: the result is well-formed but partial
        assert_eq!(res.records.len(), 2, "{engine:?}");
        assert_eq!(res.records.last().unwrap().round, 2, "{engine:?}");
        assert_eq!(res.engine, engine.name());
        assert!(res.final_val.is_finite(), "{engine:?}: eval ran at round 2");
        assert!(res.final_test.is_finite(), "{engine:?}: final test still runs");
        assert!(res.avg_round_bytes > 0.0, "{engine:?}");
    }
}

// ---------------------------------------------------------------------------
// async-staleness mode
// ---------------------------------------------------------------------------

#[test]
fn async_mode_completes_and_respects_staleness_bound() {
    let rt = native_rt();
    for tau in [0usize, 1, 3] {
        let mut cfg = base_cfg();
        cfg.engine = Engine::Cluster;
        cfg.round_mode = RoundMode::AsyncStaleness { tau };
        // mild per-link jitter via injected sleeps makes workers genuinely
        // drift; the gate must still hold the bound
        cfg.net = "lat=2e-3,bw=1e9,jitter=0.5,scale=1".into();
        let res = run_with(&cfg, &rt);
        assert_eq!(res.records.len(), cfg.rounds, "tau={tau}");
        let max_staleness = res.max_staleness.expect("async reports staleness");
        assert!(
            max_staleness <= tau as u64,
            "tau={tau}: observed staleness {max_staleness}"
        );
        assert!(res.final_val.is_finite(), "tau={tau}");
        let pb = rt.meta("gcn_adam_tiny").unwrap().param_bytes();
        for r in &res.records {
            assert!(r.local_loss.is_finite(), "tau={tau} round {}", r.round);
            // every window closes on exactly P parameter pushes
            assert_eq!(r.comm.up_bytes, cfg.parts as u64 * pb, "tau={tau}");
        }
        // every local round was granted exactly once (P*rounds downloads),
        // though grants may land in a neighboring window under tau > 0
        let down_total: u64 = res.records.iter().map(|r| r.comm.down_bytes).sum();
        assert_eq!(down_total, (cfg.parts * cfg.rounds) as u64 * pb, "tau={tau}");
    }
}

// ---------------------------------------------------------------------------
// pipelined-correction mode
// ---------------------------------------------------------------------------

#[test]
fn pipelined_correction_matches_sync_byte_accounting() {
    let rt = native_rt();
    let mut sync_cfg = base_cfg();
    sync_cfg.engine = Engine::Cluster;
    let mut pipe_cfg = sync_cfg.clone();
    pipe_cfg.round_mode = RoundMode::PipelinedCorrection;
    let a = run_with(&sync_cfg, &rt);
    let b = run_with(&pipe_cfg, &rt);
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        // the overlap changes *when* correction runs, never what moves on
        // the wire
        assert_eq!(ra.comm.total(), rb.comm.total(), "round {}", ra.round);
    }
    assert!(b.final_val.is_finite());
    assert!(b.records.iter().all(|r| r.local_loss.is_finite()));
    // pipelined correction differs numerically from sync (it corrects the
    // stale broadcast params), but must stay in the same ballpark
    assert!((a.final_val - b.final_val).abs() < 0.5);
}

// ---------------------------------------------------------------------------
// queued (per-round) loss readback
// ---------------------------------------------------------------------------

#[test]
fn queued_losses_match_per_step_losses() {
    let rt = native_rt();
    let ds = generators::by_name("tiny", 0).unwrap();
    let name = "gcn_adam_tiny";
    let meta = rt.meta(name).unwrap().clone();
    let bb = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        meta.multilabel(),
    );
    let mut init_rng = Pcg64::new(3);
    let mut state_a = ModelState::init(&meta, &mut init_rng);
    let state_b = state_a.clone();
    // two identical block streams, one per device state
    let mut rng_a = Pcg64::new(11);
    let mut rng_b = Pcg64::new(11);

    let targets: Vec<u32> = ds.splits.train[..meta.dims.b].to_vec();
    let mut dev_a = rt.upload(name, &state_a).unwrap();
    let mut dev_b = rt.upload(name, &state_b).unwrap();
    let mut immediate = Vec::new();
    for _ in 0..5 {
        let blk_a = bb.build(&targets, &ds.graph, &ds, &mut rng_a);
        immediate.push(rt.train_step_device(&mut dev_a, &blk_a, 0.01).unwrap());
        let blk_b = bb.build(&targets, &ds.graph, &ds, &mut rng_b);
        rt.train_step_device_queued(&mut dev_b, &blk_b, 0.01).unwrap();
    }
    let queued = dev_b.take_losses().unwrap();
    assert_eq!(immediate, queued, "queued loss stream differs");
    assert!(dev_b.take_losses().unwrap().is_empty(), "drain must clear");
    // and the resulting states agree bit-for-bit
    let mut out_a = state_a.clone();
    rt.download_into(&dev_a, &mut out_a).unwrap();
    rt.download_into(&dev_b, &mut state_a).unwrap();
    for (ta, tb) in out_a.params.iter().zip(&state_a.params) {
        assert_eq!(ta.data, tb.data);
    }
}

// ---------------------------------------------------------------------------
// guard rails
// ---------------------------------------------------------------------------

#[test]
fn cluster_rejects_zero_rounds() {
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.rounds = 0;
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    assert!(driver::run_experiment(&cfg, &ds, &rt).is_err());
}

#[test]
fn sequential_engine_rejects_non_sync_round_modes() {
    // the sequential driver is always sync; a non-sync round_mode must be
    // an error, not a silent downgrade
    let rt = native_rt();
    let ds = generators::by_name("tiny", 7).unwrap();
    for mode in [
        RoundMode::AsyncStaleness { tau: 2 },
        RoundMode::PipelinedCorrection,
    ] {
        let mut cfg = base_cfg();
        cfg.round_mode = mode;
        let err = match driver::run_experiment(&cfg, &ds, &rt) {
            Ok(_) => panic!("non-sync round_mode accepted on the sequential engine"),
            Err(e) => e,
        };
        assert!(
            format!("{err:#}").contains("cluster engine"),
            "unhelpful error: {err:#}"
        );
    }
}
