//! Cluster-engine tests: sync-mode bit parity with the sequential driver —
//! over both `RunResult`s and the streamed `Event` sequences — bounded
//! staleness, pipelined correction, `RunControl` early-stop, queued-loss
//! readback, and the modeled network's engine-independence.
//!
//! Always runs against the native backend (the cluster engine requires it);
//! the manifest is generated under `target/` if absent.

use std::sync::Arc;

use llcg::api::{Event, ExperimentBuilder};
use llcg::cluster::{Engine, RoundMode};
use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::runtime::{ModelState, Runtime};
use llcg::sampler::BlockBuilder;
use llcg::util::Pcg64;

/// A native-backend runtime (cluster workers must be able to rebuild it on
/// their own threads, which PJRT cannot do). Asking `load_or_native` for the
/// native dir directly routes around any PJRT artifacts in the checkout.
fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 4;
    cfg.rounds = 4;
    cfg.schedule = Schedule::Fixed { k: 3 };
    cfg.correction_steps = 2;
    cfg.eval_every = 2;
    cfg.eval_max_nodes = 64;
    cfg.seed = 7;
    cfg
}

fn run_with(cfg: &ExperimentConfig, rt: &Runtime) -> driver::RunResult {
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    driver::run_experiment(cfg, &ds, rt).unwrap()
}

// ---------------------------------------------------------------------------
// sync mode: exact reproduction of the sequential driver
// ---------------------------------------------------------------------------

#[test]
fn cluster_sync_matches_sequential_bit_for_bit() {
    let rt = native_rt();
    let mut seq_cfg = base_cfg();
    // a non-ideal (but non-sleeping) net also checks that the modeled time
    // is engine-independent: same bytes, same deterministic jitter stream
    seq_cfg.net = "lan".into();
    let mut clu_cfg = seq_cfg.clone();
    clu_cfg.engine = Engine::Cluster;

    let a = run_with(&seq_cfg, &rt);
    let b = run_with(&clu_cfg, &rt);
    assert_eq!(a.engine, "sequential");
    assert_eq!(b.engine, "cluster");
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.local_steps, rb.local_steps);
        assert_eq!(
            ra.local_loss.to_bits(),
            rb.local_loss.to_bits(),
            "round {}: local loss {} vs {}",
            ra.round,
            ra.local_loss,
            rb.local_loss
        );
        assert_eq!(
            ra.global_loss.to_bits(),
            rb.global_loss.to_bits(),
            "round {}: global loss",
            ra.round
        );
        assert_eq!(
            ra.val_score.to_bits(),
            rb.val_score.to_bits(),
            "round {}: val",
            ra.round
        );
        assert_eq!(ra.comm.down_bytes, rb.comm.down_bytes, "round {}", ra.round);
        assert_eq!(ra.comm.up_bytes, rb.comm.up_bytes, "round {}", ra.round);
        assert_eq!(
            ra.comm.feature_bytes, rb.comm.feature_bytes,
            "round {}",
            ra.round
        );
        assert_eq!(ra.cum_bytes, rb.cum_bytes, "round {}", ra.round);
        assert_eq!(
            ra.net_time_s.to_bits(),
            rb.net_time_s.to_bits(),
            "round {}: modeled net time must be engine-independent",
            ra.round
        );
    }
    assert_eq!(a.final_val.to_bits(), b.final_val.to_bits());
    assert_eq!(a.final_test.to_bits(), b.final_test.to_bits());
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.cut_ratio.to_bits(), b.cut_ratio.to_bits());
}

#[test]
fn cluster_sync_matches_sequential_for_ggs_feature_bytes() {
    // GGS exercises the RemoteFeatures message path
    let rt = native_rt();
    let mut seq_cfg = base_cfg();
    seq_cfg.algorithm = Algorithm::Ggs;
    seq_cfg.rounds = 2;
    let mut clu_cfg = seq_cfg.clone();
    clu_cfg.engine = Engine::Cluster;
    let a = run_with(&seq_cfg, &rt);
    let b = run_with(&clu_cfg, &rt);
    assert!(a.records.iter().any(|r| r.comm.feature_bytes > 0));
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.comm.feature_bytes, rb.comm.feature_bytes);
        assert_eq!(ra.local_loss.to_bits(), rb.local_loss.to_bits());
    }
}

#[test]
fn cluster_survives_empty_worker_shards() {
    // more parts than train clusters -> some workers own no train nodes
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.parts = 32;
    cfg.rounds = 2;
    cfg.eval_max_nodes = 32;
    let res = run_with(&cfg, &rt);
    assert_eq!(res.records.len(), 2);
    assert!(res.final_val.is_finite());
}

// ---------------------------------------------------------------------------
// event-level parity + run control (session API)
// ---------------------------------------------------------------------------

/// Exact digest of an event: kind + full payload (float payloads by bits).
fn event_summary(ev: &Event) -> String {
    match ev {
        Event::RoundStarted { round, local_steps } => {
            format!("round_started r={round} k={local_steps}")
        }
        Event::WorkerRoundCompleted { round, part, .. } => {
            // the identity (round, part) — and the part-ordered position in
            // the stream — are engine-independent; the compute/net times
            // are measurements and deliberately excluded from the digest
            format!("worker_round r={round} p={part}")
        }
        Event::CorrectionApplied { round, steps } => {
            format!("correction_applied r={round} s={steps}")
        }
        Event::EvalCompleted {
            round,
            val_score,
            global_loss,
        } => format!(
            "eval_completed r={round} val={:016x} loss={:016x}",
            val_score.to_bits(),
            global_loss.to_bits()
        ),
        Event::WorkerRestarted { round, part } => {
            format!("worker_restarted r={round} p={part}")
        }
        Event::CheckpointSaved { round, .. } => {
            // the path embeds the (per-test, per-process) checkpoint dir;
            // only the identity belongs in the digest
            format!("checkpoint_saved r={round}")
        }
        Event::RoundCompleted(r) => format!(
            "round_completed r={} k={} ll={:016x} gl={:016x} val={:016x} bytes={} cum={}",
            r.round,
            r.local_steps,
            r.local_loss.to_bits(),
            r.global_loss.to_bits(),
            r.val_score.to_bits(),
            r.comm.total(),
            r.cum_bytes
        ),
        Event::MonitorAlert {
            round,
            monitor,
            value,
            ..
        } => {
            // only emitted while the telemetry monitors are on (they are
            // off in every parity test here); digest identity + value
            format!("monitor_alert r={round} m={monitor} v={:016x}", value.to_bits())
        }
        Event::Finished(res) => format!(
            "finished rounds={} val={:016x} test={:016x}",
            res.records.len(),
            res.final_val.to_bits(),
            res.final_test.to_bits()
        ),
    }
}

fn collect_events(rt: &Runtime, cfg: &ExperimentConfig) -> Vec<String> {
    let ds = Arc::new(generators::by_name(&cfg.dataset, cfg.seed).unwrap());
    let exp = ExperimentBuilder::from_config(cfg.clone())
        .with_dataset(ds)
        .build()
        .unwrap();
    let mut evs = Vec::new();
    exp.launch(rt)
        .stream(|ev| evs.push(event_summary(ev)))
        .unwrap();
    evs
}

#[test]
fn engines_emit_identical_sync_event_streams() {
    let rt = native_rt();
    let mut seq_cfg = base_cfg();
    seq_cfg.net = "lan".into();
    let mut clu_cfg = seq_cfg.clone();
    clu_cfg.engine = Engine::Cluster;

    let a = collect_events(&rt, &seq_cfg);
    let b = collect_events(&rt, &clu_cfg);
    assert_eq!(a, b, "sync-mode event streams must match kind-for-kind and bit-for-bit");

    // the stream has the documented shape: every round starts and
    // completes, LLCG corrects every round, eval fires on the cadence,
    // and the stream ends with `finished`
    let count = |prefix: &str| a.iter().filter(|s| s.starts_with(prefix)).count();
    assert_eq!(count("round_started"), seq_cfg.rounds);
    assert_eq!(count("round_completed"), seq_cfg.rounds);
    assert_eq!(
        count("worker_round"),
        seq_cfg.rounds * seq_cfg.parts,
        "one WorkerRoundCompleted per worker per round"
    );
    assert_eq!(count("correction_applied"), seq_cfg.rounds);
    assert_eq!(count("eval_completed"), 2, "eval_every=2 over 4 rounds");
    assert_eq!(count("finished"), 1);
    assert!(a.last().unwrap().starts_with("finished"));
    // worker events sit between their RoundStarted and RoundCompleted, in
    // part order (0..P) on both engines
    let first_round: Vec<&String> = a
        .iter()
        .skip_while(|s| !s.starts_with("round_started r=1 "))
        .take_while(|s| !s.starts_with("round_completed"))
        .filter(|s| s.starts_with("worker_round"))
        .collect();
    let want: Vec<String> = (0..seq_cfg.parts)
        .map(|p| format!("worker_round r=1 p={p}"))
        .collect();
    assert_eq!(
        first_round.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        want.iter().map(|s| s.as_str()).collect::<Vec<_>>()
    );
}

#[test]
fn run_control_stops_at_the_next_round_boundary() {
    let rt = native_rt();
    for engine in [Engine::Sequential, Engine::Cluster] {
        let mut cfg = base_cfg();
        cfg.engine = engine;
        cfg.rounds = 6;
        let ds = Arc::new(generators::by_name(&cfg.dataset, cfg.seed).unwrap());
        let exp = ExperimentBuilder::from_config(cfg)
            .with_dataset(ds)
            .build()
            .unwrap();
        let run = exp.launch(&rt);
        let control = run.control();
        assert!(!control.stop_requested());
        let mut completed = 0usize;
        let res = run
            .stream(|ev| {
                if matches!(ev, Event::RoundCompleted(_)) {
                    completed += 1;
                    if completed == 2 {
                        control.stop();
                    }
                }
            })
            .unwrap();
        // stopped after round 2: the result is well-formed but partial
        assert_eq!(res.records.len(), 2, "{engine:?}");
        assert_eq!(res.records.last().unwrap().round, 2, "{engine:?}");
        assert_eq!(res.engine, engine.name());
        assert!(res.final_val.is_finite(), "{engine:?}: eval ran at round 2");
        assert!(res.final_test.is_finite(), "{engine:?}: final test still runs");
        assert!(res.avg_round_bytes > 0.0, "{engine:?}");
    }
}

// ---------------------------------------------------------------------------
// async-staleness mode
// ---------------------------------------------------------------------------

#[test]
fn async_mode_completes_and_respects_staleness_bound() {
    let rt = native_rt();
    for tau in [0usize, 1, 3] {
        let mut cfg = base_cfg();
        cfg.engine = Engine::Cluster;
        cfg.round_mode = RoundMode::AsyncStaleness { tau };
        // mild per-link jitter via injected sleeps makes workers genuinely
        // drift; the gate must still hold the bound
        cfg.net = "lat=2e-3,bw=1e9,jitter=0.5,scale=1".into();
        let res = run_with(&cfg, &rt);
        assert_eq!(res.records.len(), cfg.rounds, "tau={tau}");
        let max_staleness = res.max_staleness.expect("async reports staleness");
        assert!(
            max_staleness <= tau as u64,
            "tau={tau}: observed staleness {max_staleness}"
        );
        assert!(res.final_val.is_finite(), "tau={tau}");
        let pb = rt.meta("gcn_adam_tiny").unwrap().param_bytes();
        for r in &res.records {
            assert!(r.local_loss.is_finite(), "tau={tau} round {}", r.round);
            // every window closes on exactly P parameter pushes
            assert_eq!(r.comm.up_bytes, cfg.parts as u64 * pb, "tau={tau}");
        }
        // every local round was granted exactly once (P*rounds downloads),
        // though grants may land in a neighboring window under tau > 0
        let down_total: u64 = res.records.iter().map(|r| r.comm.down_bytes).sum();
        assert_eq!(down_total, (cfg.parts * cfg.rounds) as u64 * pb, "tau={tau}");
    }
}

// ---------------------------------------------------------------------------
// pipelined-correction mode
// ---------------------------------------------------------------------------

#[test]
fn pipelined_correction_matches_sync_byte_accounting() {
    let rt = native_rt();
    let mut sync_cfg = base_cfg();
    sync_cfg.engine = Engine::Cluster;
    let mut pipe_cfg = sync_cfg.clone();
    pipe_cfg.round_mode = RoundMode::PipelinedCorrection;
    let a = run_with(&sync_cfg, &rt);
    let b = run_with(&pipe_cfg, &rt);
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        // the overlap changes *when* correction runs, never what moves on
        // the wire
        assert_eq!(ra.comm.total(), rb.comm.total(), "round {}", ra.round);
    }
    assert!(b.final_val.is_finite());
    assert!(b.records.iter().all(|r| r.local_loss.is_finite()));
    // pipelined correction differs numerically from sync (it corrects the
    // stale broadcast params), but must stay in the same ballpark
    assert!((a.final_val - b.final_val).abs() < 0.5);
}

// ---------------------------------------------------------------------------
// queued (per-round) loss readback
// ---------------------------------------------------------------------------

#[test]
fn queued_losses_match_per_step_losses() {
    let rt = native_rt();
    let ds = generators::by_name("tiny", 0).unwrap();
    let name = "gcn_adam_tiny";
    let meta = rt.meta(name).unwrap().clone();
    let bb = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        meta.multilabel(),
    );
    let mut init_rng = Pcg64::new(3);
    let mut state_a = ModelState::init(&meta, &mut init_rng);
    let state_b = state_a.clone();
    // two identical block streams, one per device state
    let mut rng_a = Pcg64::new(11);
    let mut rng_b = Pcg64::new(11);

    let targets: Vec<u32> = ds.splits.train[..meta.dims.b].to_vec();
    let mut dev_a = rt.upload(name, &state_a).unwrap();
    let mut dev_b = rt.upload(name, &state_b).unwrap();
    let mut immediate = Vec::new();
    for _ in 0..5 {
        let blk_a = bb.build(&targets, &ds.graph, &ds, &mut rng_a);
        immediate.push(rt.train_step_device(&mut dev_a, &blk_a, 0.01).unwrap());
        let blk_b = bb.build(&targets, &ds.graph, &ds, &mut rng_b);
        rt.train_step_device_queued(&mut dev_b, &blk_b, 0.01).unwrap();
    }
    let queued = dev_b.take_losses().unwrap();
    assert_eq!(immediate, queued, "queued loss stream differs");
    assert!(dev_b.take_losses().unwrap().is_empty(), "drain must clear");
    // and the resulting states agree bit-for-bit
    let mut out_a = state_a.clone();
    rt.download_into(&dev_a, &mut out_a).unwrap();
    rt.download_into(&dev_b, &mut state_a).unwrap();
    for (ta, tb) in out_a.params.iter().zip(&state_a.params) {
        assert_eq!(ta.data, tb.data);
    }
}

// ---------------------------------------------------------------------------
// fault tolerance: injected drops/crashes, quorum rounds, respawn
// ---------------------------------------------------------------------------

fn param_bytes_of(rt: &Runtime) -> u64 {
    rt.meta("gcn_adam_tiny").unwrap().param_bytes()
}

#[test]
fn crash_with_respawn_completes_all_rounds_near_fault_free_score() {
    let rt = native_rt();
    let mut clean_cfg = base_cfg();
    clean_cfg.engine = Engine::Cluster;
    clean_cfg.rounds = 6;
    let clean = run_with(&clean_cfg, &rt);

    let mut cfg = clean_cfg.clone();
    cfg.net = "crash=1@3".into();
    cfg.respawn = true;
    let res = run_with(&cfg, &rt);

    assert_eq!(res.records.len(), 6, "the crash must not end the run");
    assert_eq!(res.total_respawns, 1);
    assert_eq!(res.total_drops, 0, "a crash is not a message drop");
    // worker 1 dies on receipt of round 3's broadcast: 3 of 4 params are
    // averaged that round, and the supervisor respawns it at round 4
    assert_eq!(res.records[2].quorum, 3);
    assert_eq!(res.records[3].respawns, 1);
    assert_eq!(res.records[3].quorum, 4, "respawned worker contributes again");
    let pb = param_bytes_of(&rt);
    for r in &res.records {
        assert_eq!(
            r.comm.up_bytes,
            r.quorum as u64 * pb,
            "round {}: up bytes must count integrated uploads only",
            r.round
        );
    }
    assert!(res.final_val.is_finite());
    assert!(
        (res.final_val - clean.final_val).abs() <= 0.05,
        "crash+respawn drifted too far from the fault-free score: {} vs {}",
        res.final_val,
        clean.final_val
    );
}

#[test]
fn crash_without_respawn_drops_the_worker_for_good() {
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.net = "crash=2@2".into();
    cfg.respawn = false;
    let res = run_with(&cfg, &rt);
    assert_eq!(res.records.len(), cfg.rounds);
    assert_eq!(res.total_respawns, 0);
    assert_eq!(res.records[0].quorum, 4);
    for r in &res.records[1..] {
        assert_eq!(r.quorum, 3, "round {}: dead worker must stay out", r.round);
    }
}

#[test]
fn message_drops_are_tolerated_and_counted() {
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.rounds = 6;
    // 20% per-leg loss over 6 rounds x 4 workers x 2 legs: some drops are
    // (deterministically, per the seeded draws) guaranteed in practice
    cfg.net = "lan,drop=0.2".into();
    let res = run_with(&cfg, &rt);
    assert_eq!(res.records.len(), cfg.rounds);
    assert!(res.total_drops > 0, "0 drops at drop=0.2 over 48 draws");
    assert_eq!(
        res.total_drops,
        res.records.iter().map(|r| r.drops).sum::<u64>()
    );
    let pb = param_bytes_of(&rt);
    for r in &res.records {
        assert!(r.quorum <= cfg.parts, "round {}", r.round);
        assert_eq!(r.comm.up_bytes, r.quorum as u64 * pb, "round {}", r.round);
        // a down-leg drop skips that worker's download
        assert!(r.comm.down_bytes <= cfg.parts as u64 * pb, "round {}", r.round);
    }
    assert!(res.final_val.is_finite());
    // determinism: the same spec + seed reproduces the run bit-for-bit,
    // drops and all
    let again = run_with(&cfg, &rt);
    assert_eq!(res.total_drops, again.total_drops);
    for (a, b) in res.records.iter().zip(&again.records) {
        assert_eq!(a.local_loss.to_bits(), b.local_loss.to_bits());
        assert_eq!(a.quorum, b.quorum);
        assert_eq!(a.drops, b.drops);
    }
}

#[test]
fn round_timeout_defers_late_uploads_one_round() {
    let rt = native_rt();
    // lan modeled latency (0.5 ms) >> the 1 us deadline: every upload is
    // late, so each round averages the previous round's held uploads
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.net = "lan".into();
    cfg.round_timeout = 1e-6;
    let res = run_with(&cfg, &rt);
    assert_eq!(res.records.len(), cfg.rounds);
    assert_eq!(res.records[0].quorum, 0, "round 1 has nothing held yet");
    assert!(
        res.records[0].local_loss.is_nan(),
        "no contributors -> no local loss to report"
    );
    for r in &res.records[1..] {
        assert_eq!(r.quorum, cfg.parts, "round {}: staleness-1 re-admission", r.round);
    }
    // the final round's fresh uploads have no next round: discarded as drops
    assert_eq!(res.records.last().unwrap().drops, cfg.parts as u64);

    // quorum backfill: K late uploads are admitted immediately instead
    let mut qcfg = cfg.clone();
    qcfg.quorum = 2;
    let qres = run_with(&qcfg, &rt);
    assert_eq!(qres.records[0].quorum, 2, "round 1 backfills to K from the late set");
    for r in &qres.records {
        assert!(r.quorum >= 2, "round {}: quorum floor", r.round);
    }
    assert!(qres.final_val.is_finite());
}

// ---------------------------------------------------------------------------
// checkpoint / resume
// ---------------------------------------------------------------------------

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("llcg_cluster_ckpt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn resume_replays_remaining_rounds_bit_for_bit() {
    let rt = native_rt();
    for engine in [Engine::Sequential, Engine::Cluster] {
        let mut full_cfg = base_cfg();
        full_cfg.engine = engine;
        let full = run_with(&full_cfg, &rt);

        // the same run writing checkpoints every 2 rounds must not drift
        let dir = ckpt_dir(engine.name());
        let mut ck_cfg = full_cfg.clone();
        ck_cfg.checkpoint_every = 2;
        ck_cfg.checkpoint_dir = dir.display().to_string();
        let with_ck = run_with(&ck_cfg, &rt);
        for (a, b) in full.records.iter().zip(&with_ck.records) {
            assert_eq!(
                a.local_loss.to_bits(),
                b.local_loss.to_bits(),
                "{engine:?} round {}: checkpointing perturbed the run",
                a.round
            );
            assert_eq!(a.val_score.to_bits(), b.val_score.to_bits());
            assert_eq!(a.cum_bytes, b.cum_bytes);
        }
        assert_eq!(full.final_test.to_bits(), with_ck.final_test.to_bits());
        assert!(dir.join("round_2").join("meta.json").is_file());
        assert!(dir.join("round_4").join("meta.json").is_file());

        // resuming from round 2 replays rounds 3..4 bit-for-bit
        let mut res_cfg = full_cfg.clone();
        res_cfg.resume = dir.join("round_2").display().to_string();
        let resumed = run_with(&res_cfg, &rt);
        assert_eq!(resumed.records.len(), 2, "{engine:?}: rounds 3 and 4 remain");
        for (a, b) in full.records[2..].iter().zip(&resumed.records) {
            assert_eq!(a.round, b.round, "{engine:?}");
            assert_eq!(
                a.local_loss.to_bits(),
                b.local_loss.to_bits(),
                "{engine:?} round {}: resume forked the local loss stream",
                a.round
            );
            assert_eq!(
                a.global_loss.to_bits(),
                b.global_loss.to_bits(),
                "{engine:?} round {}: resume forked the correction stream",
                a.round
            );
            assert_eq!(
                a.val_score.to_bits(),
                b.val_score.to_bits(),
                "{engine:?} round {}: resume forked the eval stream",
                a.round
            );
            assert_eq!(a.comm.total(), b.comm.total(), "{engine:?}");
            assert_eq!(a.cum_bytes, b.cum_bytes, "{engine:?}: cumulative bytes carry over");
        }
        assert_eq!(
            full.final_val.to_bits(),
            resumed.final_val.to_bits(),
            "{engine:?}"
        );
        assert_eq!(
            full.final_test.to_bits(),
            resumed.final_test.to_bits(),
            "{engine:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn resume_works_under_faults_on_the_cluster_engine() {
    // checkpoint at round 2 of a run whose worker 3 crashes at round 2
    // (leaving a dead entry in the checkpoint), then resume: the respawn
    // happens at round 3 of the resumed run, and the run completes
    let rt = native_rt();
    let dir = ckpt_dir("faulted");
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.net = "crash=3@2".into();
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = dir.display().to_string();
    let full = run_with(&cfg, &rt);
    assert_eq!(full.records.len(), cfg.rounds);
    assert_eq!(full.total_respawns, 1);

    let mut res_cfg = cfg.clone();
    res_cfg.checkpoint_every = 0;
    res_cfg.resume = dir.join("round_2").display().to_string();
    let resumed = run_with(&res_cfg, &rt);
    assert_eq!(resumed.records.len(), 2);
    assert_eq!(
        resumed.records[0].respawns, 1,
        "the checkpointed dead worker respawns on resume"
    );
    assert_eq!(resumed.records[0].quorum, 4);
    assert!(resumed.final_val.is_finite());

    // the sequential engine must refuse a checkpoint with dead workers
    let mut seq_cfg = res_cfg.clone();
    seq_cfg.engine = Engine::Sequential;
    seq_cfg.net = "ideal".into();
    let ds = generators::by_name(&seq_cfg.dataset, seq_cfg.seed).unwrap();
    let err = driver::run_experiment(&seq_cfg, &ds, &rt).unwrap_err();
    // (digest mismatch: the checkpoint pins net="crash=3@2" — that alone
    // rejects it; with a matching net it would be the dead-worker refusal)
    assert!(
        format!("{err:#}").contains("different experiment"),
        "unhelpful error: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fault_and_checkpoint_event_streams_have_the_documented_shape() {
    let rt = native_rt();
    let dir = ckpt_dir("events");
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.net = "crash=0@2".into();
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = dir.display().to_string();
    let evs = collect_events(&rt, &cfg);
    let count = |prefix: &str| evs.iter().filter(|s| s.starts_with(prefix)).count();
    assert_eq!(count("worker_restarted"), 1);
    assert!(evs.contains(&"worker_restarted r=3 p=0".to_string()), "{evs:?}");
    assert_eq!(count("checkpoint_saved"), 2, "rounds 2 and 4");
    assert!(evs.contains(&"checkpoint_saved r=2".to_string()));
    // crash at round 2: worker 0 contributes to rounds 1, 3, 4 only
    assert_eq!(count("worker_round"), cfg.rounds * cfg.parts - 1);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// guard rails
// ---------------------------------------------------------------------------

#[test]
fn cluster_rejects_zero_rounds() {
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;
    cfg.rounds = 0;
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    assert!(driver::run_experiment(&cfg, &ds, &rt).is_err());
}

#[test]
fn sequential_engine_rejects_non_sync_round_modes() {
    // the sequential driver is always sync; a non-sync round_mode must be
    // an error, not a silent downgrade
    let rt = native_rt();
    let ds = generators::by_name("tiny", 7).unwrap();
    for mode in [
        RoundMode::AsyncStaleness { tau: 2 },
        RoundMode::PipelinedCorrection,
    ] {
        let mut cfg = base_cfg();
        cfg.round_mode = mode;
        let err = match driver::run_experiment(&cfg, &ds, &rt) {
            Ok(_) => panic!("non-sync round_mode accepted on the sequential engine"),
            Err(e) => e,
        };
        assert!(
            format!("{err:#}").contains("cluster engine"),
            "unhelpful error: {err:#}"
        );
    }
}
