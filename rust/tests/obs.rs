//! Observability-layer acceptance tests:
//!
//! - every bit-exactness contract in the repo holds **with tracing and
//!   metrics enabled**: cluster-sync ≡ sequential losses, serve ≡ eval
//!   logits, checkpoint-resume replay;
//! - the exported Chrome trace parses, carries the schema version, and its
//!   spans nest (never partially overlap) per thread;
//! - eval spans and `eval_time_s` attribute to the round that *triggered*
//!   the eval under `eval_every > 1` — never to the rounds after it;
//! - the JSONL event log parses line-by-line and every line is stamped
//!   with the schema version, as is `RunResult::to_json`.
//!
//! The trace flag and span sink are process-global, so every test takes
//! `test_lock()` and leaves tracing disabled + drained behind it.

use std::sync::{Arc, Mutex, MutexGuard};

use llcg::cluster::Engine;
use llcg::config::ExperimentConfig;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::obs;
use llcg::runtime::{KernelCtx, ModelState, Runtime};
use llcg::sampler::BlockBuilder;
use llcg::serve::{InferenceEngine, ModelSnapshot};
use llcg::util::{Json, Pcg64};

fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // a previous test may have panicked mid-trace: start from a clean slate
    obs::set_enabled(false);
    let _ = obs::take_spans();
    guard
}

fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "tiny".into();
    cfg.arch = "gcn".into();
    cfg.algorithm = Algorithm::Llcg;
    cfg.parts = 4;
    cfg.rounds = 4;
    cfg.schedule = Schedule::Fixed { k: 3 };
    cfg.correction_steps = 2;
    cfg.eval_every = 2;
    cfg.eval_max_nodes = 64;
    cfg.seed = 7;
    cfg
}

fn run_with(cfg: &ExperimentConfig, rt: &Runtime) -> driver::RunResult {
    let ds = generators::by_name(&cfg.dataset, cfg.seed).unwrap();
    driver::run_experiment(cfg, &ds, rt).unwrap()
}

/// The rounds on which `eval_if_due` fires for this config.
fn due_rounds(cfg: &ExperimentConfig) -> Vec<usize> {
    (1..=cfg.rounds)
        .filter(|r| r % cfg.eval_every == 0 || *r == cfg.rounds)
        .collect()
}

// ---------------------------------------------------------------------------
// bit-parity with instrumentation on
// ---------------------------------------------------------------------------

#[test]
fn training_parity_holds_with_tracing_and_metrics_on() {
    let _l = test_lock();
    let rt = native_rt();
    let cfg = base_cfg();

    // baseline: tracing off (the shipped default)
    let base = run_with(&cfg, &rt);

    // same run traced, on both engines: numbers must not move a bit
    obs::set_enabled(true);
    let seq = run_with(&cfg, &rt);
    let mut clu_cfg = cfg.clone();
    clu_cfg.engine = Engine::Cluster;
    let clu = run_with(&clu_cfg, &rt);
    obs::set_enabled(false);
    let spans = obs::take_spans();

    for (tag, res) in [("sequential", &seq), ("cluster", &clu)] {
        assert_eq!(base.records.len(), res.records.len(), "{tag}");
        for (ra, rb) in base.records.iter().zip(&res.records) {
            assert_eq!(
                ra.local_loss.to_bits(),
                rb.local_loss.to_bits(),
                "{tag} round {}: tracing perturbed the local loss",
                ra.round
            );
            assert_eq!(
                ra.global_loss.to_bits(),
                rb.global_loss.to_bits(),
                "{tag} round {}: tracing perturbed the correction stream",
                ra.round
            );
            assert_eq!(
                ra.val_score.to_bits(),
                rb.val_score.to_bits(),
                "{tag} round {}: tracing perturbed the eval stream",
                ra.round
            );
            assert_eq!(ra.comm.total(), rb.comm.total(), "{tag}");
        }
        assert_eq!(base.final_val.to_bits(), res.final_val.to_bits(), "{tag}");
        assert_eq!(base.final_test.to_bits(), res.final_test.to_bits(), "{tag}");
    }

    // the traced runs actually recorded the whole stack
    let names: std::collections::BTreeSet<&str> =
        spans.iter().map(|s| s.name).collect();
    for want in [
        "round",
        "server.average",
        "server.correction",
        "server.eval",
        "worker.round",
        "kernel.matmul",
        "sampler.build_block",
    ] {
        assert!(names.contains(want), "no `{want}` span in {names:?}");
    }
}

#[test]
fn checkpoint_resume_parity_holds_with_tracing_on() {
    let _l = test_lock();
    let rt = native_rt();
    let cfg = base_cfg();
    let full = run_with(&cfg, &rt); // untraced reference

    let dir = std::env::temp_dir()
        .join(format!("llcg_obs_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let saves0 = obs::counter("checkpoint.saves").get();
    let loads0 = obs::counter("checkpoint.loads").get();

    obs::set_enabled(true);
    let mut ck_cfg = cfg.clone();
    ck_cfg.checkpoint_every = 2;
    ck_cfg.checkpoint_dir = dir.display().to_string();
    let with_ck = run_with(&ck_cfg, &rt);

    let mut res_cfg = cfg.clone();
    res_cfg.resume = dir.join("round_2").display().to_string();
    let resumed = run_with(&res_cfg, &rt);
    obs::set_enabled(false);
    let spans = obs::take_spans();

    for (a, b) in full.records.iter().zip(&with_ck.records) {
        assert_eq!(
            a.local_loss.to_bits(),
            b.local_loss.to_bits(),
            "round {}: traced checkpointing perturbed the run",
            a.round
        );
    }
    assert_eq!(resumed.records.len(), 2, "rounds 3 and 4 remain");
    for (a, b) in full.records[2..].iter().zip(&resumed.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(
            a.local_loss.to_bits(),
            b.local_loss.to_bits(),
            "round {}: traced resume forked the local loss stream",
            a.round
        );
        assert_eq!(a.val_score.to_bits(), b.val_score.to_bits());
    }
    assert_eq!(full.final_test.to_bits(), resumed.final_test.to_bits());

    // checkpoint I/O was both counted and traced
    assert!(obs::counter("checkpoint.saves").get() >= saves0 + 2);
    assert!(obs::counter("checkpoint.loads").get() >= loads0 + 1);
    assert!(spans.iter().any(|s| s.name == "checkpoint.save"));
    assert!(spans.iter().any(|s| s.name == "checkpoint.load"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_parity_holds_with_tracing_on() {
    let _l = test_lock();
    let rt = native_rt();
    let ds = Arc::new(generators::by_name("tiny", 2).unwrap());
    let train_meta = rt
        .meta(&Runtime::train_name("gcn", "adam", "tiny"))
        .unwrap()
        .clone();
    let mut rng = Pcg64::new(7);
    let state = ModelState::init(&train_meta, &mut rng);
    let ids: Vec<u32> = ds.splits.val.iter().copied().take(50).collect();

    // reference logits from the training-side eval path, untraced
    let eval_name = Runtime::eval_name("gcn", "tiny");
    let meta = rt.meta(&eval_name).unwrap().clone();
    let bb = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        meta.multilabel(),
    );
    let want =
        driver::eval_logits(&rt, &eval_name, &state.params, &ds, &ids, &bb, &mut Pcg64::new(1))
            .unwrap();

    obs::set_enabled(true);
    let snap = Arc::new(ModelSnapshot::for_artifact(&train_meta, &state.params, 1).unwrap());
    let mut engine = InferenceEngine::new(snap, ds.clone(), KernelCtx::new(1)).unwrap();
    let mut got: Vec<f32> = Vec::new();
    for chunk in ids.chunks(7) {
        got.extend_from_slice(engine.score_batch(chunk).unwrap());
    }
    obs::set_enabled(false);
    let spans = obs::take_spans();

    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&want), bits(&got), "traced serve diverged from the eval path");
    assert!(spans.iter().any(|s| s.name == "serve.cache_build"));
}

// ---------------------------------------------------------------------------
// eval attribution under eval_every > 1
// ---------------------------------------------------------------------------

#[test]
fn eval_cost_attributes_to_the_triggering_round() {
    let _l = test_lock();
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.rounds = 5; // due rounds: 2, 4 (cadence) and 5 (final)
    let due = due_rounds(&cfg);
    assert_eq!(due, vec![2, 4, 5]);

    for engine in [Engine::Sequential, Engine::Cluster] {
        let mut c = cfg.clone();
        c.engine = engine;
        obs::set_enabled(true);
        let res = run_with(&c, &rt);
        obs::set_enabled(false);
        let spans = obs::take_spans();

        for r in &res.records {
            if due.contains(&r.round) {
                assert!(
                    r.phases.eval_s > 0.0,
                    "{engine:?} round {}: eval ran but eval_time_s is zero",
                    r.round
                );
                assert!(!r.val_score.is_nan(), "{engine:?} round {}", r.round);
            } else {
                assert_eq!(
                    r.phases.eval_s, 0.0,
                    "{engine:?} round {}: eval cost smeared into a non-eval round",
                    r.round
                );
            }
            assert!(r.phases.avg_s > 0.0, "{engine:?} round {}", r.round);
        }

        // the span round-tags say the same thing as the records
        let mut eval_rounds: Vec<i64> = spans
            .iter()
            .filter(|s| s.name == "server.eval")
            .map(|s| s.round)
            .collect();
        eval_rounds.sort_unstable();
        eval_rounds.dedup();
        let want: Vec<i64> = due.iter().map(|&r| r as i64).collect();
        assert_eq!(
            eval_rounds, want,
            "{engine:?}: server.eval spans mis-attributed"
        );
    }
}

// ---------------------------------------------------------------------------
// trace export shape
// ---------------------------------------------------------------------------

#[test]
fn chrome_trace_parses_and_spans_nest_per_thread() {
    let _l = test_lock();
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;

    obs::set_enabled(true);
    let _ = run_with(&cfg, &rt);
    obs::set_enabled(false);
    let spans = obs::take_spans();
    assert!(!spans.is_empty());

    // export parses back and carries every span + the schema stamp
    let trace = obs::chrome_trace_json(&spans);
    let parsed = Json::parse(&trace.to_string_pretty()).expect("trace JSON parses");
    assert_eq!(
        parsed.req("schema").as_f64().unwrap() as u64,
        obs::SCHEMA_VERSION
    );
    let events = parsed.req("traceEvents").as_array().unwrap();
    assert_eq!(events.len(), spans.len());
    for ev in events {
        assert_eq!(ev.req("ph").as_str().unwrap(), "X");
        assert!(ev.req("dur").as_f64().unwrap() >= 0.0);
        assert!(ev.get("name").is_some() && ev.get("ts").is_some());
        assert!(ev.get("tid").is_some() && ev.get("pid").is_some());
    }

    // per thread, spans must nest or be disjoint — a span that partially
    // overlaps its predecessor means a guard outlived its enclosing scope.
    // take_spans sorts by (tid, start, longest-first), so a stack of end
    // times is enough.
    let mut stack: Vec<(u32, u64)> = Vec::new(); // (tid, end_ns)
    for s in &spans {
        let end = s.start_ns + s.dur_ns;
        while let Some(&(tid, top_end)) = stack.last() {
            if tid != s.tid || top_end <= s.start_ns {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(&(tid, top_end)) = stack.last() {
            if tid == s.tid {
                assert!(
                    end <= top_end,
                    "span `{}` [{}, {end}] on tid {} partially overlaps its \
                     enclosing span ending at {top_end}",
                    s.name,
                    s.start_ns,
                    s.tid
                );
            }
        }
        stack.push((s.tid, end));
    }

    // summaries cover every name once
    let sums = obs::summarize(&spans);
    let names: std::collections::BTreeSet<&str> = spans.iter().map(|s| s.name).collect();
    assert_eq!(sums.len(), names.len());
    for s in &sums {
        assert!(s.count > 0 && s.total_s >= s.max_s && s.max_s >= 0.0);
    }
}

#[test]
fn traced_phase_durations_cover_the_round_wall_time() {
    // acceptance: per-round phase spans must account for the round — the
    // sum of a round's top-level phase times stays within its wall time,
    // and the `round` span itself is at least as long as any phase.
    let _l = test_lock();
    let rt = native_rt();
    let mut cfg = base_cfg();
    cfg.engine = Engine::Cluster;

    obs::set_enabled(true);
    let res = run_with(&cfg, &rt);
    obs::set_enabled(false);
    let spans = obs::take_spans();

    for r in &res.records {
        let phase_sum = r.phases.avg_s + r.phases.corr_s + r.phases.eval_s;
        assert!(
            phase_sum <= r.wall_time_s * 1.05 + 1e-3,
            "round {}: phases sum to {phase_sum}s but the round took {}s",
            r.round,
            r.wall_time_s
        );
        let round_span = spans
            .iter()
            .filter(|s| s.name == "round" && s.round == r.round as i64)
            .map(|s| s.dur_ns)
            .max()
            .unwrap_or(0);
        // the `round` span opens/closes with the round's wall clock: its
        // duration must agree with wall_time_s within 5% (+2ms slop for the
        // post-record bookkeeping before the guard drops)
        let round_span_s = round_span as f64 / 1e9;
        assert!(
            round_span_s >= r.wall_time_s * 0.95 - 2e-3
                && round_span_s <= r.wall_time_s * 1.05 + 2e-3,
            "round {}: round span {round_span_s}s vs wall {}s",
            r.round,
            r.wall_time_s
        );
        for phase in ["server.average", "server.correction", "server.eval"] {
            for s in spans
                .iter()
                .filter(|s| s.name == phase && s.round == r.round as i64)
            {
                assert!(
                    s.dur_ns <= round_span,
                    "round {}: `{phase}` span outlasted the round span",
                    r.round
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// structured outputs: JSONL log, RunResult::to_json, metrics
// ---------------------------------------------------------------------------

#[test]
fn jsonl_log_parses_and_every_line_is_schema_stamped() {
    let _l = test_lock();
    let rt = native_rt();
    let path = std::env::temp_dir().join(format!(
        "llcg_obs_events_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let mut log = obs::JsonlLog::create(&path).unwrap();
    obs::set_enabled(true);
    let cfg = base_cfg();
    let ds = Arc::new(generators::by_name(&cfg.dataset, cfg.seed).unwrap());
    let res = llcg::api::ExperimentBuilder::from_config(cfg.clone())
        .with_dataset(ds)
        .build()
        .unwrap()
        .launch(&rt)
        .stream(|ev| {
            log.write(ev.to_json()).unwrap();
        })
        .unwrap();
    obs::set_enabled(false);
    let spans = obs::take_spans();
    log.write_span_summaries(&obs::summarize(&spans)).unwrap();
    log.write_metrics().unwrap();
    log.flush().unwrap();
    let lines_written = log.lines();
    drop(log);

    let text = std::fs::read_to_string(&path).unwrap();
    let mut kinds: Vec<String> = Vec::new();
    let mut n = 0u64;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line: {e:?}\n{line}"));
        assert_eq!(
            j.req("schema").as_f64().unwrap() as u64,
            obs::SCHEMA_VERSION,
            "line missing schema stamp: {line}"
        );
        kinds.push(j.req("event").as_str().unwrap().to_string());
        n += 1;
    }
    assert_eq!(n, lines_written);
    assert_eq!(kinds.first().map(String::as_str), Some("round_started"));
    assert!(kinds.iter().any(|k| k == "finished"));
    assert!(kinds.iter().any(|k| k == "span_summary"));
    assert!(kinds.iter().any(|k| k == "metrics"));
    assert_eq!(
        kinds.iter().filter(|k| *k == "round_completed").count(),
        cfg.rounds
    );

    // the finished line embeds the full RunResult, phase timings included
    let fin_line = text
        .lines()
        .find(|l| l.contains("\"finished\""))
        .expect("finished event logged");
    let fin = Json::parse(fin_line).unwrap();
    let result = fin.req("result");
    assert_eq!(
        result.req("schema").as_f64().unwrap() as u64,
        obs::SCHEMA_VERSION
    );
    let rows = result.req("records").as_array().unwrap();
    assert_eq!(rows.len(), res.records.len());
    for row in rows {
        for key in ["avg_time_s", "corr_time_s", "eval_time_s", "wall_time_s"] {
            assert!(row.get(key).is_some(), "record row misses {key}");
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn metrics_snapshot_parses_and_percentiles_are_ordered() {
    let _l = test_lock();
    let h = obs::histogram("test.obs.latency");
    h.reset();
    let mut rng = Pcg64::new(3);
    for _ in 0..1000 {
        h.record_ns(1_000 + (rng.f32() * 1_000_000.0) as u64);
    }
    let c = obs::counter("test.obs.count");
    c.reset();
    c.add(42);

    let j = obs::metrics_json();
    let parsed = Json::parse(&j.to_string_pretty()).expect("metrics JSON parses");
    let by_name = |section: &str, name: &str| -> Json {
        parsed
            .req(section)
            .as_array()
            .unwrap()
            .iter()
            .find(|e| e.req("name").as_str() == Some(name))
            .unwrap_or_else(|| panic!("{section} misses {name}"))
            .clone()
    };
    assert_eq!(
        by_name("counters", "test.obs.count").req("value").as_f64().unwrap(),
        42.0
    );
    let lat = by_name("histograms", "test.obs.latency");
    assert_eq!(lat.req("count").as_f64().unwrap(), 1000.0);
    let p50 = lat.req("p50_s").as_f64().unwrap();
    let p99 = lat.req("p99_s").as_f64().unwrap();
    let max = lat.req("max_s").as_f64().unwrap();
    // percentiles interpolate inside power-of-two buckets, so p99 may sit
    // above the true max — but never past its bucket's upper bound (2x)
    assert!(0.0 < p50 && p50 <= p99 && p99 <= max * 2.0, "{p50} {p99} {max}");
    assert!(obs::metrics_table().contains("test.obs.latency"));
}
