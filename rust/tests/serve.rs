//! Serving-parity and server-behavior tests (acceptance criteria of the
//! serve subsystem):
//!
//! - scores from the cached serve path (embedding cache + one output-layer
//!   step) are **bit-identical** to the training-side eval path
//!   (`driver::eval_logits`, the forward behind `driver::eval_split`) for
//!   every node of a split, across batch sizes {1, 7, 64} and kernel
//!   threads {1, 4}, on every servable arch;
//! - the live micro-batching server preserves that parity under concurrent
//!   clients and across a snapshot hot-swap (versions observed to change);
//! - snapshots published through `Run::publish_to` arrive once per round on
//!   both engines, and sync-mode published params agree bit-for-bit;
//! - the load generator completes its request budget and reports sane
//!   percentiles.

use std::sync::Arc;

use llcg::api::ExperimentBuilder;
use llcg::cluster::Engine;
use llcg::coordinator::{driver, Algorithm, Schedule};
use llcg::graph::generators;
use llcg::metrics;
use llcg::runtime::{KernelCtx, ModelState, Runtime};
use llcg::sampler::BlockBuilder;
use llcg::serve::{
    run_load, InferenceEngine, LoadMode, LoadSpec, ModelSnapshot, ServeConfig, Server,
    SnapshotHub,
};
use llcg::util::Pcg64;

fn native_rt() -> Runtime {
    let (rt, _dir) =
        Runtime::load_or_native("target/native-artifacts").expect("native runtime");
    assert_eq!(rt.backend_name(), "native");
    rt
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// The training-side eval forward: full-neighbor (capped) blocks on the
/// full graph, logits in `ids` order — the reference the serve path must
/// reproduce bit-for-bit.
fn eval_reference(
    rt: &Runtime,
    eval_name: &str,
    params: &[llcg::runtime::Tensor],
    ds: &llcg::graph::Dataset,
    ids: &[u32],
) -> Vec<f32> {
    let meta = rt.meta(eval_name).unwrap().clone();
    let bb = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        meta.multilabel(),
    );
    driver::eval_logits(rt, eval_name, params, ds, ids, &bb, &mut Pcg64::new(1)).unwrap()
}

#[test]
fn serve_scores_match_eval_path_bitwise() {
    let rt = native_rt();
    // every servable arch; appnp lives on flickr-s in the shape table
    for (ds_name, arch) in [
        ("tiny", "gcn"),
        ("tiny", "sage"),
        ("tiny", "mlp"),
        ("flickr-s", "appnp"),
    ] {
        let ds = Arc::new(generators::by_name(ds_name, 2).unwrap());
        let train_meta = rt
            .meta(&Runtime::train_name(arch, "adam", ds_name))
            .unwrap()
            .clone();
        let mut rng = Pcg64::new(7);
        let state = ModelState::init(&train_meta, &mut rng);
        let ids: Vec<u32> = ds.splits.val.iter().copied().take(70).collect();
        assert!(!ids.is_empty());
        let want = eval_reference(
            &rt,
            &Runtime::eval_name(arch, ds_name),
            &state.params,
            &ds,
            &ids,
        );
        let snap =
            Arc::new(ModelSnapshot::for_artifact(&train_meta, &state.params, 1).unwrap());
        let c = train_meta.dims.c;
        for threads in [1usize, 4] {
            let mut engine =
                InferenceEngine::new(snap.clone(), ds.clone(), KernelCtx::new(threads))
                    .unwrap();
            for batch in [1usize, 7, 64] {
                let mut got: Vec<f32> = Vec::with_capacity(ids.len() * c);
                for chunk in ids.chunks(batch) {
                    got.extend_from_slice(engine.score_batch(chunk).unwrap());
                }
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "{ds_name}/{arch} threads={threads} batch={batch}: serve diverged \
                     from the eval path"
                );
            }
        }
    }
}

#[test]
fn server_preserves_parity_and_hot_swaps() {
    let rt = native_rt();
    let ds = Arc::new(generators::by_name("tiny", 4).unwrap());
    let train_meta = rt.meta("gcn_adam_tiny").unwrap().clone();
    let c = train_meta.dims.c;
    let mut rng = Pcg64::new(9);
    let before = ModelState::init(&train_meta, &mut rng);
    let after = ModelState::init(&train_meta, &mut rng);

    let hub = SnapshotHub::new();
    hub.publish(ModelSnapshot::for_artifact(&train_meta, &before.params, 1).unwrap());
    let server = Server::start(
        hub.clone(),
        ds.clone(),
        ServeConfig {
            max_batch: 8,
            flush_us: 300,
            threads: 1,
            queue: 64,
            shed: false,
        },
    )
    .unwrap();
    let client = server.client();
    let ids: Vec<u32> = ds.splits.val.iter().copied().take(24).collect();

    // concurrent clients: requests may coalesce into micro-batches, and
    // every answer must still be the snapshot-1 eval-path result
    let want1 = eval_reference(&rt, "gcn_eval_tiny", &before.params, &ds, &ids);
    std::thread::scope(|s| {
        for (k, chunk) in ids.chunks(6).enumerate() {
            let cl = client.clone();
            let want = &want1;
            let all = &ids;
            s.spawn(move || {
                for &v in chunk {
                    let r = cl.query(v).unwrap();
                    assert_eq!(r.version, 1, "pre-swap answer from wrong snapshot");
                    let i = all.iter().position(|&x| x == v).unwrap();
                    assert_eq!(
                        bits(&want[i * c..(i + 1) * c]),
                        bits(&r.scores),
                        "client {k} node {v}: served scores diverged"
                    );
                    assert_eq!(r.pred as usize, metrics::argmax(&r.scores));
                }
            });
        }
    });

    // hot-swap: publish new params; the very next batches must serve them
    hub.publish(ModelSnapshot::for_artifact(&train_meta, &after.params, 2).unwrap());
    let want2 = eval_reference(&rt, "gcn_eval_tiny", &after.params, &ds, &ids);
    for (i, &v) in ids.iter().enumerate() {
        let r = client.query(v).unwrap();
        assert_eq!(r.version, 2, "post-swap answer from stale snapshot");
        assert_eq!(
            bits(&want2[i * c..(i + 1) * c]),
            bits(&r.scores),
            "node {v}: post-swap scores diverged"
        );
    }

    // out-of-range ids error without wedging the batch loop
    assert!(client.query(ds.n() as u32 + 5).is_err());
    let ok = client.query(ids[0]).unwrap();
    assert_eq!(ok.version, 2);

    // a published snapshot the server cannot build a cache for (different
    // dataset behind a shared hub) must NOT take the server down: it keeps
    // answering from the engine it has, and a later good snapshot swaps in
    let hetero_meta = rt.meta("gcn_adam_tiny-hetero").unwrap().clone();
    let mut hrng = Pcg64::new(10);
    let hetero = ModelState::init(&hetero_meta, &mut hrng);
    hub.publish(ModelSnapshot::for_artifact(&hetero_meta, &hetero.params, 3).unwrap());
    let still = client.query(ids[0]).unwrap();
    assert_eq!(still.version, 2, "bad snapshot must not replace the engine");
    assert_eq!(bits(&want2[..c]), bits(&still.scores));
    hub.publish(ModelSnapshot::for_artifact(&train_meta, &before.params, 4).unwrap());
    let back = client.query(ids[0]).unwrap();
    assert_eq!(back.version, 4, "good snapshot after a failed one swaps in");
    assert_eq!(bits(&want1[..c]), bits(&back.scores));

    let stats = server.stats();
    assert_eq!(stats.swaps, 2, "v1->v2 and v2->v4 rebuilds");
    assert_eq!(stats.failed_swaps, 1, "the mismatched v3 publish");
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.requests, 2 * ids.len() as u64 + 3);
    assert!(stats.batches >= 1 && stats.batches <= stats.requests);
    assert!(stats.max_batch >= 1 && stats.max_batch <= 8);

    drop(client);
    server.shutdown();
}

#[test]
fn both_engines_publish_identical_per_round_snapshots() {
    let rt = native_rt();
    let ds = Arc::new(generators::by_name("tiny", 5).unwrap());
    let rounds = 3usize;
    let mut published: Vec<Vec<Vec<u32>>> = Vec::new();
    for engine in [Engine::Sequential, Engine::Cluster] {
        let exp = ExperimentBuilder::new()
            .with_dataset(ds.clone())
            .arch("gcn")
            .algorithm(Algorithm::Llcg)
            .engine(engine)
            .parts(2)
            .rounds(rounds)
            .schedule(Schedule::Fixed { k: 2 })
            .correction_steps(1)
            .eval_max_nodes(32)
            .seed(11)
            .build()
            .unwrap();
        let hub = SnapshotHub::new();
        exp.launch(&rt)
            .publish_to(hub.clone())
            .unwrap()
            .finish()
            .unwrap();
        assert_eq!(
            hub.version(),
            rounds as u64,
            "{}: one publish per round boundary",
            engine.name()
        );
        let snap = hub.current().unwrap();
        assert_eq!(snap.round, rounds);
        assert_eq!(snap.arch, "gcn");
        published.push(snap.params.iter().map(|t| bits(&t.data)).collect());
    }
    // sync-mode bit-parity extends to the published serving snapshots
    assert_eq!(
        published[0], published[1],
        "sequential and cluster engines published different final snapshots"
    );
}

#[test]
fn load_generator_completes_and_reports() {
    let rt = native_rt();
    let ds = Arc::new(generators::by_name("tiny", 6).unwrap());
    let train_meta = rt.meta("gcn_adam_tiny").unwrap().clone();
    let mut rng = Pcg64::new(13);
    let state = ModelState::init(&train_meta, &mut rng);
    let hub = SnapshotHub::new();
    hub.publish(ModelSnapshot::for_artifact(&train_meta, &state.params, 1).unwrap());
    let server = Server::start(hub, ds.clone(), ServeConfig::default()).unwrap();
    let client = server.client();
    let nodes: Vec<u32> = ds.splits.val.clone();

    let closed = run_load(
        &client,
        &nodes,
        &LoadSpec {
            mode: LoadMode::Closed,
            clients: 3,
            requests: 90,
            seed: 21,
        },
    );
    assert_eq!(closed.completed, 90);
    assert_eq!(closed.errors, 0);
    assert!(closed.throughput_rps > 0.0);
    assert!(closed.latency.p50 <= closed.latency.p95);
    assert!(closed.latency.p95 <= closed.latency.p99);

    let open = run_load(
        &client,
        &nodes,
        &LoadSpec {
            mode: LoadMode::Open { rate_rps: 2000.0 },
            clients: 3,
            requests: 60,
            seed: 21,
        },
    );
    assert_eq!(open.completed + open.errors, 60);
    assert_eq!(open.errors, 0);

    drop(client);
    server.shutdown();
}
