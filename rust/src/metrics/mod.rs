//! Evaluation metrics (micro-F1, ROC-AUC, accuracy, loss) and experiment
//! logging — the quantities every table/figure of the paper reports.

use crate::graph::Labels;

/// Micro-averaged F1.
/// - multiclass: argmax prediction — micro-F1 == accuracy;
/// - multilabel: logits > 0 ⇒ positive, F1 = 2TP / (2TP + FP + FN).
pub fn micro_f1(logits: &[f32], c: usize, labels: &Labels, ids: &[u32]) -> f64 {
    assert_eq!(logits.len(), ids.len() * c);
    match labels {
        Labels::MultiClass(y) => {
            if ids.is_empty() {
                return 0.0;
            }
            let mut correct = 0usize;
            for (i, &v) in ids.iter().enumerate() {
                let row = &logits[i * c..(i + 1) * c];
                let pred = argmax(row);
                if pred == y[v as usize] as usize {
                    correct += 1;
                }
            }
            correct as f64 / ids.len() as f64
        }
        Labels::MultiLabel { data, c: dc } => {
            assert_eq!(*dc, c);
            let mut acc = MicroF1::default();
            for (i, &v) in ids.iter().enumerate() {
                for j in 0..c {
                    let pred = logits[i * c + j] > 0.0;
                    let truth = data[v as usize * c + j] > 0.5;
                    acc.add(pred, truth);
                }
            }
            acc.value()
        }
    }
}

/// ROC-AUC averaged over classes (rank statistic; ties get midranks).
/// For multiclass labels uses one-vs-rest on the logits.
pub fn roc_auc(logits: &[f32], c: usize, labels: &Labels, ids: &[u32]) -> f64 {
    assert_eq!(logits.len(), ids.len() * c);
    let n = ids.len();
    if n == 0 {
        return 0.0;
    }
    let is_pos = |v: u32, j: usize| -> bool {
        match labels {
            Labels::MultiClass(y) => y[v as usize] as usize == j,
            Labels::MultiLabel { data, c: dc } => data[v as usize * *dc + j] > 0.5,
        }
    };
    let mut aucs = Vec::new();
    let mut scored: Vec<(f32, bool)> = Vec::with_capacity(n);
    for j in 0..c {
        scored.clear();
        for (i, &v) in ids.iter().enumerate() {
            scored.push((logits[i * c + j], is_pos(v, j)));
        }
        let pos = scored.iter().filter(|x| x.1).count();
        let neg = n - pos;
        if pos == 0 || neg == 0 {
            continue; // undefined for this class
        }
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // midrank sum of positives
        let mut rank_sum = 0f64;
        let mut i = 0usize;
        while i < n {
            let mut k = i;
            while k + 1 < n && scored[k + 1].0 == scored[i].0 {
                k += 1;
            }
            let midrank = (i + k) as f64 / 2.0 + 1.0;
            for item in &scored[i..=k] {
                if item.1 {
                    rank_sum += midrank;
                }
            }
            i = k + 1;
        }
        let u = rank_sum - (pos as f64) * (pos as f64 + 1.0) / 2.0;
        aucs.push(u / (pos as f64 * neg as f64));
    }
    if aucs.is_empty() {
        0.0
    } else {
        aucs.iter().sum::<f64>() / aucs.len() as f64
    }
}

/// One row's softmax-CE in f64 via log-sum-exp — the single source of the
/// row formula, shared by [`mean_loss`] and the device-side eval
/// reductions (`Runtime::eval_scores_device`), so the two paths cannot
/// drift apart bitwise.
pub fn row_ce_loss(row: &[f32], target: usize) -> f64 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln() + m as f64;
    lse - row[target] as f64
}

/// One row's mean sigmoid-BCE over classes in f64 — see [`row_ce_loss`]
/// for the sharing contract. `yrow` holds the 0/1 targets for this row.
pub fn row_bce_loss(row: &[f32], yrow: &[f32]) -> f64 {
    debug_assert_eq!(row.len(), yrow.len());
    let mut bce = 0f64;
    for (&zf, &yf) in row.iter().zip(yrow) {
        let z = zf as f64;
        let y = yf as f64;
        bce += z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
    }
    bce / row.len() as f64
}

/// tp/fp/fn accumulator behind multilabel micro-F1 — one counting and one
/// final-ratio rule, shared by [`micro_f1`] and `driver::eval_split`'s
/// device-side fold.
#[derive(Clone, Copy, Debug, Default)]
pub struct MicroF1 {
    tp: u64,
    fp: u64,
    fnn: u64,
}

impl MicroF1 {
    pub fn add(&mut self, pred: bool, truth: bool) {
        match (pred, truth) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, true) => self.fnn += 1,
            _ => {}
        }
    }

    /// `2TP / (2TP + FP + FN)`, 0.0 when no positives were seen at all.
    pub fn value(&self) -> f64 {
        let denom = 2 * self.tp + self.fp + self.fnn;
        if denom == 0 {
            0.0
        } else {
            (2 * self.tp) as f64 / denom as f64
        }
    }
}

/// Masked mean loss from logits, matching `model.loss_fn` semantics
/// (softmax-CE for multiclass, mean sigmoid-BCE for multilabel) — used for
/// the "global training loss" curves (Fig 4 e/f).
pub fn mean_loss(logits: &[f32], c: usize, labels: &Labels, ids: &[u32]) -> f64 {
    assert_eq!(logits.len(), ids.len() * c);
    if ids.is_empty() {
        return 0.0;
    }
    let mut total = 0f64;
    for (i, &v) in ids.iter().enumerate() {
        let row = &logits[i * c..(i + 1) * c];
        match labels {
            Labels::MultiClass(y) => {
                total += row_ce_loss(row, y[v as usize] as usize);
            }
            Labels::MultiLabel { data, c: dc } => {
                let yrow = &data[v as usize * dc..v as usize * dc + c];
                total += row_bce_loss(row, yrow);
            }
        }
    }
    total / ids.len() as f64
}

pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (j, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = j;
        }
    }
    best
}

/// Append-only CSV logger for experiment histories.
pub struct CsvLogger {
    path: std::path::PathBuf,
    wrote_header: bool,
}

impl CsvLogger {
    pub fn create(path: impl Into<std::path::PathBuf>) -> std::io::Result<CsvLogger> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, "")?;
        Ok(CsvLogger {
            path,
            wrote_header: false,
        })
    }

    pub fn row(&mut self, header: &[&str], values: &[String]) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&self.path)?;
        if !self.wrote_header {
            writeln!(f, "{}", header.join(","))?;
            self.wrote_header = true;
        }
        writeln!(f, "{}", values.join(","))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiclass_f1_is_accuracy() {
        let labels = Labels::MultiClass(vec![0, 1, 2, 1]);
        // logits for nodes 0..4, c=3
        let logits = vec![
            9.0, 0.0, 0.0, // -> 0 correct
            0.0, 9.0, 0.0, // -> 1 correct
            9.0, 0.0, 0.0, // -> 0 wrong (truth 2)
            0.0, 9.0, 0.0, // -> 1 correct
        ];
        let f1 = micro_f1(&logits, 3, &labels, &[0, 1, 2, 3]);
        assert!((f1 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn multilabel_f1() {
        let labels = Labels::MultiLabel {
            data: vec![1.0, 0.0, 1.0, 1.0],
            c: 2,
        };
        // node0: pred (+,-) truth (1,0): TP=1; node1: pred (-,+) truth (1,1): TP=1, FN=1
        let logits = vec![2.0, -2.0, -2.0, 2.0];
        let f1 = micro_f1(&logits, 2, &labels, &[0, 1]);
        assert!((f1 - 2.0 * 2.0 / (2.0 * 2.0 + 0.0 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn auc_perfect_and_random() {
        let labels = Labels::MultiClass(vec![0, 0, 1, 1]);
        // c=2 one-vs-rest; scores perfectly separate
        let logits = vec![5.0, -5.0, 4.0, -4.0, -4.0, 4.0, -5.0, 5.0];
        let auc = roc_auc(&logits, 2, &labels, &[0, 1, 2, 3]);
        assert!((auc - 1.0).abs() < 1e-12, "auc={auc}");
        // all-equal scores -> 0.5 via midranks
        let logits_tied = vec![1.0; 8];
        let auc_t = roc_auc(&logits_tied, 2, &labels, &[0, 1, 2, 3]);
        assert!((auc_t - 0.5).abs() < 1e-12, "auc={auc_t}");
    }

    #[test]
    fn loss_uniform_logits_is_log_c() {
        let labels = Labels::MultiClass(vec![0, 3]);
        let logits = vec![0.0; 8];
        let l = mean_loss(&logits, 4, &labels, &[0, 1]);
        assert!((l - (4f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn loss_decreases_with_confidence() {
        let labels = Labels::MultiClass(vec![1]);
        let weak = mean_loss(&[0.0, 1.0], 2, &labels, &[0]);
        let strong = mean_loss(&[0.0, 8.0], 2, &labels, &[0]);
        assert!(strong < weak);
    }

    #[test]
    fn csv_logger_writes_header_once() {
        let dir = std::env::temp_dir().join("llcg_test_csv");
        let path = dir.join("x.csv");
        let mut log = CsvLogger::create(&path).unwrap();
        log.row(&["a", "b"], &["1".into(), "2".into()]).unwrap();
        log.row(&["a", "b"], &["3".into(), "4".into()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
    }
}
