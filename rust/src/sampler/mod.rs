//! Neighbor sampler + dense block builder.
//!
//! Bridges the graph substrate and the AOT-compiled compute: for a batch of
//! `B` target nodes it samples a 2-hop neighborhood (Hamilton et al. style,
//! Alg. 2 line 6) and materializes the dense block format the HLO train/eval
//! steps consume (DESIGN.md §L2):
//!
//! ```text
//! level-1 slots: target i owns slots [i*f1, (i+1)*f1); slot i*f1 is the
//!                target itself (self-loop), the rest are sampled neighbors.
//! level-2 slots: level-1 slot j owns slots [j*f2, (j+1)*f2) likewise.
//! A1[i, s] = 1/(#filled slots of i)  — row-normalized mean aggregation.
//! ```
//!
//! The *same* builder serves local training (induced-subgraph adjacency =
//! "ignore cut-edges"), GGS (full adjacency + remote-feature accounting) and
//! server correction (full adjacency, full-neighbor-up-to-cap sampling).
//!
//! ## Zero-allocation pipeline
//!
//! The hot path builds one block per local step; at `reddit-s` shape that is
//! ~`n1*n2 + (b+n1+n2)*d` fresh floats per mini-batch. [`BlockArena`] recycles
//! all of that: [`BlockBuilder::build_into`] reuses the arena's block buffers
//! and sampling scratch, clearing only the slot bands that can ever hold
//! non-zeros (`n1 + n2` adjacency entries instead of `b*n1 + n1*n2`). The
//! allocating [`BlockBuilder::build`] is a thin wrapper over a throwaway
//! arena and consumes the identical RNG stream, so arena users and
//! fresh-allocation users stay bit-reproducible with each other.

use crate::graph::{CsrGraph, Dataset, Labels};
use crate::util::Pcg64;

pub const EMPTY: u32 = u32::MAX;

/// Dense mini-batch block — input payload for one HLO train/eval step.
#[derive(Clone, Debug)]
pub struct Block {
    pub b: usize,
    pub n1: usize,
    pub n2: usize,
    pub d: usize,
    pub c: usize,
    /// `[b * n1]` row-major
    pub a1: Vec<f32>,
    /// `[n1 * n2]` row-major
    pub a2: Vec<f32>,
    pub x0: Vec<f32>,
    pub x1: Vec<f32>,
    pub x2: Vec<f32>,
    /// multiclass labels (i32 for the HLO side); empty if multilabel
    pub y_class: Vec<i32>,
    /// multilabel targets `[b * c]`; empty if multiclass
    pub y_multi: Vec<f32>,
    pub mask: Vec<f32>,
    /// node behind each level-1 slot (EMPTY = padding)
    pub nodes_l1: Vec<u32>,
    /// node behind each level-2 slot (EMPTY = padding)
    pub nodes_l2: Vec<u32>,
    /// the targets themselves
    pub targets: Vec<u32>,
}

impl Block {
    fn empty() -> Block {
        Block {
            b: 0,
            n1: 0,
            n2: 0,
            d: 0,
            c: 0,
            a1: Vec::new(),
            a2: Vec::new(),
            x0: Vec::new(),
            x1: Vec::new(),
            x2: Vec::new(),
            y_class: Vec::new(),
            y_multi: Vec::new(),
            mask: Vec::new(),
            nodes_l1: Vec::new(),
            nodes_l2: Vec::new(),
            targets: Vec::new(),
        }
    }

    /// Unique real node ids touched by this block (targets + both levels).
    pub fn unique_nodes(&self) -> Vec<u32> {
        let mut all: Vec<u32> = self
            .targets
            .iter()
            .chain(self.nodes_l1.iter())
            .chain(self.nodes_l2.iter())
            .copied()
            .filter(|&v| v != EMPTY)
            .collect();
        all.sort_unstable();
        all.dedup();
        all
    }

    /// Bytes of feature data for nodes whose part != `part` under
    /// `assignment` — the GGS per-batch feature-communication cost.
    ///
    /// Convenience wrapper over [`remote_feature_bytes_with`]; allocates a
    /// fresh [`NodeScratch`] per call. Hot-path callers (the driver's
    /// per-batch accounting) should hold one scratch across batches.
    ///
    /// [`remote_feature_bytes_with`]: Block::remote_feature_bytes_with
    pub fn remote_feature_bytes(&self, assignment: &[u32], part: u32) -> u64 {
        let mut scratch = NodeScratch::new();
        self.remote_feature_bytes_with(&mut scratch, assignment, part)
    }

    /// [`remote_feature_bytes`] with caller-owned dedup scratch: a single
    /// stamped-bitmap pass over the slot arrays, no sort/dedup allocation.
    ///
    /// [`remote_feature_bytes`]: Block::remote_feature_bytes
    pub fn remote_feature_bytes_with(
        &self,
        scratch: &mut NodeScratch,
        assignment: &[u32],
        part: u32,
    ) -> u64 {
        scratch.begin(assignment.len());
        let mut remote = 0u64;
        for &v in self
            .targets
            .iter()
            .chain(self.nodes_l1.iter())
            .chain(self.nodes_l2.iter())
        {
            if v != EMPTY && scratch.insert(v) && assignment[v as usize] != part {
                remote += 1;
            }
        }
        remote * (self.d as u64) * 4
    }
}

/// Reusable "seen this node yet?" set over dense node ids: an epoch-stamped
/// array, so clearing between batches is O(1) instead of O(n).
#[derive(Clone, Debug, Default)]
pub struct NodeScratch {
    stamp: Vec<u32>,
    epoch: u32,
}

impl NodeScratch {
    pub fn new() -> NodeScratch {
        NodeScratch::default()
    }

    /// Start a new membership epoch for ids in `0..n`.
    pub fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, self.epoch);
        }
        if self.epoch == u32::MAX {
            self.stamp.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Mark `v` seen; returns true iff it was new this epoch.
    pub fn insert(&mut self, v: u32) -> bool {
        let slot = &mut self.stamp[v as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }
}

/// Sampling policy for one level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fanout {
    /// sample up to `k` neighbors uniformly without replacement
    Sample,
    /// take neighbors in order up to the slot cap ("full neighbors", capped
    /// by the static block shape — see DESIGN.md on the correction step)
    Full,
}

/// Reusable storage for the block-build hot path: the dense block buffers
/// plus the neighbor-sampling scratch. After the first build, subsequent
/// [`BlockBuilder::build_into`] calls are allocation-free.
#[derive(Clone, Debug, Default)]
pub struct BlockArena {
    block: Option<Block>,
    /// previous build's (b, n1, n2) — gates the banded adjacency clear
    prev_dims: Option<(usize, usize, usize)>,
    /// sampled-neighbor output scratch (fill_slots)
    chosen: Vec<u32>,
    /// Fisher–Yates index scratch (Pcg64::sample_without_replacement_into)
    idx: Vec<u32>,
}

impl BlockArena {
    pub fn new() -> BlockArena {
        BlockArena::default()
    }

    /// The most recently built block, if any.
    pub fn block(&self) -> Option<&Block> {
        self.block.as_ref()
    }

    /// Move the built block out (the arena re-allocates on next use).
    pub fn take_block(&mut self) -> Option<Block> {
        self.prev_dims = None;
        self.block.take()
    }
}

/// Block builder bound to one artifact's static dims.
#[derive(Clone, Debug)]
pub struct BlockBuilder {
    pub b: usize,
    pub f1: usize,
    pub f2: usize,
    pub d: usize,
    pub c: usize,
    pub multilabel: bool,
    /// neighbor sampling policy (local training samples; correction is Full)
    pub fanout: Fanout,
    /// if < 1.0, only this fraction of the fanout slots are used for
    /// neighbors (the Fig 6 "sampling ratio" knob)
    pub sample_ratio: f64,
}

impl BlockBuilder {
    pub fn new(b: usize, f1: usize, f2: usize, d: usize, c: usize, multilabel: bool) -> Self {
        BlockBuilder {
            b,
            f1,
            f2,
            d,
            c,
            multilabel,
            fanout: Fanout::Sample,
            sample_ratio: 1.0,
        }
    }

    pub fn n1(&self) -> usize {
        self.b * self.f1
    }

    pub fn n2(&self) -> usize {
        self.b * self.f1 * self.f2
    }

    /// Fill one level: node `u`'s slot group of width `f`; slot 0 is `u`
    /// itself, the rest sampled/capped neighbors. Returns filled count.
    #[allow(clippy::too_many_arguments)]
    fn fill_slots(
        &self,
        adj: &CsrGraph,
        u: u32,
        f: usize,
        out_nodes: &mut [u32],
        rng: &mut Pcg64,
        chosen: &mut Vec<u32>,
        idx: &mut Vec<u32>,
    ) -> usize {
        debug_assert_eq!(out_nodes.len(), f);
        out_nodes.fill(EMPTY);
        out_nodes[0] = u;
        let budget = (((f - 1) as f64) * self.sample_ratio).round() as usize;
        if budget == 0 {
            return 1;
        }
        let neigh = adj.neighbors(u);
        let mut cnt = 1;
        match self.fanout {
            Fanout::Sample => {
                rng.sample_without_replacement_into(neigh, budget, chosen, idx);
                for (i, &v) in chosen.iter().enumerate() {
                    out_nodes[1 + i] = v;
                    cnt += 1;
                }
            }
            Fanout::Full => {
                for (i, &v) in neigh.iter().take(budget).enumerate() {
                    out_nodes[1 + i] = v;
                    cnt += 1;
                }
            }
        }
        cnt
    }

    /// Build a block for `targets` (≤ B; short batches are padded + masked).
    ///
    /// Allocating convenience wrapper over [`build_into`]; both variants
    /// consume the same RNG stream and produce identical blocks.
    ///
    /// [`build_into`]: BlockBuilder::build_into
    pub fn build(
        &self,
        targets: &[u32],
        adj: &CsrGraph,
        ds: &Dataset,
        rng: &mut Pcg64,
    ) -> Block {
        let mut arena = BlockArena::new();
        self.build_into(&mut arena, targets, adj, ds, rng);
        arena.take_block().expect("build_into always fills the arena")
    }

    /// Build a block for `targets` into `arena`, recycling its buffers.
    /// Returns a borrow of the arena's block; the borrow ends before the
    /// next `build_into`, which overwrites it in place.
    pub fn build_into<'a>(
        &self,
        arena: &'a mut BlockArena,
        targets: &[u32],
        adj: &CsrGraph,
        ds: &Dataset,
        rng: &mut Pcg64,
    ) -> &'a Block {
        let _s = crate::obs::span("sampler.build_block");
        assert!(targets.len() <= self.b, "batch larger than block B");
        assert_eq!(ds.d, self.d, "dataset d mismatch");
        let (b, f1, f2, d, c) = (self.b, self.f1, self.f2, self.d, self.c);
        let (n1, n2) = (self.n1(), self.n2());

        let BlockArena {
            block,
            prev_dims,
            chosen,
            idx,
        } = arena;
        let blk = block.get_or_insert_with(Block::empty);

        // -- (re)shape + clear -------------------------------------------
        // Adjacency non-zeros only ever land in the per-slot-group bands
        // (row i of A1 in cols [i*f1, (i+1)*f1); row j of A2 in cols
        // [j*f2, (j+1)*f2)), so on same-shape reuse clearing those bands —
        // n1 + n2 floats — replaces zeroing the full b*n1 + n1*n2 matrices.
        let same_shape = *prev_dims == Some((b, n1, n2));
        blk.b = b;
        blk.n1 = n1;
        blk.n2 = n2;
        blk.d = d;
        blk.c = c;
        blk.a1.resize(b * n1, 0.0);
        blk.a2.resize(n1 * n2, 0.0);
        blk.x0.resize(b * d, 0.0);
        blk.x1.resize(n1 * d, 0.0);
        blk.x2.resize(n2 * d, 0.0);
        blk.mask.resize(b, 0.0);
        blk.targets.resize(b, EMPTY);
        blk.nodes_l1.resize(n1, EMPTY);
        blk.nodes_l2.resize(n2, EMPTY);
        if same_shape {
            for i in 0..b {
                blk.a1[i * n1 + i * f1..i * n1 + (i + 1) * f1].fill(0.0);
            }
            for j in 0..n1 {
                blk.a2[j * n2 + j * f2..j * n2 + (j + 1) * f2].fill(0.0);
            }
        } else {
            blk.a1.fill(0.0);
            blk.a2.fill(0.0);
        }
        *prev_dims = Some((b, n1, n2));
        blk.mask.fill(0.0);
        blk.targets.fill(EMPTY);
        blk.nodes_l1.fill(EMPTY);
        blk.nodes_l2.fill(EMPTY);

        // -- sample + adjacency ------------------------------------------
        for (i, &t) in targets.iter().enumerate() {
            blk.targets[i] = t;
            blk.mask[i] = 1.0;
            let slots = &mut blk.nodes_l1[i * f1..(i + 1) * f1];
            let cnt = self.fill_slots(adj, t, f1, slots, rng, chosen, idx);
            let w = 1.0 / cnt as f32;
            for s in 0..f1 {
                if blk.nodes_l1[i * f1 + s] != EMPTY {
                    blk.a1[i * n1 + i * f1 + s] = w;
                }
            }
        }
        for j in 0..n1 {
            let u = blk.nodes_l1[j];
            if u == EMPTY {
                continue;
            }
            let slots_start = j * f2;
            let cnt = {
                let slots = &mut blk.nodes_l2[slots_start..slots_start + f2];
                self.fill_slots(adj, u, f2, slots, rng, chosen, idx)
            };
            let w = 1.0 / cnt as f32;
            for s in 0..f2 {
                if blk.nodes_l2[slots_start + s] != EMPTY {
                    blk.a2[j * n2 + slots_start + s] = w;
                }
            }
        }

        // -- feature gathers (every slot written; zeros for EMPTY) --------
        fn gather_into(out: &mut [f32], nodes: &[u32], ds: &Dataset, d: usize) {
            for (i, &v) in nodes.iter().enumerate() {
                let dst = &mut out[i * d..(i + 1) * d];
                if v == EMPTY {
                    dst.fill(0.0);
                } else {
                    dst.copy_from_slice(ds.feature(v));
                }
            }
        }
        gather_into(&mut blk.x0, &blk.targets, ds, d);
        gather_into(&mut blk.x1, &blk.nodes_l1, ds, d);
        gather_into(&mut blk.x2, &blk.nodes_l2, ds, d);

        // -- labels (every row written) ----------------------------------
        match (&ds.labels, self.multilabel) {
            (Labels::MultiClass(y), false) => {
                blk.y_multi.clear();
                blk.y_class.resize(b, 0);
                for (i, &t) in blk.targets.iter().enumerate() {
                    blk.y_class[i] = if t == EMPTY { 0 } else { y[t as usize] as i32 };
                }
            }
            (Labels::MultiLabel { data, c: dc }, true) => {
                assert_eq!(*dc, c, "label dim mismatch");
                blk.y_class.clear();
                blk.y_multi.resize(b * c, 0.0);
                for (i, &t) in blk.targets.iter().enumerate() {
                    let dst = &mut blk.y_multi[i * c..(i + 1) * c];
                    if t == EMPTY {
                        dst.fill(0.0);
                    } else {
                        dst.copy_from_slice(&data[t as usize * c..(t as usize + 1) * c]);
                    }
                }
            }
            _ => panic!("label kind / builder multilabel flag mismatch"),
        }

        blk
    }
}

/// Iterate over `ids` in seeded-shuffled mini-batches of size `b`.
pub struct BatchIter {
    ids: Vec<u32>,
    pos: usize,
    b: usize,
}

impl BatchIter {
    pub fn new(ids: &[u32], b: usize, rng: &mut Pcg64) -> Self {
        let mut ids = ids.to_vec();
        rng.shuffle(&mut ids);
        BatchIter { ids, pos: 0, b }
    }

    /// Batches left before the iterator is exhausted.
    pub fn remaining(&self) -> usize {
        (self.ids.len() - self.pos).div_ceil(self.b)
    }

    /// Restart a fresh epoch: reshuffle in place and rewind. Draws the same
    /// *amount* of RNG state as constructing a new `BatchIter`, but permutes
    /// the already-shuffled order (not the caller's original id order), so
    /// epoch ≥ 2 batch sequences differ from repeated `BatchIter::new`.
    pub fn reshuffle(&mut self, rng: &mut Pcg64) {
        rng.shuffle(&mut self.ids);
        self.pos = 0;
    }

    /// Borrowing, allocation-free variant of `next`.
    pub fn next_batch(&mut self) -> Option<&[u32]> {
        if self.pos >= self.ids.len() {
            return None;
        }
        let end = (self.pos + self.b).min(self.ids.len());
        let out = &self.ids[self.pos..end];
        self.pos = end;
        Some(out)
    }
}

impl Iterator for BatchIter {
    type Item = Vec<u32>;

    fn next(&mut self) -> Option<Vec<u32>> {
        self.next_batch().map(|s| s.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn setup() -> (Dataset, BlockBuilder, Pcg64) {
        let ds = generators::by_name("tiny", 0).unwrap();
        let bb = BlockBuilder::new(8, 4, 4, ds.d, ds.c(), false);
        (ds, bb, Pcg64::new(1))
    }

    fn assert_blocks_equal(a: &Block, b: &Block, what: &str) {
        assert_eq!(a.b, b.b, "{what}: b");
        assert_eq!(a.n1, b.n1, "{what}: n1");
        assert_eq!(a.n2, b.n2, "{what}: n2");
        assert_eq!(a.a1, b.a1, "{what}: a1");
        assert_eq!(a.a2, b.a2, "{what}: a2");
        assert_eq!(a.x0, b.x0, "{what}: x0");
        assert_eq!(a.x1, b.x1, "{what}: x1");
        assert_eq!(a.x2, b.x2, "{what}: x2");
        assert_eq!(a.y_class, b.y_class, "{what}: y_class");
        assert_eq!(a.y_multi, b.y_multi, "{what}: y_multi");
        assert_eq!(a.mask, b.mask, "{what}: mask");
        assert_eq!(a.nodes_l1, b.nodes_l1, "{what}: nodes_l1");
        assert_eq!(a.nodes_l2, b.nodes_l2, "{what}: nodes_l2");
        assert_eq!(a.targets, b.targets, "{what}: targets");
    }

    #[test]
    fn rows_are_normalized() {
        let (ds, bb, mut rng) = setup();
        let targets: Vec<u32> = (0..8).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for i in 0..blk.b {
            let row: f32 = blk.a1[i * blk.n1..(i + 1) * blk.n1].iter().sum();
            assert!((row - 1.0).abs() < 1e-5, "a1 row {i} sums to {row}");
        }
        for j in 0..blk.n1 {
            let row: f32 = blk.a2[j * blk.n2..(j + 1) * blk.n2].iter().sum();
            if blk.nodes_l1[j] == EMPTY {
                assert_eq!(row, 0.0, "padding row {j} not zero");
            } else {
                assert!((row - 1.0).abs() < 1e-5, "a2 row {j} sums to {row}");
            }
        }
    }

    #[test]
    fn slot_zero_is_self() {
        let (ds, bb, mut rng) = setup();
        let targets: Vec<u32> = vec![5, 9, 13];
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for (i, &t) in targets.iter().enumerate() {
            assert_eq!(blk.nodes_l1[i * bb.f1], t);
            assert_eq!(blk.nodes_l2[i * bb.f1 * bb.f2], t);
        }
    }

    #[test]
    fn short_batch_masked() {
        let (ds, bb, mut rng) = setup();
        let blk = bb.build(&[1, 2, 3], &ds.graph, &ds, &mut rng);
        assert_eq!(&blk.mask[..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&blk.mask[3..], &[0.0; 5]);
        // padded target rows of A1 must be all-zero
        for i in 3..8 {
            assert!(blk.a1[i * blk.n1..(i + 1) * blk.n1].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn sampled_neighbors_are_real_neighbors() {
        let (ds, bb, mut rng) = setup();
        let targets: Vec<u32> = (20..28).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for (i, &t) in targets.iter().enumerate() {
            for s in 1..bb.f1 {
                let v = blk.nodes_l1[i * bb.f1 + s];
                if v != EMPTY {
                    assert!(ds.graph.neighbors(t).contains(&v), "{v} not nbr of {t}");
                }
            }
        }
    }

    #[test]
    fn induced_view_never_crosses_parts() {
        let (ds, bb, mut rng) = setup();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let local = ds.graph.induced_view(&assignment, 0);
        let targets: Vec<u32> = (0..ds.n() as u32)
            .filter(|&v| assignment[v as usize] == 0)
            .take(8)
            .collect();
        let blk = bb.build(&targets, &local, &ds, &mut rng);
        for &v in blk.nodes_l1.iter().chain(&blk.nodes_l2) {
            if v != EMPTY {
                assert_eq!(assignment[v as usize], 0, "cut-edge node {v} leaked in");
            }
        }
        assert_eq!(blk.remote_feature_bytes(&assignment, 0), 0);
    }

    #[test]
    fn remote_bytes_counted_on_global_view() {
        let (ds, bb, mut rng) = setup();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let targets: Vec<u32> = (0..ds.n() as u32)
            .filter(|&v| assignment[v as usize] == 0)
            .take(8)
            .collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        // with ~alternating assignment the 2-hop set will contain remotes
        assert!(blk.remote_feature_bytes(&assignment, 0) > 0);
        // and the bytes are 4*d per unique remote node
        let uniq = blk.unique_nodes();
        let remote = uniq.iter().filter(|&&v| assignment[v as usize] != 0).count();
        assert_eq!(
            blk.remote_feature_bytes(&assignment, 0),
            (remote * ds.d * 4) as u64
        );
    }

    #[test]
    fn remote_bytes_scratch_reuse_matches_fresh() {
        // independent oracle: the sort+dedup path (unique_nodes), not the
        // stamped bitmap comparing against itself
        let (ds, bb, mut rng) = setup();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 4).collect();
        let mut scratch = NodeScratch::new();
        for round in 0..5 {
            let targets: Vec<u32> = (round * 8..round * 8 + 8).map(|v| v as u32).collect();
            let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
            for part in 0..4 {
                let expected = blk
                    .unique_nodes()
                    .iter()
                    .filter(|&&v| assignment[v as usize] != part)
                    .count() as u64
                    * (blk.d as u64)
                    * 4;
                assert_eq!(
                    blk.remote_feature_bytes_with(&mut scratch, &assignment, part),
                    expected,
                    "round {round} part {part} (reused scratch)"
                );
                assert_eq!(
                    blk.remote_feature_bytes(&assignment, part),
                    expected,
                    "round {round} part {part} (fresh scratch)"
                );
            }
        }
    }

    #[test]
    fn arena_reuse_produces_identical_blocks() {
        // two consecutive builds into one arena == two fresh allocations,
        // including the RNG stream (sampled neighbors must match too)
        let (ds, bb, mut rng_fresh) = setup();
        let mut rng_arena = Pcg64::new(1);
        let mut arena = BlockArena::new();
        let batches: [Vec<u32>; 3] = [
            (0..8).collect(),
            (100..105).collect(), // short batch: padding must be re-cleared
            (40..48).collect(),
        ];
        for (k, batch) in batches.iter().enumerate() {
            let fresh = bb.build(batch, &ds.graph, &ds, &mut rng_fresh);
            let reused = bb.build_into(&mut arena, batch, &ds.graph, &ds, &mut rng_arena);
            assert_blocks_equal(&fresh, reused, &format!("batch {k}"));
        }
    }

    #[test]
    fn arena_survives_builder_and_fanout_changes() {
        let (ds, bb, mut rng) = setup();
        let mut arena = BlockArena::new();
        bb.build_into(&mut arena, &[0, 1, 2], &ds.graph, &ds, &mut rng);
        // a different (smaller) shape + full fanout through the same arena
        let mut bb2 = BlockBuilder::new(4, 3, 2, ds.d, ds.c(), false);
        bb2.fanout = Fanout::Full;
        let mut rng_fresh = Pcg64::new(77);
        let mut rng_arena = Pcg64::new(77);
        let fresh = bb2.build(&[5, 6], &ds.graph, &ds, &mut rng_fresh);
        let reused = bb2.build_into(&mut arena, &[5, 6], &ds.graph, &ds, &mut rng_arena);
        assert_blocks_equal(&fresh, reused, "after shape change");
    }

    #[test]
    fn sample_ratio_shrinks_fanout() {
        let (ds, mut bb, mut rng) = setup();
        bb.sample_ratio = 0.34; // 1 of 3 neighbor slots
        let targets: Vec<u32> = (0..8).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for i in 0..8 {
            let filled = blk.nodes_l1[i * bb.f1..(i + 1) * bb.f1]
                .iter()
                .filter(|&&v| v != EMPTY)
                .count();
            assert!(filled <= 2, "row {i} has {filled} slots at ratio 0.34");
        }
    }

    #[test]
    fn full_fanout_is_deterministic_prefix() {
        let (ds, mut bb, mut rng) = setup();
        bb.fanout = Fanout::Full;
        let t = 3u32;
        let blk1 = bb.build(&[t], &ds.graph, &ds, &mut rng);
        let blk2 = bb.build(&[t], &ds.graph, &ds, &mut rng);
        assert_eq!(blk1.nodes_l1, blk2.nodes_l1);
        let nbrs = ds.graph.neighbors(t);
        for s in 1..bb.f1.min(nbrs.len() + 1) {
            assert_eq!(blk1.nodes_l1[s], nbrs[s - 1]);
        }
    }

    #[test]
    fn batch_iter_covers_all_ids() {
        let ids: Vec<u32> = (0..23).collect();
        let mut rng = Pcg64::new(9);
        let mut seen: Vec<u32> = BatchIter::new(&ids, 5, &mut rng).flatten().collect();
        assert_eq!(seen.len(), 23);
        seen.sort_unstable();
        assert_eq!(seen, ids);
    }

    #[test]
    fn batch_iter_reshuffle_matches_fresh_iter() {
        let ids: Vec<u32> = (0..17).collect();
        let mut rng_a = Pcg64::new(4);
        let mut rng_b = Pcg64::new(4);
        let mut it = BatchIter::new(&ids, 5, &mut rng_a);
        while it.next_batch().is_some() {}
        assert_eq!(it.remaining(), 0);
        it.reshuffle(&mut rng_a);
        // a fresh iter over the *shuffled* order with the same rng stream
        let mut ids_b = ids.clone();
        rng_b.shuffle(&mut ids_b);
        let fresh = BatchIter::new(&ids_b, 5, &mut rng_b);
        let a: Vec<Vec<u32>> = std::iter::from_fn(|| it.next_batch().map(|s| s.to_vec())).collect();
        let b: Vec<Vec<u32>> = fresh.collect();
        assert_eq!(a, b);
        assert!(a.iter().flatten().count() == 17);
    }

    #[test]
    fn multilabel_blocks() {
        let ds = generators::by_name("proteins-s", 0).unwrap();
        let bb = BlockBuilder::new(4, 3, 3, ds.d, ds.c(), true);
        let mut rng = Pcg64::new(2);
        let blk = bb.build(&[0, 1], &ds.graph, &ds, &mut rng);
        assert!(blk.y_class.is_empty());
        assert_eq!(blk.y_multi.len(), 4 * ds.c());
        assert!(blk.y_multi.iter().all(|&x| x == 0.0 || x == 1.0));
    }
}
