//! Neighbor sampler + dense block builder.
//!
//! Bridges the graph substrate and the AOT-compiled compute: for a batch of
//! `B` target nodes it samples a 2-hop neighborhood (Hamilton et al. style,
//! Alg. 2 line 6) and materializes the dense block format the HLO train/eval
//! steps consume (DESIGN.md §L2):
//!
//! ```text
//! level-1 slots: target i owns slots [i*f1, (i+1)*f1); slot i*f1 is the
//!                target itself (self-loop), the rest are sampled neighbors.
//! level-2 slots: level-1 slot j owns slots [j*f2, (j+1)*f2) likewise.
//! A1[i, s] = 1/(#filled slots of i)  — row-normalized mean aggregation.
//! ```
//!
//! The *same* builder serves local training (induced-subgraph adjacency =
//! "ignore cut-edges"), GGS (full adjacency + remote-feature accounting) and
//! server correction (full adjacency, full-neighbor-up-to-cap sampling).

use crate::graph::{CsrGraph, Dataset, Labels};
use crate::util::Pcg64;

pub const EMPTY: u32 = u32::MAX;

/// Dense mini-batch block — input payload for one HLO train/eval step.
#[derive(Clone, Debug)]
pub struct Block {
    pub b: usize,
    pub n1: usize,
    pub n2: usize,
    pub d: usize,
    pub c: usize,
    /// `[b * n1]` row-major
    pub a1: Vec<f32>,
    /// `[n1 * n2]` row-major
    pub a2: Vec<f32>,
    pub x0: Vec<f32>,
    pub x1: Vec<f32>,
    pub x2: Vec<f32>,
    /// multiclass labels (i32 for the HLO side); empty if multilabel
    pub y_class: Vec<i32>,
    /// multilabel targets `[b * c]`; empty if multiclass
    pub y_multi: Vec<f32>,
    pub mask: Vec<f32>,
    /// node behind each level-1 slot (EMPTY = padding)
    pub nodes_l1: Vec<u32>,
    /// node behind each level-2 slot (EMPTY = padding)
    pub nodes_l2: Vec<u32>,
    /// the targets themselves
    pub targets: Vec<u32>,
}

impl Block {
    /// Unique real node ids touched by this block (targets + both levels).
    pub fn unique_nodes(&self) -> Vec<u32> {
        let mut all: Vec<u32> = self
            .targets
            .iter()
            .chain(self.nodes_l1.iter())
            .chain(self.nodes_l2.iter())
            .copied()
            .filter(|&v| v != EMPTY)
            .collect();
        all.sort_unstable();
        all.dedup();
        all
    }

    /// Bytes of feature data for nodes whose part != `part` under
    /// `assignment` — the GGS per-batch feature-communication cost.
    pub fn remote_feature_bytes(&self, assignment: &[u32], part: u32) -> u64 {
        let remote = self
            .unique_nodes()
            .into_iter()
            .filter(|&v| assignment[v as usize] != part)
            .count() as u64;
        remote * (self.d as u64) * 4
    }
}

/// Sampling policy for one level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fanout {
    /// sample up to `k` neighbors uniformly without replacement
    Sample,
    /// take neighbors in order up to the slot cap ("full neighbors", capped
    /// by the static block shape — see DESIGN.md on the correction step)
    Full,
}

/// Block builder bound to one artifact's static dims.
#[derive(Clone, Debug)]
pub struct BlockBuilder {
    pub b: usize,
    pub f1: usize,
    pub f2: usize,
    pub d: usize,
    pub c: usize,
    pub multilabel: bool,
    /// neighbor sampling policy (local training samples; correction is Full)
    pub fanout: Fanout,
    /// if < 1.0, only this fraction of the fanout slots are used for
    /// neighbors (the Fig 6 "sampling ratio" knob)
    pub sample_ratio: f64,
}

impl BlockBuilder {
    pub fn new(b: usize, f1: usize, f2: usize, d: usize, c: usize, multilabel: bool) -> Self {
        BlockBuilder {
            b,
            f1,
            f2,
            d,
            c,
            multilabel,
            fanout: Fanout::Sample,
            sample_ratio: 1.0,
        }
    }

    pub fn n1(&self) -> usize {
        self.b * self.f1
    }

    pub fn n2(&self) -> usize {
        self.b * self.f1 * self.f2
    }

    /// Fill one level: node `u`'s slot group of width `f`; slot 0 is `u`
    /// itself, the rest sampled/capped neighbors. Returns filled count.
    fn fill_slots(
        &self,
        adj: &CsrGraph,
        u: u32,
        f: usize,
        out_nodes: &mut [u32],
        rng: &mut Pcg64,
    ) -> usize {
        debug_assert_eq!(out_nodes.len(), f);
        out_nodes.fill(EMPTY);
        out_nodes[0] = u;
        let budget = (((f - 1) as f64) * self.sample_ratio).round() as usize;
        if budget == 0 {
            return 1;
        }
        let neigh = adj.neighbors(u);
        let chosen: Vec<u32> = match self.fanout {
            Fanout::Sample => rng.sample_without_replacement(neigh, budget),
            Fanout::Full => neigh.iter().copied().take(budget).collect(),
        };
        let mut cnt = 1;
        for (i, v) in chosen.into_iter().enumerate() {
            out_nodes[1 + i] = v;
            cnt += 1;
        }
        cnt
    }

    /// Build a block for `targets` (≤ B; short batches are padded + masked).
    pub fn build(
        &self,
        targets: &[u32],
        adj: &CsrGraph,
        ds: &Dataset,
        rng: &mut Pcg64,
    ) -> Block {
        assert!(targets.len() <= self.b, "batch larger than block B");
        assert_eq!(ds.d, self.d, "dataset d mismatch");
        let (b, f1, f2, d, c) = (self.b, self.f1, self.f2, self.d, self.c);
        let (n1, n2) = (self.n1(), self.n2());

        let mut nodes_l1 = vec![EMPTY; n1];
        let mut nodes_l2 = vec![EMPTY; n2];
        let mut a1 = vec![0f32; b * n1];
        let mut a2 = vec![0f32; n1 * n2];
        let mut mask = vec![0f32; b];
        let mut padded_targets = vec![EMPTY; b];

        for (i, &t) in targets.iter().enumerate() {
            padded_targets[i] = t;
            mask[i] = 1.0;
            let slots = &mut nodes_l1[i * f1..(i + 1) * f1];
            let cnt = self.fill_slots(adj, t, f1, slots, rng);
            let w = 1.0 / cnt as f32;
            for s in 0..f1 {
                if nodes_l1[i * f1 + s] != EMPTY {
                    a1[i * n1 + i * f1 + s] = w;
                }
            }
        }
        for j in 0..n1 {
            let u = nodes_l1[j];
            if u == EMPTY {
                continue;
            }
            let slots_start = j * f2;
            let cnt = {
                let slots = &mut nodes_l2[slots_start..slots_start + f2];
                self.fill_slots(adj, u, f2, slots, rng)
            };
            let w = 1.0 / cnt as f32;
            for s in 0..f2 {
                if nodes_l2[slots_start + s] != EMPTY {
                    a2[j * n2 + slots_start + s] = w;
                }
            }
        }

        // feature gathers (zeros for EMPTY slots)
        let gather = |nodes: &[u32]| {
            let mut out = vec![0f32; nodes.len() * d];
            for (i, &v) in nodes.iter().enumerate() {
                if v != EMPTY {
                    out[i * d..(i + 1) * d].copy_from_slice(ds.feature(v));
                }
            }
            out
        };
        let x0 = gather(&padded_targets);
        let x1 = gather(&nodes_l1);
        let x2 = gather(&nodes_l2);

        // labels
        let mut y_class = Vec::new();
        let mut y_multi = Vec::new();
        match (&ds.labels, self.multilabel) {
            (Labels::MultiClass(y), false) => {
                y_class = padded_targets
                    .iter()
                    .map(|&t| if t == EMPTY { 0 } else { y[t as usize] as i32 })
                    .collect();
            }
            (Labels::MultiLabel { data, c: dc }, true) => {
                assert_eq!(*dc, c, "label dim mismatch");
                y_multi = vec![0f32; b * c];
                for (i, &t) in padded_targets.iter().enumerate() {
                    if t != EMPTY {
                        y_multi[i * c..(i + 1) * c]
                            .copy_from_slice(&data[t as usize * c..(t as usize + 1) * c]);
                    }
                }
            }
            _ => panic!("label kind / builder multilabel flag mismatch"),
        }

        Block {
            b,
            n1,
            n2,
            d,
            c,
            a1,
            a2,
            x0,
            x1,
            x2,
            y_class,
            y_multi,
            mask,
            nodes_l1,
            nodes_l2,
            targets: padded_targets,
        }
    }
}

/// Iterate over `ids` in seeded-shuffled mini-batches of size `b`.
pub struct BatchIter {
    ids: Vec<u32>,
    pos: usize,
    b: usize,
}

impl BatchIter {
    pub fn new(ids: &[u32], b: usize, rng: &mut Pcg64) -> Self {
        let mut ids = ids.to_vec();
        rng.shuffle(&mut ids);
        BatchIter { ids, pos: 0, b }
    }
}

impl Iterator for BatchIter {
    type Item = Vec<u32>;

    fn next(&mut self) -> Option<Vec<u32>> {
        if self.pos >= self.ids.len() {
            return None;
        }
        let end = (self.pos + self.b).min(self.ids.len());
        let out = self.ids[self.pos..end].to_vec();
        self.pos = end;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn setup() -> (Dataset, BlockBuilder, Pcg64) {
        let ds = generators::by_name("tiny", 0).unwrap();
        let bb = BlockBuilder::new(8, 4, 4, ds.d, ds.c(), false);
        (ds, bb, Pcg64::new(1))
    }

    #[test]
    fn rows_are_normalized() {
        let (ds, bb, mut rng) = setup();
        let targets: Vec<u32> = (0..8).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for i in 0..blk.b {
            let row: f32 = blk.a1[i * blk.n1..(i + 1) * blk.n1].iter().sum();
            assert!((row - 1.0).abs() < 1e-5, "a1 row {i} sums to {row}");
        }
        for j in 0..blk.n1 {
            let row: f32 = blk.a2[j * blk.n2..(j + 1) * blk.n2].iter().sum();
            if blk.nodes_l1[j] == EMPTY {
                assert_eq!(row, 0.0, "padding row {j} not zero");
            } else {
                assert!((row - 1.0).abs() < 1e-5, "a2 row {j} sums to {row}");
            }
        }
    }

    #[test]
    fn slot_zero_is_self() {
        let (ds, bb, mut rng) = setup();
        let targets: Vec<u32> = vec![5, 9, 13];
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for (i, &t) in targets.iter().enumerate() {
            assert_eq!(blk.nodes_l1[i * bb.f1], t);
            assert_eq!(blk.nodes_l2[i * bb.f1 * bb.f2], t);
        }
    }

    #[test]
    fn short_batch_masked() {
        let (ds, bb, mut rng) = setup();
        let blk = bb.build(&[1, 2, 3], &ds.graph, &ds, &mut rng);
        assert_eq!(&blk.mask[..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&blk.mask[3..], &[0.0; 5]);
        // padded target rows of A1 must be all-zero
        for i in 3..8 {
            assert!(blk.a1[i * blk.n1..(i + 1) * blk.n1].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn sampled_neighbors_are_real_neighbors() {
        let (ds, bb, mut rng) = setup();
        let targets: Vec<u32> = (20..28).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for (i, &t) in targets.iter().enumerate() {
            for s in 1..bb.f1 {
                let v = blk.nodes_l1[i * bb.f1 + s];
                if v != EMPTY {
                    assert!(ds.graph.neighbors(t).contains(&v), "{v} not nbr of {t}");
                }
            }
        }
    }

    #[test]
    fn induced_view_never_crosses_parts() {
        let (ds, bb, mut rng) = setup();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let local = ds.graph.induced_view(&assignment, 0);
        let targets: Vec<u32> = (0..ds.n() as u32)
            .filter(|&v| assignment[v as usize] == 0)
            .take(8)
            .collect();
        let blk = bb.build(&targets, &local, &ds, &mut rng);
        for &v in blk.nodes_l1.iter().chain(&blk.nodes_l2) {
            if v != EMPTY {
                assert_eq!(assignment[v as usize], 0, "cut-edge node {v} leaked in");
            }
        }
        assert_eq!(blk.remote_feature_bytes(&assignment, 0), 0);
    }

    #[test]
    fn remote_bytes_counted_on_global_view() {
        let (ds, bb, mut rng) = setup();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let targets: Vec<u32> = (0..ds.n() as u32)
            .filter(|&v| assignment[v as usize] == 0)
            .take(8)
            .collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        // with ~alternating assignment the 2-hop set will contain remotes
        assert!(blk.remote_feature_bytes(&assignment, 0) > 0);
        // and the bytes are 4*d per unique remote node
        let uniq = blk.unique_nodes();
        let remote = uniq.iter().filter(|&&v| assignment[v as usize] != 0).count();
        assert_eq!(
            blk.remote_feature_bytes(&assignment, 0),
            (remote * ds.d * 4) as u64
        );
    }

    #[test]
    fn sample_ratio_shrinks_fanout() {
        let (ds, mut bb, mut rng) = setup();
        bb.sample_ratio = 0.34; // 1 of 3 neighbor slots
        let targets: Vec<u32> = (0..8).collect();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        for i in 0..8 {
            let filled = blk.nodes_l1[i * bb.f1..(i + 1) * bb.f1]
                .iter()
                .filter(|&&v| v != EMPTY)
                .count();
            assert!(filled <= 2, "row {i} has {filled} slots at ratio 0.34");
        }
    }

    #[test]
    fn full_fanout_is_deterministic_prefix() {
        let (ds, mut bb, mut rng) = setup();
        bb.fanout = Fanout::Full;
        let t = 3u32;
        let blk1 = bb.build(&[t], &ds.graph, &ds, &mut rng);
        let blk2 = bb.build(&[t], &ds.graph, &ds, &mut rng);
        assert_eq!(blk1.nodes_l1, blk2.nodes_l1);
        let nbrs = ds.graph.neighbors(t);
        for s in 1..bb.f1.min(nbrs.len() + 1) {
            assert_eq!(blk1.nodes_l1[s], nbrs[s - 1]);
        }
    }

    #[test]
    fn batch_iter_covers_all_ids() {
        let ids: Vec<u32> = (0..23).collect();
        let mut rng = Pcg64::new(9);
        let mut seen: Vec<u32> = BatchIter::new(&ids, 5, &mut rng).flatten().collect();
        assert_eq!(seen.len(), 23);
        seen.sort_unstable();
        assert_eq!(seen, ids);
    }

    #[test]
    fn multilabel_blocks() {
        let ds = generators::by_name("proteins-s", 0).unwrap();
        let bb = BlockBuilder::new(4, 3, 3, ds.d, ds.c(), true);
        let mut rng = Pcg64::new(2);
        let blk = bb.build(&[0, 1], &ds.graph, &ds, &mut rng);
        assert!(blk.y_class.is_empty());
        assert_eq!(blk.y_multi.len(), 4 * ds.c());
        assert!(blk.y_multi.iter().all(|&x| x == 0.0 || x == 1.0));
    }
}
