//! `llcg::api` — the crate's public experiment API.
//!
//! Four pieces, layered over the coordinator/cluster engines:
//!
//! - [`keys`] — the single-source config schema: every `ExperimentConfig`
//!   key is one [`keys::KeySpec`] row; JSON parsing, CLI overrides,
//!   unknown-key errors, and the `llcg run --help` table derive from it.
//! - [`registry`] — name-keyed, pluggable registries for datasets,
//!   partitioners, and architectures, with `list()`-backed validation
//!   errors and CLI listings.
//! - [`session`] — typed construction ([`ExperimentBuilder`] → validated
//!   [`Experiment`]) and streaming execution ([`Experiment::launch`] →
//!   [`Run`] emitting [`Event`]s, with [`RunControl`] early-stop). Both
//!   engines emit the identical sync-mode event sequence through shared
//!   driver helpers.
//! - [`sweep`] — config grids ([`Sweep`]) that reuse the loaded dataset
//!   and partition assignment across points.
//!
//! ```text
//! let (rt, _) = Runtime::load_or_native("artifacts")?;
//! let exp = ExperimentBuilder::new()
//!     .dataset("tiny")
//!     .algorithm(Algorithm::Llcg)
//!     .parts(4)
//!     .rounds(10)
//!     .build()?;
//! let result = exp.launch(&rt).stream(|ev| {
//!     if let Event::RoundCompleted(r) = ev {
//!         println!("round {}: loss {:.4}", r.round, r.local_loss);
//!     }
//! })?;
//! println!("final val {:.4}", result.final_val);
//! ```

pub mod keys;
pub mod registry;
pub mod session;
pub mod sweep;

pub use keys::{KeyKind, KeySpec};
pub use registry::{ArchEntry, DatasetProvider, PartitionerProvider, Registry};
pub use session::{Event, Experiment, ExperimentBuilder, Run, RunControl, TablePrinter};
pub use sweep::Sweep;
