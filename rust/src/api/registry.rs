//! Name-keyed registries for the experiment axes: datasets, partitioners,
//! and model architectures. The built-in entries wrap the crate's synthetic
//! generators and partitioners; downstream code can register additional
//! providers at startup ([`register_dataset`] / [`register_partitioner`] /
//! [`register_arch`]) and every lookup, CLI listing (`llcg datasets`,
//! `llcg partition`), and validation error ("unknown dataset X, have
//! [...]") picks them up.

use std::sync::{Arc, OnceLock, RwLock};

use crate::graph::{generators, Dataset};
use crate::partition::{self, Partitioner};

/// A loadable dataset, keyed by name.
pub trait DatasetProvider: Send + Sync {
    fn name(&self) -> &str;
    fn doc(&self) -> &str;
    fn load(&self, seed: u64) -> Result<Dataset, String>;
}

/// A constructible partitioner, keyed by name (plus optional aliases).
pub trait PartitionerProvider: Send + Sync {
    fn name(&self) -> &str;
    fn doc(&self) -> &str;
    fn aliases(&self) -> &[&str] {
        &[]
    }
    fn build(&self) -> Box<dyn Partitioner>;
}

/// A known model architecture (artifact availability is still checked per
/// `(arch, optimizer, dataset)` at runtime load).
#[derive(Clone, Debug)]
pub struct ArchEntry {
    pub name: String,
    pub doc: String,
}

// ---------------------------------------------------------------------------
// built-in providers
// ---------------------------------------------------------------------------

/// Synthetic-dataset provider backed by `graph::generators`.
struct SynthDataset {
    name: &'static str,
    doc: &'static str,
}

impl DatasetProvider for SynthDataset {
    fn name(&self) -> &str {
        self.name
    }

    fn doc(&self) -> &str {
        self.doc
    }

    fn load(&self, seed: u64) -> Result<Dataset, String> {
        generators::by_name(self.name, seed)
            .ok_or_else(|| format!("generator missing for registered dataset {}", self.name))
    }
}

/// Partitioner provider backed by `partition::by_name`.
struct BuiltinPartitioner {
    name: &'static str,
    doc: &'static str,
    aliases: &'static [&'static str],
}

impl PartitionerProvider for BuiltinPartitioner {
    fn name(&self) -> &str {
        self.name
    }

    fn doc(&self) -> &str {
        self.doc
    }

    fn aliases(&self) -> &[&str] {
        self.aliases
    }

    fn build(&self) -> Box<dyn Partitioner> {
        partition::by_name(self.name).expect("builtin partitioner exists")
    }
}

// ---------------------------------------------------------------------------
// the registry
// ---------------------------------------------------------------------------

/// All pluggable experiment axes in one place. Providers are `Arc`ed so
/// lookups can hand a clone out of the global lock — dataset generation
/// never runs with the registry locked.
pub struct Registry {
    datasets: Vec<Arc<dyn DatasetProvider>>,
    partitioners: Vec<Arc<dyn PartitionerProvider>>,
    archs: Vec<ArchEntry>,
}

impl Registry {
    /// The compiled-in entries.
    pub fn builtin() -> Registry {
        let datasets: Vec<Arc<dyn DatasetProvider>> = vec![
            Arc::new(SynthDataset {
                name: "tiny",
                doc: "300-node coupled SBM; fast unit-test workload",
            }),
            Arc::new(SynthDataset {
                name: "tiny-hetero",
                doc: "600-node decoupled SBM; small cut-sensitivity smoke",
            }),
            Arc::new(SynthDataset {
                name: "flickr-s",
                doc: "Flickr analog (Table 2)",
            }),
            Arc::new(SynthDataset {
                name: "proteins-s",
                doc: "Proteins analog; multilabel, ROC-AUC scored",
            }),
            Arc::new(SynthDataset {
                name: "arxiv-s",
                doc: "OGB-Arxiv analog (Table 2)",
            }),
            Arc::new(SynthDataset {
                name: "reddit-s",
                doc: "Reddit analog; the paper's headline substrate",
            }),
            Arc::new(SynthDataset {
                name: "yelp-s",
                doc: "Yelp analog; structure-independent labels (Fig 10)",
            }),
            Arc::new(SynthDataset {
                name: "products-s",
                doc: "OGB-Products analog; the 16-machine setting (Fig 11)",
            }),
        ];
        let partitioners: Vec<Arc<dyn PartitionerProvider>> = vec![
            Arc::new(BuiltinPartitioner {
                name: "metis",
                doc: "multilevel coarsen + KL/FM refine (METIS-like default)",
                aliases: &["multilevel"],
            }),
            Arc::new(BuiltinPartitioner {
                name: "ldg",
                doc: "linear deterministic greedy streaming partitioner",
                aliases: &[],
            }),
            Arc::new(BuiltinPartitioner {
                name: "bfs",
                doc: "BFS region growing",
                aliases: &[],
            }),
            Arc::new(BuiltinPartitioner {
                name: "hash",
                doc: "id-hash assignment (naive baseline)",
                aliases: &[],
            }),
            Arc::new(BuiltinPartitioner {
                name: "random",
                doc: "balanced random (worst-case cut baseline)",
                aliases: &[],
            }),
        ];
        let archs = [
            ("mlp", "2-layer MLP (graph-free lower bound, Fig 10b)"),
            ("gcn", "2-layer GCN (Kipf & Welling)"),
            ("sage", "2-layer GraphSAGE-mean (the paper's base arch)"),
            ("appnp", "APPNP: MLP + personalized-PageRank propagation"),
            ("gat", "2-layer GAT (attention backward is PJRT-only)"),
        ]
        .iter()
        .map(|(n, d)| ArchEntry {
            name: n.to_string(),
            doc: d.to_string(),
        })
        .collect();
        Registry {
            datasets,
            partitioners,
            archs,
        }
    }

    // ------------------------------------------------------------- lookups
    pub fn dataset(&self, name: &str) -> Option<&dyn DatasetProvider> {
        self.datasets
            .iter()
            .find(|p| p.name() == name)
            .map(|p| p.as_ref())
    }

    pub fn partitioner(&self, name: &str) -> Option<&dyn PartitionerProvider> {
        self.partitioners
            .iter()
            .find(|p| p.name() == name || p.aliases().contains(&name))
            .map(|p| p.as_ref())
    }

    pub fn arch(&self, name: &str) -> Option<&ArchEntry> {
        self.archs.iter().find(|a| a.name == name)
    }

    // ------------------------------------------------------------- listing
    pub fn dataset_names(&self) -> Vec<String> {
        self.datasets.iter().map(|p| p.name().to_string()).collect()
    }

    pub fn partitioner_names(&self) -> Vec<String> {
        self.partitioners
            .iter()
            .map(|p| p.name().to_string())
            .collect()
    }

    pub fn arch_names(&self) -> Vec<String> {
        self.archs.iter().map(|a| a.name.clone()).collect()
    }

    pub fn dataset_docs(&self) -> Vec<(String, String)> {
        self.datasets
            .iter()
            .map(|p| (p.name().to_string(), p.doc().to_string()))
            .collect()
    }

    pub fn partitioner_docs(&self) -> Vec<(String, String)> {
        self.partitioners
            .iter()
            .map(|p| (p.name().to_string(), p.doc().to_string()))
            .collect()
    }

    // ------------------------------------------------ owning-clone lookups
    /// `Arc` clone of a dataset provider — lets callers load *after*
    /// releasing the global lock.
    pub fn dataset_provider(&self, name: &str) -> Option<Arc<dyn DatasetProvider>> {
        self.datasets.iter().find(|p| p.name() == name).cloned()
    }

    /// `Arc` clone of a partitioner provider (name or alias).
    pub fn partitioner_provider(&self, name: &str) -> Option<Arc<dyn PartitionerProvider>> {
        self.partitioners
            .iter()
            .find(|p| p.name() == name || p.aliases().contains(&name))
            .cloned()
    }

    // -------------------------------------------------------- registration
    pub fn register_dataset(&mut self, p: Box<dyn DatasetProvider>) {
        self.datasets.retain(|q| q.name() != p.name());
        self.datasets.push(Arc::from(p));
    }

    pub fn register_partitioner(&mut self, p: Box<dyn PartitionerProvider>) {
        self.partitioners.retain(|q| q.name() != p.name());
        self.partitioners.push(Arc::from(p));
    }

    pub fn register_arch(&mut self, name: &str, doc: &str) {
        self.archs.retain(|a| a.name != name);
        self.archs.push(ArchEntry {
            name: name.to_string(),
            doc: doc.to_string(),
        });
    }
}

/// "unknown dataset \"x\", have [a, b, ...]" — the one place validation
/// error wording lives.
pub fn unknown(kind: &str, name: &str, have: &[String]) -> String {
    format!("unknown {kind} {name:?}, have [{}]", have.join(", "))
}

// ---------------------------------------------------------------------------
// process-global instance
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<RwLock<Registry>> = OnceLock::new();

/// The process-global registry (built-ins plus anything registered).
pub fn global() -> &'static RwLock<Registry> {
    GLOBAL.get_or_init(|| RwLock::new(Registry::builtin()))
}

/// Read-access helper: `with(|r| r.dataset_names())`.
pub fn with<R>(f: impl FnOnce(&Registry) -> R) -> R {
    f(&global().read().expect("registry lock poisoned"))
}

/// Register a dataset provider on the global registry (replaces same-name).
pub fn register_dataset(p: Box<dyn DatasetProvider>) {
    global()
        .write()
        .expect("registry lock poisoned")
        .register_dataset(p);
}

/// Register a partitioner provider on the global registry.
pub fn register_partitioner(p: Box<dyn PartitionerProvider>) {
    global()
        .write()
        .expect("registry lock poisoned")
        .register_partitioner(p);
}

/// Register an architecture name on the global registry.
pub fn register_arch(name: &str, doc: &str) {
    global()
        .write()
        .expect("registry lock poisoned")
        .register_arch(name, doc);
}

/// Load a dataset by registry name; unknown names report the available
/// set. The provider is resolved under the lock but `load` runs after it
/// is released — generation can take seconds and custom providers may
/// touch the registry themselves.
pub fn load_dataset(name: &str, seed: u64) -> Result<Dataset, String> {
    let p = with(|r| {
        r.dataset_provider(name)
            .ok_or_else(|| unknown("dataset", name, &r.dataset_names()))
    })?;
    p.load(seed)
}

/// Build a partitioner by registry name; unknown names report the set.
/// Construction runs outside the lock, like [`load_dataset`].
pub fn build_partitioner(name: &str) -> Result<Box<dyn Partitioner>, String> {
    let p = with(|r| {
        r.partitioner_provider(name)
            .ok_or_else(|| unknown("partitioner", name, &r.partitioner_names()))
    })?;
    Ok(p.build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_lookups_and_lists() {
        let r = Registry::builtin();
        assert!(r.dataset("tiny").is_some());
        assert!(r.dataset("imagenet").is_none());
        assert!(r.partitioner("metis").is_some());
        assert!(r.partitioner("multilevel").is_some(), "alias resolves");
        assert!(r.arch("sage").is_some());
        assert_eq!(
            r.dataset_names(),
            generators::SynthConfig::all_names()
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
        );
        // every listed partitioner actually constructs
        for name in r.partitioner_names() {
            let p = r.partitioner(&name).unwrap().build();
            assert_eq!(p.name(), name);
        }
    }

    #[test]
    fn unknown_errors_name_the_available_set() {
        let err = load_dataset("nope", 0).unwrap_err();
        assert!(err.contains("unknown dataset"), "{err}");
        assert!(err.contains("reddit-s"), "must list what exists: {err}");
        let err = build_partitioner("kway?").unwrap_err();
        assert!(err.contains("unknown partitioner") && err.contains("metis"), "{err}");
    }

    #[test]
    fn registration_extends_the_global_registry() {
        struct Echo;
        impl DatasetProvider for Echo {
            fn name(&self) -> &str {
                "echo-test-ds"
            }
            fn doc(&self) -> &str {
                "test-only"
            }
            fn load(&self, seed: u64) -> Result<Dataset, String> {
                generators::by_name("tiny", seed).ok_or_else(|| "tiny missing".into())
            }
        }
        register_dataset(Box::new(Echo));
        let ds = load_dataset("echo-test-ds", 3).unwrap();
        assert_eq!(ds.name, "tiny");
        assert!(with(|r| r.dataset_names()).contains(&"echo-test-ds".to_string()));
    }
}
