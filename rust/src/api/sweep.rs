//! Config-grid sweeps that reuse the expensive invariants across points:
//! the loaded dataset (one `Arc` shared by every point with the same
//! `(dataset, seed)`) and the partition assignment (recomputed only when
//! `(dataset, seed, partitioner, parts)` changes — previously every repro
//! figure re-partitioned per config). Each point runs through the session
//! API and yields the same bit-exact results as a standalone run: the
//! cached assignment is computed with the run's own RNG stream discipline.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::api::keys;
use crate::api::registry;
use crate::api::session::{Experiment, ExperimentBuilder};
use crate::config::ExperimentConfig;
use crate::coordinator::driver::RunResult;
use crate::graph::Dataset;
use crate::runtime::Runtime;
use crate::util::Pcg64;

/// One sweep point: `(key, value)` overrides applied (in order) on the base
/// config through the single-source key schema.
pub type Patch = Vec<(String, String)>;

/// A list of config points over a shared base.
pub struct Sweep {
    base: ExperimentConfig,
    points: Vec<Patch>,
}

impl Sweep {
    /// One point per value of `axis`: the classic single-axis sweep.
    pub fn over<S: ToString>(base: &ExperimentConfig, axis: &str, values: &[S]) -> Sweep {
        Sweep {
            base: base.clone(),
            points: values
                .iter()
                .map(|v| vec![(axis.to_string(), v.to_string())])
                .collect(),
        }
    }

    /// An empty sweep to fill with explicit [`Sweep::point`]s.
    pub fn points(base: &ExperimentConfig) -> Sweep {
        Sweep {
            base: base.clone(),
            points: Vec::new(),
        }
    }

    /// Append one multi-key point (overrides apply in slice order).
    pub fn point(mut self, patch: &[(&str, String)]) -> Sweep {
        self.points.push(
            patch
                .iter()
                .map(|(k, v)| (k.to_string(), v.clone()))
                .collect(),
        );
        self
    }

    /// Cartesian-extend every existing point by `axis` × `values`.
    pub fn cross<S: ToString>(mut self, axis: &str, values: &[S]) -> Sweep {
        let mut out = Vec::with_capacity(self.points.len().max(1) * values.len());
        let seeds: Vec<Patch> = if self.points.is_empty() {
            vec![Vec::new()]
        } else {
            self.points
        };
        for p in &seeds {
            for v in values {
                let mut q = p.clone();
                q.push((axis.to_string(), v.to_string()));
                out.push(q);
            }
        }
        self.points = out;
        self
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The `(key, value)` overrides of point `i`, in application order —
    /// e.g. for labeling per-point rows in the `llcg sweep` table.
    pub fn patch(&self, i: usize) -> &[(String, String)] {
        &self.points[i]
    }

    /// Resolve point `i`'s full config (base + patch).
    pub fn config(&self, i: usize) -> Result<ExperimentConfig> {
        let mut cfg = self.base.clone();
        for (k, v) in &self.points[i] {
            keys::apply_str(&mut cfg, k, v).map_err(|e| anyhow!(e))?;
        }
        Ok(cfg)
    }

    /// Run every point in order, reusing the dataset + partition caches;
    /// `visit` fires after each point with the built experiment and its
    /// result. Returns all results in point order.
    pub fn run(
        &self,
        rt: &Runtime,
        mut visit: impl FnMut(usize, &Experiment, &RunResult),
    ) -> Result<Vec<RunResult>> {
        let mut ds_cache: Option<((String, u64), Arc<Dataset>)> = None;
        let mut part_cache: Option<((String, u64, String, usize), Arc<Vec<u32>>)> = None;
        let mut results = Vec::with_capacity(self.points.len());
        for i in 0..self.points.len() {
            let cfg = self.config(i)?;

            let ds_key = (cfg.dataset.clone(), cfg.seed);
            let ds = match &ds_cache {
                Some((k, ds)) if *k == ds_key => ds.clone(),
                _ => {
                    let ds = Arc::new(
                        registry::load_dataset(&cfg.dataset, cfg.seed)
                            .map_err(|e| anyhow!(e))?,
                    );
                    ds_cache = Some((ds_key, ds.clone()));
                    ds
                }
            };

            let mut exp = ExperimentBuilder::from_config(cfg.clone())
                .with_dataset(ds.clone())
                .build()?;
            if cfg.parts > 1 {
                let part_key = (
                    cfg.dataset.clone(),
                    cfg.seed,
                    cfg.partitioner.clone(),
                    cfg.parts,
                );
                let assignment = match &part_cache {
                    Some((k, a)) if *k == part_key => a.clone(),
                    _ => {
                        // exactly the stream setup_run draws: the partition
                        // stream is split(1) off the root seed
                        let p = registry::build_partitioner(&cfg.partitioner)
                            .map_err(|e| anyhow!(e))?;
                        let mut root_rng = Pcg64::new(cfg.seed);
                        let a = Arc::new(p.partition(
                            &ds.graph,
                            cfg.parts,
                            &mut root_rng.split(1),
                        ));
                        part_cache = Some((part_key, a.clone()));
                        a
                    }
                };
                exp = exp.with_partition(assignment);
            }

            let result = exp.launch(rt).finish()?;
            visit(i, &exp, &result);
            results.push(result);
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn over_and_cross_build_the_grid() {
        let base = ExperimentConfig::default();
        let s = Sweep::over(&base, "parts", &[2usize, 4]).cross("lr", &["0.1", "0.01"]);
        assert_eq!(s.len(), 4);
        let c = s.config(3).unwrap();
        assert_eq!(c.parts, 4);
        assert!((c.lr - 0.01).abs() < 1e-9);
        let p = Sweep::points(&base).point(&[
            ("algorithm", "llcg".to_string()),
            ("rho", "1.1".to_string()),
        ]);
        assert_eq!(p.len(), 1);
        assert_eq!(
            p.config(0).unwrap().algorithm,
            crate::coordinator::Algorithm::Llcg
        );
    }

    #[test]
    fn bad_axis_reports_unknown_key() {
        let base = ExperimentConfig::default();
        let s = Sweep::over(&base, "partz", &[2usize]);
        let err = format!("{:#}", s.config(0).err().unwrap());
        assert!(err.contains("unknown config key"), "{err}");
    }
}
