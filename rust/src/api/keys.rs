//! Single-source config schema: every `ExperimentConfig` key is declared
//! exactly once as a [`KeySpec`] row in [`KEYS`]. JSON parsing
//! (`ExperimentConfig::from_json`), CLI overrides (`apply_override`),
//! unknown-key errors, and the `llcg run --help` key listing are all
//! derived from this one table — adding a config key is a one-row change.

use crate::cluster::{Engine, NetModel, RoundMode};
use crate::config::ExperimentConfig;
use crate::coordinator::{Algorithm, CorrectionBatch, Schedule};
use crate::util::Json;

/// The value class of a key — drives CLI string -> JSON conversion and the
/// type column in the generated help.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyKind {
    Str,
    Num,
    Bool,
}

impl KeyKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            KeyKind::Str => "str",
            KeyKind::Num => "num",
            KeyKind::Bool => "bool",
        }
    }
}

/// One config key: the only place its name, type, doc line, parse/validate
/// logic, and help-display live.
pub struct KeySpec {
    pub name: &'static str,
    pub kind: KeyKind,
    pub doc: &'static str,
    /// parse + validate `v`, then write the field(s) onto `cfg`
    apply: fn(&mut ExperimentConfig, &Json) -> Result<(), String>,
    /// render the key's current value (used with defaults for `--help`)
    show: fn(&ExperimentConfig) -> String,
}

fn req_str(v: &Json, k: &str) -> Result<String, String> {
    v.as_str()
        .map(String::from)
        .ok_or(format!("{k} must be a string"))
}

fn req_num(v: &Json, k: &str) -> Result<f64, String> {
    v.as_f64().ok_or(format!("{k} must be a number"))
}

fn req_bool(v: &Json, k: &str) -> Result<bool, String> {
    v.as_bool()
        .ok_or(format!("{k} must be a bool (true|false)"))
}

/// Non-negative integer with a lower bound — rejects fractions and
/// negatives instead of letting an `as usize` cast saturate them to 0 and
/// panic deep inside the run (e.g. `parts=0` averaging an empty worker
/// set, `eval_every=0` dividing by zero).
fn req_count(v: &Json, k: &str, min: usize) -> Result<usize, String> {
    let x = req_num(v, k)?;
    if !x.is_finite() || x.fract() != 0.0 || x < min as f64 {
        return Err(format!("{k} must be an integer >= {min}, got {x}"));
    }
    Ok(x as usize)
}

/// Strict boolean literal set for CLI/string values. Anything else — `yes`,
/// `TRUE`, `on`, ... — is an error, never a silent `false`.
pub fn parse_bool_str(s: &str) -> Option<bool> {
    match s {
        "true" | "1" => Some(true),
        "false" | "0" => Some(false),
        _ => None,
    }
}

/// The schema. One row per key; alphabetical-ish by topic. JSON objects are
/// applied in `BTreeMap` order, so `local_steps` always lands before `rho`
/// (which reads the schedule's current `k0`).
static KEYS: &[KeySpec] = &[
    KeySpec {
        name: "dataset",
        kind: KeyKind::Str,
        doc: "dataset name (see `llcg datasets`)",
        apply: |cfg, v| {
            cfg.dataset = req_str(v, "dataset")?;
            Ok(())
        },
        show: |cfg| cfg.dataset.clone(),
    },
    KeySpec {
        name: "arch",
        kind: KeyKind::Str,
        doc: "model architecture: mlp|gcn|sage|appnp|gat",
        apply: |cfg, v| {
            cfg.arch = req_str(v, "arch")?;
            Ok(())
        },
        show: |cfg| cfg.arch.clone(),
    },
    KeySpec {
        name: "algorithm",
        kind: KeyKind::Str,
        doc: "llcg|psgd-pa|ggs|full-sync|subgraph-approx",
        apply: |cfg, v| {
            cfg.algorithm = Algorithm::parse(&req_str(v, "algorithm")?)
                .ok_or_else(|| format!("unknown algorithm {v}"))?;
            Ok(())
        },
        show: |cfg| cfg.algorithm.name().to_string(),
    },
    KeySpec {
        name: "parts",
        kind: KeyKind::Num,
        doc: "number of workers / graph partitions P (>= 1)",
        apply: |cfg, v| {
            cfg.parts = req_count(v, "parts", 1)?;
            Ok(())
        },
        show: |cfg| cfg.parts.to_string(),
    },
    KeySpec {
        name: "rounds",
        kind: KeyKind::Num,
        doc: "communication rounds R",
        apply: |cfg, v| {
            cfg.rounds = req_count(v, "rounds", 0)?;
            Ok(())
        },
        show: |cfg| cfg.rounds.to_string(),
    },
    KeySpec {
        name: "local_steps",
        kind: KeyKind::Num,
        doc: "local steps per round (K; sets k0 when a rho schedule is active)",
        apply: |cfg, v| {
            let k = req_count(v, "local_steps", 1)?;
            // compose with `rho` in either order: an active exponential
            // schedule keeps its growth factor and only moves k0
            cfg.schedule = match cfg.schedule {
                Schedule::Exponential { rho, .. } => Schedule::Exponential { k0: k, rho },
                Schedule::Fixed { .. } => Schedule::Fixed { k },
            };
            Ok(())
        },
        show: |cfg| match cfg.schedule {
            Schedule::Fixed { k } => k.to_string(),
            Schedule::Exponential { k0, .. } => k0.to_string(),
        },
    },
    KeySpec {
        name: "rho",
        kind: KeyKind::Num,
        doc: "exponential local-epoch growth K·rho^r (Alg. 2)",
        apply: |cfg, v| {
            let rho = req_num(v, "rho")?;
            let k0 = match cfg.schedule {
                Schedule::Fixed { k } => k,
                Schedule::Exponential { k0, .. } => k0,
            };
            cfg.schedule = Schedule::Exponential { k0, rho };
            Ok(())
        },
        show: |cfg| match cfg.schedule {
            Schedule::Fixed { .. } => "-".to_string(),
            Schedule::Exponential { rho, .. } => rho.to_string(),
        },
    },
    KeySpec {
        name: "correction_steps",
        kind: KeyKind::Num,
        doc: "server correction steps per round S (LLCG)",
        apply: |cfg, v| {
            cfg.correction_steps = req_count(v, "correction_steps", 0)?;
            Ok(())
        },
        show: |cfg| cfg.correction_steps.to_string(),
    },
    KeySpec {
        name: "correction_batch",
        kind: KeyKind::Str,
        doc: "correction mini-batch selection: uniform|max_cut",
        apply: |cfg, v| {
            cfg.correction_batch = match req_str(v, "correction_batch")?.as_str() {
                "uniform" => CorrectionBatch::Uniform,
                "max_cut" => CorrectionBatch::MaxCutEdges,
                other => return Err(format!("unknown correction_batch {other}")),
            };
            Ok(())
        },
        show: |cfg| match cfg.correction_batch {
            CorrectionBatch::Uniform => "uniform".to_string(),
            CorrectionBatch::MaxCutEdges => "max_cut".to_string(),
        },
    },
    KeySpec {
        name: "correction_full_neighbors",
        kind: KeyKind::Bool,
        doc: "full (capped) vs sampled neighbors in correction (Fig 7/8)",
        apply: |cfg, v| {
            cfg.correction_full_neighbors = req_bool(v, "correction_full_neighbors")?;
            Ok(())
        },
        show: |cfg| cfg.correction_full_neighbors.to_string(),
    },
    KeySpec {
        name: "optimizer",
        kind: KeyKind::Str,
        doc: "worker optimizer: sgd|adam",
        apply: |cfg, v| {
            cfg.optimizer = req_str(v, "optimizer")?;
            Ok(())
        },
        show: |cfg| cfg.optimizer.clone(),
    },
    KeySpec {
        name: "server_optimizer",
        kind: KeyKind::Str,
        doc: "server-correction optimizer: sgd|adam",
        apply: |cfg, v| {
            cfg.server_optimizer = req_str(v, "server_optimizer")?;
            Ok(())
        },
        show: |cfg| cfg.server_optimizer.clone(),
    },
    KeySpec {
        name: "lr",
        kind: KeyKind::Num,
        doc: "worker learning rate",
        apply: |cfg, v| {
            cfg.lr = req_num(v, "lr")? as f32;
            Ok(())
        },
        show: |cfg| cfg.lr.to_string(),
    },
    KeySpec {
        name: "server_lr",
        kind: KeyKind::Num,
        doc: "server correction learning rate (gamma in Alg. 2)",
        apply: |cfg, v| {
            cfg.server_lr = req_num(v, "server_lr")? as f32;
            Ok(())
        },
        show: |cfg| cfg.server_lr.to_string(),
    },
    KeySpec {
        name: "partitioner",
        kind: KeyKind::Str,
        doc: "graph partitioner (see `llcg partition`)",
        apply: |cfg, v| {
            cfg.partitioner = req_str(v, "partitioner")?;
            Ok(())
        },
        show: |cfg| cfg.partitioner.clone(),
    },
    KeySpec {
        name: "sample_ratio",
        kind: KeyKind::Num,
        doc: "local neighbor-sampling ratio (Fig 6)",
        apply: |cfg, v| {
            cfg.sample_ratio = req_num(v, "sample_ratio")?;
            Ok(())
        },
        show: |cfg| cfg.sample_ratio.to_string(),
    },
    KeySpec {
        name: "approx_storage",
        kind: KeyKind::Num,
        doc: "extra-storage fraction for subgraph-approx (Fig 11)",
        apply: |cfg, v| {
            cfg.approx_storage = req_num(v, "approx_storage")?;
            Ok(())
        },
        show: |cfg| cfg.approx_storage.to_string(),
    },
    KeySpec {
        name: "seed",
        kind: KeyKind::Num,
        doc: "root RNG seed (whole run is reproducible from it)",
        apply: |cfg, v| {
            cfg.seed = req_count(v, "seed", 0)? as u64;
            Ok(())
        },
        show: |cfg| cfg.seed.to_string(),
    },
    KeySpec {
        name: "eval_every",
        kind: KeyKind::Num,
        doc: "validate every N rounds (1 = every round)",
        apply: |cfg, v| {
            cfg.eval_every = req_count(v, "eval_every", 1)?;
            Ok(())
        },
        show: |cfg| cfg.eval_every.to_string(),
    },
    KeySpec {
        name: "eval_max_nodes",
        kind: KeyKind::Num,
        doc: "cap on validation nodes scored per eval (0 = all)",
        apply: |cfg, v| {
            cfg.eval_max_nodes = req_count(v, "eval_max_nodes", 0)?;
            Ok(())
        },
        show: |cfg| cfg.eval_max_nodes.to_string(),
    },
    KeySpec {
        name: "artifacts_dir",
        kind: KeyKind::Str,
        doc: "compiled-artifact directory (native fallback if absent)",
        apply: |cfg, v| {
            cfg.artifacts_dir = req_str(v, "artifacts_dir")?;
            Ok(())
        },
        show: |cfg| cfg.artifacts_dir.clone(),
    },
    KeySpec {
        name: "engine",
        kind: KeyKind::Str,
        doc: "execution engine: sequential|cluster",
        apply: |cfg, v| {
            cfg.engine = Engine::parse(&req_str(v, "engine")?)
                .ok_or_else(|| format!("unknown engine {v} (sequential|cluster)"))?;
            Ok(())
        },
        show: |cfg| cfg.engine.name().to_string(),
    },
    KeySpec {
        name: "round_mode",
        kind: KeyKind::Str,
        doc: "cluster round discipline: sync|async:<tau>|pipelined",
        apply: |cfg, v| {
            cfg.round_mode = RoundMode::parse(&req_str(v, "round_mode")?)
                .ok_or_else(|| format!("unknown round_mode {v} (sync|async:<tau>|pipelined)"))?;
            Ok(())
        },
        show: |cfg| cfg.round_mode.name(),
    },
    KeySpec {
        name: "kernel_threads",
        kind: KeyKind::Num,
        doc: "native kernel-pool lanes (0 = auto: all cores, or cores/P per cluster worker); \
              results are bit-identical at any setting",
        apply: |cfg, v| {
            cfg.kernel_threads = req_count(v, "kernel_threads", 0)?;
            Ok(())
        },
        show: |cfg| cfg.kernel_threads.to_string(),
    },
    KeySpec {
        name: "net",
        kind: KeyKind::Str,
        doc: "network model: ideal|lan|wan|lat=..,bw=..,jitter=..,scale=..",
        apply: |cfg, v| {
            let spec = req_str(v, "net")?;
            NetModel::parse(&spec)?; // validate here, re-parse at engine start
            cfg.net = spec;
            Ok(())
        },
        show: |cfg| cfg.net.clone(),
    },
    KeySpec {
        name: "serve_batch",
        kind: KeyKind::Num,
        doc: "serving: micro-batch flush size (requests per inference batch)",
        apply: |cfg, v| {
            cfg.serve_batch = req_count(v, "serve_batch", 1)?;
            Ok(())
        },
        show: |cfg| cfg.serve_batch.to_string(),
    },
    KeySpec {
        name: "serve_flush_us",
        kind: KeyKind::Num,
        doc: "serving: micro-batch flush deadline (microseconds after the first request)",
        apply: |cfg, v| {
            cfg.serve_flush_us = req_count(v, "serve_flush_us", 0)? as u64;
            Ok(())
        },
        show: |cfg| cfg.serve_flush_us.to_string(),
    },
    KeySpec {
        name: "serve_threads",
        kind: KeyKind::Num,
        doc: "serving: kernel-pool lanes for the inference server (0 = all cores)",
        apply: |cfg, v| {
            cfg.serve_threads = req_count(v, "serve_threads", 0)?;
            Ok(())
        },
        show: |cfg| cfg.serve_threads.to_string(),
    },
    KeySpec {
        name: "serve_queue",
        kind: KeyKind::Num,
        doc: "serving: bounded request-queue depth (senders block when full)",
        apply: |cfg, v| {
            cfg.serve_queue = req_count(v, "serve_queue", 1)?;
            Ok(())
        },
        show: |cfg| cfg.serve_queue.to_string(),
    },
    KeySpec {
        name: "serve_shed",
        kind: KeyKind::Bool,
        doc: "serving: reject with a typed Overloaded reply when the queue is \
              full instead of blocking the producer",
        apply: |cfg, v| {
            cfg.serve_shed = req_bool(v, "serve_shed")?;
            Ok(())
        },
        show: |cfg| cfg.serve_shed.to_string(),
    },
    KeySpec {
        name: "round_timeout",
        kind: KeyKind::Num,
        doc: "cluster sync: modeled-time deadline (s) before the round closes \
              on the quorum it has (0 = wait for everyone)",
        apply: |cfg, v| {
            let t = req_num(v, "round_timeout")?;
            if !t.is_finite() || t < 0.0 {
                return Err(format!("round_timeout must be a finite number >= 0, got {t}"));
            }
            cfg.round_timeout = t;
            Ok(())
        },
        show: |cfg| cfg.round_timeout.to_string(),
    },
    KeySpec {
        name: "quorum",
        kind: KeyKind::Num,
        doc: "cluster sync: minimum params averaged when the deadline fires \
              (K-of-P; 0 = all P)",
        apply: |cfg, v| {
            cfg.quorum = req_count(v, "quorum", 0)?;
            Ok(())
        },
        show: |cfg| cfg.quorum.to_string(),
    },
    KeySpec {
        name: "respawn",
        kind: KeyKind::Bool,
        doc: "respawn crashed workers from the current global params \
              (false = a dead worker stays dead)",
        apply: |cfg, v| {
            cfg.respawn = req_bool(v, "respawn")?;
            Ok(())
        },
        show: |cfg| cfg.respawn.to_string(),
    },
    KeySpec {
        name: "checkpoint_every",
        kind: KeyKind::Num,
        doc: "write a round-boundary checkpoint every N rounds (0 = off)",
        apply: |cfg, v| {
            cfg.checkpoint_every = req_count(v, "checkpoint_every", 0)?;
            Ok(())
        },
        show: |cfg| cfg.checkpoint_every.to_string(),
    },
    KeySpec {
        name: "checkpoint_dir",
        kind: KeyKind::Str,
        doc: "directory checkpoints are written under (<dir>/round_<r>/)",
        apply: |cfg, v| {
            cfg.checkpoint_dir = req_str(v, "checkpoint_dir")?;
            Ok(())
        },
        show: |cfg| cfg.checkpoint_dir.clone(),
    },
    KeySpec {
        name: "resume",
        kind: KeyKind::Str,
        doc: "resume from a checkpoint: a round_<r> dir, or a parent dir \
              (latest round wins; \"\" = fresh run)",
        apply: |cfg, v| {
            cfg.resume = req_str(v, "resume")?;
            Ok(())
        },
        show: |cfg| cfg.resume.clone(),
    },
    KeySpec {
        name: "transport",
        kind: KeyKind::Str,
        doc: "cluster worker wire: inprocess|tcp|uds, with optional \
              process-kill faults (tcp,kill=1@3)",
        apply: |cfg, v| {
            let spec = req_str(v, "transport")?;
            crate::transport::TransportSpec::parse(&spec)?; // validate here, re-parse at engine start
            cfg.transport = spec;
            Ok(())
        },
        show: |cfg| cfg.transport.clone(),
    },
    KeySpec {
        name: "heartbeat_ms",
        kind: KeyKind::Num,
        doc: "worker heartbeat period in ms (process transports; liveness \
              monitor unit; >= 10)",
        apply: |cfg, v| {
            cfg.heartbeat_ms = req_count(v, "heartbeat_ms", 10)? as u64;
            Ok(())
        },
        show: |cfg| cfg.heartbeat_ms.to_string(),
    },
];

/// Look up a key by its canonical (underscore) name.
pub fn spec(name: &str) -> Option<&'static KeySpec> {
    KEYS.iter().find(|k| k.name == name)
}

/// All config key names, in table order.
pub fn key_names() -> Vec<&'static str> {
    KEYS.iter().map(|k| k.name).collect()
}

/// The error every unknown-key path reports — names the full key set so a
/// typo is a one-glance fix.
pub fn unknown_key_error(key: &str) -> String {
    format!(
        "unknown config key {key:?} (known keys: {})",
        key_names().join(", ")
    )
}

/// Apply one already-typed JSON value onto `cfg`.
pub fn apply_json(cfg: &mut ExperimentConfig, key: &str, v: &Json) -> Result<(), String> {
    let s = spec(key).ok_or_else(|| unknown_key_error(key))?;
    (s.apply)(cfg, v)
}

/// Apply one CLI-style `key=value` string override onto `cfg`. CLI dashes
/// are accepted (`round-mode` == `round_mode`); the value is converted to
/// the key's declared kind first, so an unknown key is always reported as
/// such (never as a bad value), and boolean values outside
/// `true|false|1|0` are rejected.
pub fn apply_str(cfg: &mut ExperimentConfig, key: &str, value: &str) -> Result<(), String> {
    let key = key.replace('-', "_");
    let s = spec(&key).ok_or_else(|| unknown_key_error(&key))?;
    let v = match s.kind {
        KeyKind::Str => Json::Str(value.to_string()),
        KeyKind::Bool => Json::Bool(parse_bool_str(value).ok_or_else(|| {
            format!("bad boolean value for {}: {value:?} (use true|false|1|0)", s.name)
        })?),
        KeyKind::Num => Json::Num(
            value
                .parse::<f64>()
                .map_err(|_| format!("bad numeric value for {}: {value}", s.name))?,
        ),
    };
    (s.apply)(cfg, &v)
}

/// Parse a whole JSON object onto the default config (unknown keys rejected
/// to catch typos).
pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
    let obj = j.as_object().ok_or("config must be a json object")?;
    let mut cfg = ExperimentConfig::default();
    for (k, v) in obj {
        apply_json(&mut cfg, k, v)?;
    }
    Ok(cfg)
}

/// The `llcg run --help` key table, generated from [`KEYS`] with the
/// compiled-in defaults.
pub fn help_table() -> String {
    let d = ExperimentConfig::default();
    let mut out = String::new();
    for k in KEYS {
        out.push_str(&format!(
            "  --{:<28} {:<5} [default: {}]\n      {}\n",
            k.name.replace('_', "-"),
            k.kind.as_str(),
            (k.show)(&d),
            k.doc
        ));
    }
    out
}

/// Render `cfg` as CLI override flags (`--name value` pairs, table order)
/// that `apply_str` round-trips back to the same config. Used to hand a
/// remote worker process the exact run config the server holds. `rho` is
/// skipped while the schedule is `Fixed` — its "-" placeholder is display
/// glue, not a value.
pub fn cli_args(cfg: &ExperimentConfig) -> Vec<String> {
    let mut out = Vec::new();
    for k in KEYS {
        let v = (k.show)(cfg);
        if k.name == "rho" && v == "-" {
            continue;
        }
        out.push(format!("--{}", k.name));
        out.push(v);
    }
    out
}

/// Short stable fingerprint of a resolved config: FNV-1a 64 over the
/// round-trippable `cli_args` rendering, hex-encoded. Stamped into the
/// run-metadata header (`obs::run_meta_json`) so artifacts from different
/// processes of the same run are matchable — and artifacts from *different*
/// configs are distinguishable — without shipping the whole config.
pub fn config_fingerprint(cfg: &ExperimentConfig) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for arg in cli_args(cfg) {
        for b in arg.bytes().chain(std::iter::once(0x1f)) {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_key_is_declared_once() {
        let names = key_names();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len(), "duplicate KeySpec rows");
        // one row per ExperimentConfig knob (schedule takes two)
        assert_eq!(names.len(), 38);
    }

    #[test]
    fn unknown_key_reports_key_not_value() {
        let mut cfg = ExperimentConfig::default();
        let err = apply_str(&mut cfg, "foo", "bar").unwrap_err();
        assert!(err.contains("unknown config key"), "got: {err}");
        assert!(err.contains("dataset"), "error must list known keys: {err}");
        assert!(!err.contains("bad numeric"), "got the old misleading error: {err}");
    }

    #[test]
    fn bool_literals_are_strict() {
        let mut cfg = ExperimentConfig::default();
        for (val, want) in [("true", true), ("1", true), ("false", false), ("0", false)] {
            apply_str(&mut cfg, "correction_full_neighbors", val).unwrap();
            assert_eq!(cfg.correction_full_neighbors, want, "literal {val}");
        }
        for bad in ["TRUE", "yes", "on", "no", ""] {
            let err = apply_str(&mut cfg, "correction_full_neighbors", bad).unwrap_err();
            assert!(err.contains("bad boolean"), "{bad:?} -> {err}");
        }
        // JSON path: only a real bool is accepted
        let j = Json::parse(r#"{"correction_full_neighbors":"yes"}"#).unwrap();
        assert!(from_json(&j).is_err());
        let j = Json::parse(r#"{"correction_full_neighbors":false}"#).unwrap();
        assert!(!from_json(&j).unwrap().correction_full_neighbors);
    }

    #[test]
    fn help_table_covers_every_key() {
        let help = help_table();
        for name in key_names() {
            assert!(
                help.contains(&format!("--{}", name.replace('_', "-"))),
                "help table misses {name}"
            );
        }
    }

    #[test]
    fn count_keys_reject_zero_negative_and_fractional() {
        let mut cfg = ExperimentConfig::default();
        for (k, bad) in [
            ("parts", "0"),
            ("parts", "-1"),
            ("parts", "2.5"),
            ("eval_every", "0"),
            ("local_steps", "0"),
            ("rounds", "-3"),
            ("seed", "1.5"),
            ("kernel_threads", "2.5"),
            ("kernel_threads", "-2"),
        ] {
            let err = apply_str(&mut cfg, k, bad).unwrap_err();
            assert!(err.contains("must be an integer"), "{k}={bad}: {err}");
        }
        apply_str(&mut cfg, "rounds", "0").unwrap(); // rounds=0 is legal
        apply_str(&mut cfg, "eval_max_nodes", "0").unwrap(); // 0 = all
        apply_str(&mut cfg, "kernel_threads", "0").unwrap(); // 0 = auto
    }

    #[test]
    fn serve_keys_parse_and_validate() {
        let mut cfg = ExperimentConfig::default();
        apply_str(&mut cfg, "serve_batch", "8").unwrap();
        apply_str(&mut cfg, "serve-flush-us", "1000").unwrap();
        apply_str(&mut cfg, "serve_threads", "2").unwrap();
        apply_str(&mut cfg, "serve_queue", "16").unwrap();
        assert_eq!(
            (cfg.serve_batch, cfg.serve_flush_us, cfg.serve_threads, cfg.serve_queue),
            (8, 1000, 2, 16)
        );
        assert!(apply_str(&mut cfg, "serve_batch", "0").is_err());
        assert!(apply_str(&mut cfg, "serve_queue", "0").is_err());
        apply_str(&mut cfg, "serve_flush_us", "0").unwrap(); // 0 = flush instantly
        apply_str(&mut cfg, "serve_threads", "0").unwrap(); // 0 = all cores
    }

    #[test]
    fn fault_and_checkpoint_keys_parse_and_validate() {
        let mut cfg = ExperimentConfig::default();
        apply_str(&mut cfg, "round-timeout", "0.25").unwrap();
        apply_str(&mut cfg, "quorum", "3").unwrap();
        apply_str(&mut cfg, "respawn", "false").unwrap();
        apply_str(&mut cfg, "checkpoint_every", "5").unwrap();
        apply_str(&mut cfg, "checkpoint-dir", "ckpt").unwrap();
        apply_str(&mut cfg, "resume", "ckpt/round_5").unwrap();
        apply_str(&mut cfg, "serve_shed", "true").unwrap();
        assert_eq!(cfg.round_timeout, 0.25);
        assert_eq!(cfg.quorum, 3);
        assert!(!cfg.respawn);
        assert_eq!(cfg.checkpoint_every, 5);
        assert_eq!(cfg.checkpoint_dir, "ckpt");
        assert_eq!(cfg.resume, "ckpt/round_5");
        assert!(cfg.serve_shed);
        assert!(apply_str(&mut cfg, "round_timeout", "-1").is_err());
        assert!(apply_str(&mut cfg, "round_timeout", "inf").is_err());
        assert!(apply_str(&mut cfg, "quorum", "-2").is_err());
        assert!(apply_str(&mut cfg, "checkpoint_every", "1.5").is_err());
        assert!(apply_str(&mut cfg, "respawn", "yes").is_err());
        // net spec faults validate at config time too
        assert!(apply_str(&mut cfg, "net", "lan,drop=0.05,crash=1@3").is_ok());
        assert!(apply_str(&mut cfg, "net", "lan,drop=2").is_err());
        assert!(apply_str(&mut cfg, "net", "crash=1").is_err());
    }

    #[test]
    fn cli_args_round_trip_every_key() {
        let mut cfg = ExperimentConfig::default();
        for (k, v) in [
            ("dataset", "reddit-s"),
            ("engine", "cluster"),
            ("round_mode", "async:2"),
            ("net", "lan,scale=0.5"),
            ("transport", "tcp"),
            ("rho", "1.1"),
            ("serve_shed", "true"),
            ("lr", "0.025"),
        ] {
            apply_str(&mut cfg, k, v).unwrap();
        }
        let args = cli_args(&cfg);
        assert_eq!(args.len() % 2, 0);
        let mut back = ExperimentConfig::default();
        for pair in args.chunks(2) {
            let key = pair[0].strip_prefix("--").expect("flag form");
            apply_str(&mut back, key, &pair[1]).unwrap();
        }
        // ExperimentConfig has no PartialEq; Debug covers every field
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
        // a Fixed schedule must not emit the "-" rho placeholder
        let fixed = cli_args(&ExperimentConfig::default());
        assert!(!fixed.iter().any(|a| a == "--rho"), "{fixed:?}");
        assert!(fixed.iter().any(|a| a == "--transport"), "{fixed:?}");
    }

    #[test]
    fn transport_key_validates_spec() {
        let mut cfg = ExperimentConfig::default();
        apply_str(&mut cfg, "transport", "tcp,kill=1@3").unwrap();
        assert_eq!(cfg.transport, "tcp,kill=1@3");
        assert!(apply_str(&mut cfg, "transport", "carrier-pigeon").is_err());
        assert!(apply_str(&mut cfg, "transport", "inprocess,kill=1@3").is_err());
    }

    #[test]
    fn heartbeat_ms_parses_and_enforces_floor() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.heartbeat_ms, 1000);
        apply_str(&mut cfg, "heartbeat-ms", "250").unwrap();
        assert_eq!(cfg.heartbeat_ms, 250);
        assert!(apply_str(&mut cfg, "heartbeat_ms", "5").is_err());
        assert!(apply_str(&mut cfg, "heartbeat_ms", "0").is_err());
        assert!(apply_str(&mut cfg, "heartbeat_ms", "99.5").is_err());
        // ships to workers via cli_args like every other key
        let args = cli_args(&cfg);
        let i = args.iter().position(|a| a == "--heartbeat_ms").unwrap();
        assert_eq!(args[i + 1], "250");
    }

    #[test]
    fn config_fingerprint_is_stable_and_config_sensitive() {
        let a = ExperimentConfig::default();
        let mut b = ExperimentConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        assert_eq!(config_fingerprint(&a).len(), 16);
        b.parts = 8;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
    }

    #[test]
    fn rho_and_local_steps_compose_in_either_order() {
        let mut a = ExperimentConfig::default();
        apply_str(&mut a, "local_steps", "8").unwrap();
        apply_str(&mut a, "rho", "1.2").unwrap();
        let mut b = ExperimentConfig::default();
        apply_str(&mut b, "rho", "1.2").unwrap();
        apply_str(&mut b, "local_steps", "8").unwrap();
        for cfg in [&a, &b] {
            assert!(
                matches!(cfg.schedule, Schedule::Exponential { k0: 8, rho }
                    if (rho - 1.2).abs() < 1e-9),
                "{:?}",
                cfg.schedule
            );
        }
    }

    #[test]
    fn dashes_normalize_on_the_cli_path() {
        let mut cfg = ExperimentConfig::default();
        apply_str(&mut cfg, "round-mode", "async:3").unwrap();
        assert_eq!(cfg.round_mode, crate::cluster::RoundMode::AsyncStaleness { tau: 3 });
        apply_str(&mut cfg, "eval-max-nodes", "99").unwrap();
        assert_eq!(cfg.eval_max_nodes, 99);
    }
}
