//! Run sessions: typed experiment construction ([`ExperimentBuilder`] →
//! validated [`Experiment`]), launching ([`Experiment::launch`] → [`Run`]),
//! and a streaming event interface ([`Event`]) with cooperative early-stop
//! ([`RunControl`]).
//!
//! Both execution engines (the sequential driver and the threaded cluster
//! engine) emit their events through the same `coordinator::driver` helpers,
//! so in sync mode the two streams are identical — kinds *and* payloads —
//! which `tests/cluster.rs` asserts. The legacy
//! `driver::run_experiment(cfg, ds, rt)` entry point survives as a thin
//! wrapper over this API.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::api::{keys, registry};
use crate::cluster::{Engine, RoundMode};
use crate::config::ExperimentConfig;
use crate::coordinator::driver::{self, RoundRecord, RunResult};
use crate::coordinator::{Algorithm, CorrectionBatch, Schedule};
use crate::graph::Dataset;
use crate::runtime::{Runtime, Tensor};
use crate::serve::{SnapshotHub, SnapshotPublisher};
use crate::util::Json;

// ---------------------------------------------------------------------------
// events
// ---------------------------------------------------------------------------

/// One step of a run's lifecycle, streamed to the consumer as it happens.
/// Sync-mode sequence per round: `RoundStarted`, then one
/// `WorkerRoundCompleted` per worker (in part order), then (when the
/// algorithm corrects) `CorrectionApplied`, then (on eval-cadence rounds)
/// `EvalCompleted`, then `RoundCompleted`; the stream ends with `Finished`.
/// Under the cluster engine's async mode, `WorkerRoundCompleted` fires in
/// push-arrival order instead.
#[derive(Clone, Debug)]
pub enum Event {
    RoundStarted {
        round: usize,
        local_steps: usize,
    },
    /// One worker finished its local round. `compute_s` is the measured
    /// wall time of the worker's round (including any injected network
    /// sleeps); `net_s` is the modeled link time. Identity (`round`,
    /// `part`) is engine-independent; the times are measurements and are
    /// not part of the sync-mode bit-parity contract.
    WorkerRoundCompleted {
        round: usize,
        part: u32,
        compute_s: f64,
        net_s: f64,
    },
    CorrectionApplied {
        round: usize,
        steps: usize,
    },
    EvalCompleted {
        round: usize,
        val_score: f64,
        global_loss: f64,
    },
    /// A crashed/dead worker was respawned on a fresh thread, seeded from
    /// the current global params (the paper's "local model = averaged
    /// global model" round entry). Emitted by the cluster engine only.
    WorkerRestarted {
        round: usize,
        part: u32,
    },
    /// A round-boundary checkpoint was written (`checkpoint_every`).
    CheckpointSaved {
        round: usize,
        path: String,
    },
    RoundCompleted(RoundRecord),
    /// A training monitor crossed a threshold rule (loss non-finite,
    /// cross-worker divergence growing for several rounds, a worker silent
    /// past its heartbeat budget, ...). Emitted only while the telemetry
    /// monitors are enabled (`--listen`); never part of the sync-mode
    /// event-parity contract.
    MonitorAlert {
        round: usize,
        monitor: &'static str,
        message: String,
        value: f64,
    },
    Finished(RunResult),
}

impl Event {
    pub fn kind(&self) -> &'static str {
        match self {
            Event::RoundStarted { .. } => "round_started",
            Event::WorkerRoundCompleted { .. } => "worker_round_completed",
            Event::CorrectionApplied { .. } => "correction_applied",
            Event::EvalCompleted { .. } => "eval_completed",
            Event::WorkerRestarted { .. } => "worker_restarted",
            Event::CheckpointSaved { .. } => "checkpoint_saved",
            Event::RoundCompleted(_) => "round_completed",
            Event::MonitorAlert { .. } => "monitor_alert",
            Event::Finished(_) => "finished",
        }
    }

    /// One `--log-json` line body: `{"event": kind, ...payload}`. Round
    /// and run payloads reuse `RoundRecord::to_json` / `RunResult::to_json`,
    /// so the streamed rows match the `--json` report field-for-field.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("event", Json::str(self.kind()))];
        match self {
            Event::RoundStarted { round, local_steps } => {
                fields.push(("round", Json::num(*round as f64)));
                fields.push(("local_steps", Json::num(*local_steps as f64)));
            }
            Event::WorkerRoundCompleted {
                round,
                part,
                compute_s,
                net_s,
            } => {
                fields.push(("round", Json::num(*round as f64)));
                fields.push(("part", Json::num(*part as f64)));
                fields.push(("compute_s", Json::num(*compute_s)));
                fields.push(("net_s", Json::num(*net_s)));
            }
            Event::CorrectionApplied { round, steps } => {
                fields.push(("round", Json::num(*round as f64)));
                fields.push(("steps", Json::num(*steps as f64)));
            }
            Event::EvalCompleted {
                round,
                val_score,
                global_loss,
            } => {
                fields.push(("round", Json::num(*round as f64)));
                fields.push(("val_score", Json::num(*val_score)));
                fields.push(("global_loss", Json::num(*global_loss)));
            }
            Event::WorkerRestarted { round, part } => {
                fields.push(("round", Json::num(*round as f64)));
                fields.push(("part", Json::num(*part as f64)));
            }
            Event::CheckpointSaved { round, path } => {
                fields.push(("round", Json::num(*round as f64)));
                fields.push(("path", Json::str(path)));
            }
            Event::RoundCompleted(r) => fields.push(("record", r.to_json())),
            Event::MonitorAlert {
                round,
                monitor,
                message,
                value,
            } => {
                fields.push(("round", Json::num(*round as f64)));
                fields.push(("monitor", Json::str(*monitor)));
                fields.push(("message", Json::str(message)));
                fields.push(("value", Json::num(*value)));
            }
            Event::Finished(r) => fields.push(("result", r.to_json())),
        }
        Json::obj(fields)
    }
}

/// Cooperative early-stop handle. Cloneable; `stop()` from any thread (or
/// from inside the event sink) ends the run at the next round boundary with
/// a well-formed partial [`RunResult`].
#[derive(Clone, Debug, Default)]
pub struct RunControl {
    stop: Arc<AtomicBool>,
}

impl RunControl {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// Engine-side plumbing: where events go, whether to keep going, and the
/// optional serving publisher. Lives on the server thread for the whole
/// run (worker threads never emit).
pub(crate) struct RunCtx<'a> {
    pub sink: &'a mut dyn FnMut(Event),
    pub stop: &'a RunControl,
    /// when set (`Run::publish_to`), every engine snapshots the global
    /// params here at each round boundary for live serving
    pub publish: Option<&'a SnapshotPublisher>,
}

impl RunCtx<'_> {
    pub fn emit(&mut self, ev: Event) {
        (self.sink)(ev);
    }

    pub fn stopped(&self) -> bool {
        self.stop.stop_requested()
    }

    /// Round-boundary snapshot publication (no-op without a publisher).
    pub fn publish_params(&self, round: usize, params: &[Tensor]) {
        if let Some(p) = self.publish {
            p.publish(round, params);
        }
    }
}

// ---------------------------------------------------------------------------
// builder
// ---------------------------------------------------------------------------

/// Typed, chainable construction of an [`Experiment`]. Every knob is also
/// settable by config-key string ([`ExperimentBuilder::set`]) through the
/// same [`keys`] schema the JSON/CLI paths use.
#[derive(Clone, Debug, Default)]
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
    preloaded: Option<Arc<Dataset>>,
}

impl ExperimentBuilder {
    pub fn new() -> ExperimentBuilder {
        ExperimentBuilder::default()
    }

    /// Start from an existing config (e.g. parsed from JSON/CLI).
    pub fn from_config(cfg: ExperimentConfig) -> ExperimentBuilder {
        ExperimentBuilder {
            cfg,
            preloaded: None,
        }
    }

    pub fn dataset(mut self, name: &str) -> Self {
        self.cfg.dataset = name.to_string();
        self
    }

    pub fn arch(mut self, name: &str) -> Self {
        self.cfg.arch = name.to_string();
        self
    }

    pub fn algorithm(mut self, alg: Algorithm) -> Self {
        self.cfg.algorithm = alg;
        self
    }

    pub fn parts(mut self, parts: usize) -> Self {
        self.cfg.parts = parts;
        self
    }

    pub fn rounds(mut self, rounds: usize) -> Self {
        self.cfg.rounds = rounds;
        self
    }

    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.cfg.schedule = schedule;
        self
    }

    pub fn correction_steps(mut self, s: usize) -> Self {
        self.cfg.correction_steps = s;
        self
    }

    pub fn correction_batch(mut self, b: CorrectionBatch) -> Self {
        self.cfg.correction_batch = b;
        self
    }

    pub fn correction_full_neighbors(mut self, full: bool) -> Self {
        self.cfg.correction_full_neighbors = full;
        self
    }

    pub fn optimizer(mut self, name: &str) -> Self {
        self.cfg.optimizer = name.to_string();
        self
    }

    pub fn server_optimizer(mut self, name: &str) -> Self {
        self.cfg.server_optimizer = name.to_string();
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.cfg.lr = lr;
        self
    }

    pub fn server_lr(mut self, lr: f32) -> Self {
        self.cfg.server_lr = lr;
        self
    }

    pub fn partitioner(mut self, name: &str) -> Self {
        self.cfg.partitioner = name.to_string();
        self
    }

    pub fn sample_ratio(mut self, r: f64) -> Self {
        self.cfg.sample_ratio = r;
        self
    }

    pub fn approx_storage(mut self, s: f64) -> Self {
        self.cfg.approx_storage = s;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn eval_every(mut self, n: usize) -> Self {
        self.cfg.eval_every = n;
        self
    }

    pub fn eval_max_nodes(mut self, n: usize) -> Self {
        self.cfg.eval_max_nodes = n;
        self
    }

    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.cfg.artifacts_dir = dir.to_string();
        self
    }

    pub fn engine(mut self, engine: Engine) -> Self {
        self.cfg.engine = engine;
        self
    }

    pub fn round_mode(mut self, mode: RoundMode) -> Self {
        self.cfg.round_mode = mode;
        self
    }

    pub fn net(mut self, spec: &str) -> Self {
        self.cfg.net = spec.to_string();
        self
    }

    /// Cluster worker wire: `inprocess` (threads + modeled net), or
    /// `tcp`/`uds` (real worker processes over the versioned wire
    /// protocol), with optional `,kill=p@r` process-kill faults.
    pub fn transport(mut self, spec: &str) -> Self {
        self.cfg.transport = spec.to_string();
        self
    }

    /// Native kernel-pool lanes (0 = auto); a pure performance knob —
    /// results are bit-identical at any setting.
    pub fn kernel_threads(mut self, threads: usize) -> Self {
        self.cfg.kernel_threads = threads;
        self
    }

    /// Modeled-time deadline (seconds) after which a cluster sync round
    /// closes on whatever quorum of params has arrived (0 = wait for all).
    pub fn round_timeout(mut self, seconds: f64) -> Self {
        self.cfg.round_timeout = seconds;
        self
    }

    /// Minimum params averaged when the round deadline fires (0 = all P).
    pub fn quorum(mut self, k: usize) -> Self {
        self.cfg.quorum = k;
        self
    }

    /// Respawn crashed workers from the current global params (default on).
    pub fn respawn(mut self, on: bool) -> Self {
        self.cfg.respawn = on;
        self
    }

    /// Write a round-boundary checkpoint every `n` rounds into `dir`
    /// (`n = 0` disables checkpointing).
    pub fn checkpoint(mut self, n: usize, dir: &str) -> Self {
        self.cfg.checkpoint_every = n;
        self.cfg.checkpoint_dir = dir.to_string();
        self
    }

    /// Resume from a checkpoint directory (a `round_<r>` dir, or a parent
    /// whose latest round wins; "" = fresh run).
    pub fn resume(mut self, path: &str) -> Self {
        self.cfg.resume = path.to_string();
        self
    }

    /// Serving: shed load with a typed `Overloaded` reply when the request
    /// queue is full, instead of blocking the producer.
    pub fn serve_shed(mut self, on: bool) -> Self {
        self.cfg.serve_shed = on;
        self
    }

    /// Set any key by its config-schema name (same table as JSON/CLI).
    pub fn set(mut self, key: &str, value: &str) -> Result<Self, String> {
        keys::apply_str(&mut self.cfg, key, value)?;
        Ok(self)
    }

    /// Use an already-loaded dataset instead of loading by name at
    /// `build()` — sweeps and benches share one `Arc` across many points.
    pub fn with_dataset(mut self, ds: Arc<Dataset>) -> Self {
        self.cfg.dataset = ds.name.clone();
        self.preloaded = Some(ds);
        self
    }

    /// Validate every registry-backed name plus the engine/round-mode
    /// combination, load the dataset (unless preloaded), and return the
    /// launchable [`Experiment`].
    pub fn build(self) -> Result<Experiment> {
        let cfg = self.cfg;
        registry::with(|r| -> Result<()> {
            if self.preloaded.is_none() && r.dataset(&cfg.dataset).is_none() {
                return Err(anyhow!(registry::unknown(
                    "dataset",
                    &cfg.dataset,
                    &r.dataset_names()
                )));
            }
            if r.partitioner(&cfg.partitioner).is_none() {
                return Err(anyhow!(registry::unknown(
                    "partitioner",
                    &cfg.partitioner,
                    &r.partitioner_names()
                )));
            }
            if r.arch(&cfg.arch).is_none() {
                return Err(anyhow!(registry::unknown(
                    "arch",
                    &cfg.arch,
                    &r.arch_names()
                )));
            }
            Ok(())
        })?;
        if cfg.engine == Engine::Sequential && cfg.round_mode != RoundMode::Sync {
            return Err(anyhow!(
                "round_mode {} requires the cluster engine — the sequential \
                 driver is always sync; use engine=cluster",
                cfg.round_mode.name()
            ));
        }
        // fault tolerance lives in the cluster engine's sync collection path
        let netm = crate::cluster::NetModel::parse(&cfg.net).map_err(|e| anyhow!(e))?;
        let quorum_on = cfg.round_timeout > 0.0 || cfg.quorum > 0;
        if netm.has_faults() || quorum_on {
            if cfg.engine != Engine::Cluster {
                return Err(anyhow!(
                    "fault injection / quorum rounds (net faults, round_timeout, \
                     quorum) require engine=cluster"
                ));
            }
            if cfg.round_mode != RoundMode::Sync {
                return Err(anyhow!(
                    "fault injection / quorum rounds require round_mode=sync \
                     (got {})",
                    cfg.round_mode.name()
                ));
            }
        }
        if !(cfg.round_timeout.is_finite() && cfg.round_timeout >= 0.0) {
            return Err(anyhow!("round_timeout must be a finite number >= 0"));
        }
        if cfg.quorum > cfg.parts {
            return Err(anyhow!(
                "quorum {} exceeds parts {} — no round could ever close",
                cfg.quorum,
                cfg.parts
            ));
        }
        if (cfg.checkpoint_every > 0 || !cfg.resume.is_empty())
            && cfg.round_mode == RoundMode::PipelinedCorrection
        {
            return Err(anyhow!(
                "checkpoint/resume require round_mode=sync or async (got {})",
                cfg.round_mode.name()
            ));
        }
        let tspec =
            crate::transport::TransportSpec::parse(&cfg.transport).map_err(|e| anyhow!(e))?;
        if tspec.kind != crate::transport::TransportKind::InProcess
            && cfg.engine != Engine::Cluster
        {
            return Err(anyhow!(
                "transport={} spawns real worker processes and requires \
                 engine=cluster",
                tspec.kind.name()
            ));
        }
        if !tspec.kills.is_empty() && cfg.round_mode != RoundMode::Sync {
            return Err(anyhow!(
                "transport kill faults feed the sync respawn path; they \
                 require round_mode=sync (got {})",
                cfg.round_mode.name()
            ));
        }
        // the schema path (`set`/JSON/CLI) already enforces these; the
        // typed setters can bypass it, so re-check the run-loop invariants
        if cfg.parts == 0 {
            return Err(anyhow!("parts must be >= 1"));
        }
        if cfg.eval_every == 0 {
            return Err(anyhow!("eval_every must be >= 1 (1 = every round)"));
        }
        if cfg.heartbeat_ms < 10 {
            return Err(anyhow!(
                "heartbeat_ms must be >= 10 (got {}) — sub-10ms heartbeats \
                 flood the wire",
                cfg.heartbeat_ms
            ));
        }
        let ds = match self.preloaded {
            Some(ds) => ds,
            None => Arc::new(
                registry::load_dataset(&cfg.dataset, cfg.seed).map_err(|e| anyhow!(e))?,
            ),
        };
        Ok(Experiment {
            cfg,
            ds,
            partition: None,
        })
    }
}

// ---------------------------------------------------------------------------
// experiment + run
// ---------------------------------------------------------------------------

/// A validated, launchable experiment: config + loaded dataset (+ an
/// optional pre-computed partition assignment, shared by sweeps).
pub struct Experiment {
    cfg: ExperimentConfig,
    ds: Arc<Dataset>,
    partition: Option<Arc<Vec<u32>>>,
}

impl Experiment {
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.ds
    }

    /// Reuse a pre-computed partition assignment. Must equal what the
    /// run's `(seed, partitioner, parts)` would produce — the sweep layer
    /// guarantees this by computing it with the run's exact RNG stream.
    pub(crate) fn with_partition(mut self, assignment: Arc<Vec<u32>>) -> Experiment {
        self.partition = Some(assignment);
        self
    }

    /// Create a launchable [`Run`]. Nothing executes until
    /// [`Run::stream`] / [`Run::finish`] is called.
    pub fn launch<'a>(&'a self, rt: &'a Runtime) -> Run<'a> {
        Run {
            exp: self,
            rt,
            control: RunControl::default(),
            publisher: None,
        }
    }
}

/// One launched (but not yet executed) run. `stream` drives it to
/// completion, delivering every [`Event`] to the sink as it happens.
pub struct Run<'a> {
    exp: &'a Experiment,
    rt: &'a Runtime,
    control: RunControl,
    publisher: Option<SnapshotPublisher>,
}

impl Run<'_> {
    /// Handle for stopping this run at the next round boundary.
    pub fn control(&self) -> RunControl {
        self.control.clone()
    }

    /// Publish a serving snapshot of the global parameters to `hub` at
    /// every round boundary (on either engine, in every round mode) — the
    /// live-serving hand-off: a `serve::Server` reading `hub` hot-swaps to
    /// each improving model while this run is still training. Fails for
    /// archs outside the native serving zoo (GAT).
    pub fn publish_to(mut self, hub: Arc<SnapshotHub>) -> Result<Self> {
        let cfg = self.exp.config();
        let name = Runtime::train_name(&cfg.arch, &cfg.optimizer, &cfg.dataset);
        let meta = self.rt.meta(&name)?.clone();
        self.publisher = Some(SnapshotPublisher::new(hub, &meta)?);
        Ok(self)
    }

    /// Execute the run, invoking `sink` for every event (ending with
    /// `Event::Finished`), and return the final result.
    pub fn stream(self, mut sink: impl FnMut(&Event)) -> Result<RunResult> {
        let mut deliver = |ev: Event| sink(&ev);
        let result = {
            let mut ctx = RunCtx {
                sink: &mut deliver,
                stop: &self.control,
                publish: self.publisher.as_ref(),
            };
            driver::run_with_ctx(
                &self.exp.cfg,
                &self.exp.ds,
                self.rt,
                self.exp.partition.as_ref().map(|a| a.as_slice()),
                &mut ctx,
            )?
        };
        deliver(Event::Finished(result.clone()));
        Ok(result)
    }

    /// Execute the run, discarding events.
    pub fn finish(self) -> Result<RunResult> {
        self.stream(|_| {})
    }
}

// ---------------------------------------------------------------------------
// console reporter
// ---------------------------------------------------------------------------

/// The CLI's per-round table printer, as a reusable event consumer: header
/// on the first completed round, one row per `RoundCompleted`.
#[derive(Debug, Default)]
pub struct TablePrinter {
    header_printed: bool,
}

impl TablePrinter {
    pub fn new() -> TablePrinter {
        TablePrinter::default()
    }

    pub fn on_event(&mut self, ev: &Event) {
        if let Event::RoundCompleted(r) = ev {
            if !self.header_printed {
                self.header_printed = true;
                println!(
                    "{:>5} {:>6} {:>10} {:>10} {:>9} {:>12}",
                    "round", "steps", "loc_loss", "glob_loss", "val", "cum_MB"
                );
            }
            println!(
                "{:>5} {:>6} {:>10.4} {:>10.4} {:>9.4} {:>12.3}",
                r.round,
                r.local_steps,
                r.local_loss,
                r.global_loss,
                r.val_score,
                r.cum_bytes as f64 / 1e6
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_registry_names() {
        let err = ExperimentBuilder::new()
            .dataset("no-such-graph")
            .build()
            .err()
            .unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown dataset") && msg.contains("tiny"), "{msg}");

        let err = ExperimentBuilder::new()
            .partitioner("kway")
            .build()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("unknown partitioner"));

        let err = ExperimentBuilder::new()
            .arch("transformer")
            .build()
            .err()
            .unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown arch") && msg.contains("gcn"), "{msg}");

        // sequential + non-sync round mode is a build-time error now
        let err = ExperimentBuilder::new()
            .round_mode(RoundMode::PipelinedCorrection)
            .build()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("cluster engine"));
    }

    #[test]
    fn builder_validates_fault_and_checkpoint_combos() {
        // faults / quorum need the cluster engine ...
        for b in [
            ExperimentBuilder::new().net("lan,drop=0.1"),
            ExperimentBuilder::new().net("lan,crash=1@2"),
            ExperimentBuilder::new().round_timeout(0.5),
            ExperimentBuilder::new().quorum(2),
        ] {
            let err = b.build().err().unwrap();
            assert!(format!("{err:#}").contains("engine=cluster"), "{err:#}");
        }
        // ... and sync mode
        let err = ExperimentBuilder::new()
            .engine(Engine::Cluster)
            .round_mode(RoundMode::AsyncStaleness { tau: 2 })
            .net("lan,drop=0.1")
            .build()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("round_mode=sync"), "{err:#}");
        // checkpoint/resume are sync-only too (either engine)
        let err = ExperimentBuilder::new()
            .engine(Engine::Cluster)
            .round_mode(RoundMode::PipelinedCorrection)
            .checkpoint(2, "ckpt")
            .build()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("checkpoint/resume"), "{err:#}");
        // quorum can't exceed parts
        let err = ExperimentBuilder::new()
            .engine(Engine::Cluster)
            .parts(2)
            .quorum(3)
            .build()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("exceeds parts"), "{err:#}");
        // valid combos build fine
        ExperimentBuilder::new()
            .engine(Engine::Cluster)
            .net("lan,drop=0.02,crash=1@3")
            .round_timeout(0.5)
            .quorum(2)
            .checkpoint(2, "ckpt")
            .build()
            .unwrap();
        ExperimentBuilder::new().checkpoint(2, "ckpt").build().unwrap();
        // async checkpoints are legal now (the async engine barriers at
        // checkpoint boundaries); pipelined stays rejected above
        ExperimentBuilder::new()
            .engine(Engine::Cluster)
            .round_mode(RoundMode::AsyncStaleness { tau: 2 })
            .checkpoint(2, "ckpt")
            .build()
            .unwrap();
    }

    #[test]
    fn builder_validates_transport_combos() {
        // a remote transport needs the cluster engine
        let err = ExperimentBuilder::new().transport("tcp").build().err().unwrap();
        assert!(format!("{err:#}").contains("engine=cluster"), "{err:#}");
        // kill faults need sync mode (they feed the respawn path)
        let err = ExperimentBuilder::new()
            .engine(Engine::Cluster)
            .round_mode(RoundMode::AsyncStaleness { tau: 1 })
            .transport("tcp,kill=0@2")
            .build()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("round_mode=sync"), "{err:#}");
        // bad specs are rejected with the grammar
        let err = ExperimentBuilder::new().transport("warp").build().err().unwrap();
        assert!(format!("{err:#}").contains("transport"), "{err:#}");
        // valid remote combos build fine
        ExperimentBuilder::new()
            .engine(Engine::Cluster)
            .transport("tcp,kill=1@2")
            .build()
            .unwrap();
    }

    #[test]
    fn builder_set_goes_through_the_key_schema() {
        let b = ExperimentBuilder::new()
            .set("algorithm", "ggs")
            .unwrap()
            .set("parts", "2")
            .unwrap();
        let exp = b.build().unwrap();
        assert_eq!(exp.config().algorithm, Algorithm::Ggs);
        assert_eq!(exp.config().parts, 2);
        assert!(ExperimentBuilder::new().set("nope", "1").is_err());
    }

    #[test]
    fn build_rejects_sub_10ms_heartbeats() {
        let mut b = ExperimentBuilder::new();
        b.cfg.heartbeat_ms = 5; // typed path can bypass the key schema
        let err = b.build().err().unwrap();
        assert!(format!("{err:#}").contains("heartbeat_ms"), "{err:#}");
        ExperimentBuilder::new()
            .set("heartbeat_ms", "10")
            .unwrap()
            .build()
            .unwrap();
    }

    #[test]
    fn monitor_alert_event_serializes() {
        let ev = Event::MonitorAlert {
            round: 3,
            monitor: "divergence",
            message: "divergence grew 3 rounds straight".to_string(),
            value: 0.25,
        };
        assert_eq!(ev.kind(), "monitor_alert");
        let j = ev.to_json();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("monitor_alert"));
        assert_eq!(j.get("round").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("monitor").and_then(Json::as_str), Some("divergence"));
        assert_eq!(j.get("value").and_then(Json::as_f64), Some(0.25));
    }

    #[test]
    fn build_loads_the_dataset_once() {
        let exp = ExperimentBuilder::new().dataset("tiny").seed(5).build().unwrap();
        assert_eq!(exp.dataset().name, "tiny");
        // preloaded dataset short-circuits the registry load and renames cfg
        let ds = exp.dataset().clone();
        let exp2 = ExperimentBuilder::new()
            .dataset("reddit-s")
            .with_dataset(ds.clone())
            .build()
            .unwrap();
        assert_eq!(exp2.config().dataset, "tiny");
        assert!(Arc::ptr_eq(exp2.dataset(), &ds));
    }
}
