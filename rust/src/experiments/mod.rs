//! Reproduction harness: one function per paper table/figure (DESIGN.md
//! experiment index). Each prints the paper's rows/series and writes a JSON
//! record under `runs/` for EXPERIMENTS.md.

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::{driver, Algorithm, CorrectionBatch, Schedule};
use crate::graph::generators;
use crate::runtime::Runtime;
use crate::util::Json;

pub const REPRO_COMMANDS: &[&str] = &[
    "fig1", "fig2", "fig4", "table1", "fig5", "fig6", "fig78", "fig9", "fig10", "fig11",
    "theory",
];

pub fn run_repro(name: &str, flags: &[(String, String)]) -> Result<()> {
    let mut opts = ReproOpts::default();
    for (k, v) in flags {
        match k.as_str() {
            "fast" => opts.fast = v == "true" || v == "1",
            "seed" => opts.seed = v.parse()?,
            "seeds" => opts.seeds = v.parse()?,
            "out-dir" => opts.out_dir = v.clone(),
            "artifacts_dir" | "artifacts-dir" => opts.artifacts_dir = v.clone(),
            _ => bail!("unknown flag --{k}"),
        }
    }
    match name {
        "fig1" => fig1(&opts),
        "fig2" => fig2(&opts),
        "fig4" => fig4(&opts),
        "table1" => table1(&opts),
        "fig5" => fig5(&opts),
        "fig6" => fig6(&opts),
        "fig78" => fig78(&opts),
        "fig9" => fig9(&opts),
        "fig10" => fig10(&opts),
        "fig11" => fig11(&opts),
        "theory" => theory(&opts),
        other => bail!("unknown repro target {other:?} (have {REPRO_COMMANDS:?})"),
    }
}

#[derive(Clone, Debug)]
pub struct ReproOpts {
    /// shrink rounds/datasets for smoke runs
    pub fast: bool,
    pub seed: u64,
    /// repetitions for mean±std rows (Table 1)
    pub seeds: usize,
    pub out_dir: String,
    pub artifacts_dir: String,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            fast: false,
            seed: 0,
            seeds: 2,
            out_dir: "runs".into(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ReproOpts {
    fn base_cfg(&self, dataset: &str, arch: &str) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = dataset.to_string();
        cfg.arch = arch.to_string();
        cfg.artifacts_dir = self.artifacts_dir.clone();
        cfg.seed = self.seed;
        cfg.parts = 8;
        cfg.rounds = if self.fast { 6 } else { 30 };
        cfg.eval_every = if self.fast { 2 } else { 5 };
        cfg.schedule = Schedule::Fixed { k: 4 };
        cfg.eval_max_nodes = 384;
        cfg
    }

    fn save(&self, name: &str, j: Json) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = format!("{}/{}.json", self.out_dir, name);
        std::fs::write(&path, j.to_string_pretty())?;
        eprintln!("wrote {path}");
        Ok(())
    }
}

/// Runtime for a repro run: the configured artifacts when loadable,
/// otherwise the generated native-backend manifest.
fn load_rt(opts: &ReproOpts) -> Result<Runtime> {
    let (rt, dir) = Runtime::load_or_native(&opts.artifacts_dir)?;
    eprintln!("runtime backend: {} (artifacts: {dir})", rt.backend_name());
    Ok(rt)
}

fn run_one(cfg: &ExperimentConfig, rt: &Runtime) -> Result<driver::RunResult> {
    let ds = driver::load_dataset(cfg)?;
    driver::run_experiment(cfg, &ds, rt)
}

/// Algorithms compared in the headline figures.
fn algos3() -> Vec<Algorithm> {
    vec![Algorithm::PsgdPa, Algorithm::Ggs, Algorithm::Llcg]
}

fn setup_llcg(cfg: &mut ExperimentConfig, alg: Algorithm) {
    cfg.algorithm = alg;
    if alg == Algorithm::Llcg {
        // paper defaults: rho = 1.1, S = 1
        let k0 = match cfg.schedule {
            Schedule::Fixed { k } => k,
            Schedule::Exponential { k0, .. } => k0,
        };
        cfg.schedule = Schedule::Exponential { k0, rho: 1.1 };
        cfg.correction_steps = 8;
    }
}

// ---------------------------------------------------------------------------
// Fig 1: speedup + per-machine memory vs number of machines (Reddit analog).
// ---------------------------------------------------------------------------
fn fig1(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "reddit-s" };
    let arch = if opts.fast { "gcn" } else { "sage" };
    println!("Fig 1 — distributed speedup & memory vs machines ({dataset})");
    println!(
        "{:>9} {:>12} {:>12} {:>12} {:>14}",
        "machines", "epoch_s", "speedup", "mem_MB/mach", "val"
    );
    let mut rows = Vec::new();
    let mut t1 = 0f64;
    for &p in &[1usize, 2, 4, 8] {
        let mut cfg = opts.base_cfg(dataset, arch);
        cfg.parts = p;
        cfg.rounds = if opts.fast { 2 } else { 6 };
        setup_llcg(&mut cfg, Algorithm::Llcg);
        let ds = driver::load_dataset(&cfg)?;
        let res = driver::run_experiment(&cfg, &ds, &rt)?;
        // simulated-parallel *epoch* time: (steps to cover the largest
        // local training shard) x measured per-step time + server work.
        let k: usize = res.records.iter().map(|r| r.local_steps).sum();
        let step_s: f64 = res
            .records
            .iter()
            .map(|r| r.worker_time_s)
            .sum::<f64>()
            / k as f64;
        let b = rt.meta(&crate::runtime::Runtime::train_name(arch, "adam", dataset))?.dims.b;
        let shard = ds.splits.train.len().div_ceil(p);
        let epoch_steps = shard.div_ceil(b);
        let server_s: f64 = res
            .records
            .iter()
            .map(|r| r.server_time_s)
            .sum::<f64>()
            / res.records.len() as f64;
        let round_s = step_s * epoch_steps as f64 + server_s;
        if p == 1 {
            t1 = round_s;
        }
        // per-machine memory = features+graph of its partition
        let mem = (ds.n() / p) as f64 * (ds.d as f64 * 4.0)
            + (ds.graph.indices.len() / p) as f64 * 4.0;
        println!(
            "{:>9} {:>12.3} {:>12.2} {:>12.2} {:>14.4}",
            p,
            round_s,
            t1 / round_s,
            mem / 1e6,
            res.final_val
        );
        rows.push(Json::obj(vec![
            ("machines", Json::num(p as f64)),
            ("round_s", Json::num(round_s)),
            ("speedup", Json::num(t1 / round_s)),
            ("mem_mb", Json::num(mem / 1e6)),
            ("val", Json::num(res.final_val)),
        ]));
    }
    opts.save("fig1", Json::arr(rows))
}

// ---------------------------------------------------------------------------
// Fig 2: PSGD-PA vs GGS (accuracy per round; bytes per round), Reddit analog.
// ---------------------------------------------------------------------------
fn fig2(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "reddit-s" };
    let arch = if opts.fast { "gcn" } else { "sage" };
    println!("Fig 2 — PSGD-PA vs GGS vs single-machine ({dataset}, P=8)");
    let mut out = Vec::new();
    for alg in [Algorithm::PsgdPa, Algorithm::Ggs] {
        let mut cfg = opts.base_cfg(dataset, arch);
        cfg.algorithm = alg;
        let res = run_one(&cfg, &rt)?;
        println!(
            "  {:<10} final_val={:.4} avg_MB/round={:.3}",
            alg.name(),
            res.final_val,
            res.avg_round_mb()
        );
        out.push(res.to_json());
    }
    // single machine baseline
    let mut cfg = opts.base_cfg(dataset, arch);
    cfg.parts = 1;
    cfg.algorithm = Algorithm::PsgdPa;
    let res = run_one(&cfg, &rt)?;
    println!(
        "  {:<10} final_val={:.4} (upper bound)",
        "single", res.final_val
    );
    out.push(res.to_json());
    opts.save("fig2", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 4: val score per round (a–d), global loss per round (e,f), score per
// byte (g,h) — all captured in the per-round records of each run.
// ---------------------------------------------------------------------------
fn fig4(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let datasets: Vec<&str> = if opts.fast {
        vec!["tiny"]
    } else {
        vec!["flickr-s", "proteins-s", "arxiv-s", "reddit-s"]
    };
    let mut out = Vec::new();
    for ds_name in &datasets {
        println!("Fig 4 — {ds_name} (P=8): val score / loss / bytes per round");
        println!(
            "  {:<10} {:>9} {:>10} {:>12}",
            "algo", "final", "glob_loss", "avg_MB/round"
        );
        for alg in algos3() {
            let arch = if opts.fast { "gcn" } else { "sage" };
            let mut cfg = opts.base_cfg(ds_name, arch);
            setup_llcg(&mut cfg, alg);
            let res = run_one(&cfg, &rt)?;
            let last_loss = res
                .records
                .iter()
                .rev()
                .find(|r| !r.global_loss.is_nan())
                .map(|r| r.global_loss)
                .unwrap_or(f64::NAN);
            println!(
                "  {:<10} {:>9.4} {:>10.4} {:>12.3}",
                alg.name(),
                res.final_val,
                last_loss,
                res.avg_round_mb()
            );
            out.push(res.to_json());
        }
    }
    opts.save("fig4", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Table 1: score + avg MB/round for 3 algos × {GCN|SAGE, GAT, APPNP} × 4
// datasets, mean±std over seeds.
// ---------------------------------------------------------------------------
fn table1(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let rows: Vec<(&str, Vec<&str>)> = if opts.fast {
        vec![("tiny", vec!["gcn", "sage"])]
    } else {
        vec![
            ("flickr-s", vec!["sage", "gat", "appnp"]),
            ("proteins-s", vec!["sage", "gat", "appnp"]),
            ("arxiv-s", vec!["sage", "gat", "appnp"]),
            ("reddit-s", vec!["sage", "gat", "appnp"]),
        ]
    };
    let seeds = if opts.fast { 1 } else { opts.seeds };
    let mut out = Vec::new();
    println!("Table 1 — score ± std and avg MB/round (seeds={seeds})");
    for (ds_name, archs) in &rows {
        for arch in archs {
            for alg in algos3() {
                let mut scores = Vec::new();
                let mut mbs = Vec::new();
                for s in 0..seeds {
                    let mut cfg = opts.base_cfg(ds_name, arch);
                    cfg.seed = opts.seed + s as u64;
                    setup_llcg(&mut cfg, alg);
                    let res = run_one(&cfg, &rt)?;
                    scores.push(res.final_test);
                    mbs.push(res.avg_round_mb());
                }
                let mean = crate::util::stats::mean(&scores);
                let std = crate::util::stats::std(&scores);
                println!(
                    "{:<12} {:<6} {:<10} {:>7.2}±{:<5.2} {:>10.3} MB",
                    ds_name,
                    arch,
                    alg.name(),
                    mean * 100.0,
                    std * 100.0,
                    crate::util::stats::mean(&mbs)
                );
                out.push(Json::obj(vec![
                    ("dataset", Json::str(*ds_name)),
                    ("arch", Json::str(*arch)),
                    ("algorithm", Json::str(alg.name())),
                    ("score_mean", Json::num(mean)),
                    ("score_std", Json::num(std)),
                    ("avg_mb", Json::num(crate::util::stats::mean(&mbs))),
                ]));
            }
        }
    }
    opts.save("table1", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 5: effect of local epoch size K (arxiv analog).
// ---------------------------------------------------------------------------
fn fig5(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "arxiv-s" };
    let ks: Vec<usize> = if opts.fast {
        vec![1, 4]
    } else {
        vec![1, 4, 16, 64, 128]
    };
    println!("Fig 5 — local epoch size K sweep ({dataset}, LLCG)");
    let mut out = Vec::new();
    for &k in &ks {
        let arch = if opts.fast { "gcn" } else { "sage" };
        let mut cfg = opts.base_cfg(dataset, arch);
        setup_llcg(&mut cfg, Algorithm::Llcg);
        cfg.schedule = Schedule::Exponential { k0: k, rho: 1.1 };
        cfg.rounds = cfg.rounds.min(15); // large K makes rounds expensive
        // same *round* budget: more local work per round for larger K
        let res = run_one(&cfg, &rt)?;
        println!(
            "  K={:<4} total_steps={:<6} final_val={:.4}",
            k, res.total_steps, res.final_val
        );
        out.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("total_steps", Json::num(res.total_steps as f64)),
            ("final_val", Json::num(res.final_val)),
            ("history", history_json(&res)),
        ]));
    }
    opts.save("fig5", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 6: neighbor-sampling ratio × correction steps (reddit analog).
// ---------------------------------------------------------------------------
fn fig6(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "reddit-s" };
    let grid: Vec<(f64, usize)> = if opts.fast {
        vec![(1.0, 1), (0.2, 1)]
    } else {
        vec![
            (1.0, 1),
            (0.5, 1),
            (0.2, 1),
            (0.05, 1),
            (0.05, 4),
            (0.2, 4),
        ]
    };
    println!("Fig 6 — sampling ratio × correction steps ({dataset}, LLCG)");
    let mut out = Vec::new();
    for &(ratio, s) in &grid {
        let arch = if opts.fast { "gcn" } else { "sage" };
        let mut cfg = opts.base_cfg(dataset, arch);
        setup_llcg(&mut cfg, Algorithm::Llcg);
        cfg.sample_ratio = ratio;
        cfg.correction_steps = s;
        let res = run_one(&cfg, &rt)?;
        println!(
            "  ratio={:<5} S={} final_val={:.4}",
            ratio, s, res.final_val
        );
        out.push(Json::obj(vec![
            ("sample_ratio", Json::num(ratio)),
            ("correction_steps", Json::num(s as f64)),
            ("final_val", Json::num(res.final_val)),
            ("history", history_json(&res)),
        ]));
    }
    opts.save("fig6", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 7/8: full vs sampled neighbors in the correction step.
// ---------------------------------------------------------------------------
fn fig78(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let datasets: Vec<&str> = if opts.fast {
        vec!["tiny"]
    } else {
        vec!["reddit-s", "arxiv-s"]
    };
    let mut out = Vec::new();
    for ds_name in &datasets {
        println!("Fig 7/8 — correction sampling ({ds_name}, LLCG)");
        for full in [true, false] {
            let arch = if opts.fast { "gcn" } else { "sage" };
            let mut cfg = opts.base_cfg(ds_name, arch);
            setup_llcg(&mut cfg, Algorithm::Llcg);
            cfg.correction_full_neighbors = full;
            let res = run_one(&cfg, &rt)?;
            println!(
                "  correction {:<18} final_val={:.4}",
                if full { "full-neighbors" } else { "sampled-neighbors" },
                res.final_val
            );
            out.push(Json::obj(vec![
                ("dataset", Json::str(*ds_name)),
                ("full_neighbors", Json::Bool(full)),
                ("final_val", Json::num(res.final_val)),
                ("history", history_json(&res)),
            ]));
        }
    }
    opts.save("fig78", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 9: uniform vs max-cut-edge correction batches.
// ---------------------------------------------------------------------------
fn fig9(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let datasets: Vec<&str> = if opts.fast {
        vec!["tiny"]
    } else {
        vec!["reddit-s", "arxiv-s"]
    };
    let mut out = Vec::new();
    for ds_name in &datasets {
        println!("Fig 9 — correction batch selection ({ds_name}, LLCG)");
        for batch in [CorrectionBatch::Uniform, CorrectionBatch::MaxCutEdges] {
            let arch = if opts.fast { "gcn" } else { "sage" };
            let mut cfg = opts.base_cfg(ds_name, arch);
            setup_llcg(&mut cfg, Algorithm::Llcg);
            cfg.correction_batch = batch;
            let res = run_one(&cfg, &rt)?;
            println!("  {:<12?} final_val={:.4}", batch, res.final_val);
            out.push(Json::obj(vec![
                ("dataset", Json::str(*ds_name)),
                ("batch", Json::str(format!("{batch:?}"))),
                ("final_val", Json::num(res.final_val)),
                ("history", history_json(&res)),
            ]));
        }
    }
    opts.save("fig9", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 10: structure-independent datasets — PSGD-PA ≈ GGS on yelp analog;
// MLP ≈ GCN there; products analog shows no gap either (small cut + split).
// ---------------------------------------------------------------------------
fn fig10(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let mut out = Vec::new();
    let yelp = if opts.fast { "tiny" } else { "yelp-s" };
    println!("Fig 10a — PSGD-PA vs GGS on {yelp}");
    for alg in [Algorithm::PsgdPa, Algorithm::Ggs] {
        let mut cfg = opts.base_cfg(yelp, if opts.fast { "gcn" } else { "sage" });
        cfg.algorithm = alg;
        let res = run_one(&cfg, &rt)?;
        println!("  {:<10} final_val={:.4}", alg.name(), res.final_val);
        out.push(res.to_json());
    }
    println!("Fig 10b — GNN vs MLP on {yelp} (single machine)");
    for arch in if opts.fast { ["gcn", "mlp"] } else { ["sage", "mlp"] } {
        let mut cfg = opts.base_cfg(yelp, arch);
        cfg.parts = 1;
        cfg.algorithm = Algorithm::PsgdPa;
        let res = run_one(&cfg, &rt)?;
        println!("  {:<10} final_val={:.4}", arch, res.final_val);
        out.push(res.to_json());
    }
    if !opts.fast {
        println!("Fig 10c — PSGD-PA vs GGS on products-s");
        for alg in [Algorithm::PsgdPa, Algorithm::Ggs] {
            let mut cfg = opts.base_cfg("products-s", "sage");
            cfg.algorithm = alg;
            let res = run_one(&cfg, &rt)?;
            println!("  {:<10} final_val={:.4}", alg.name(), res.final_val);
            out.push(res.to_json());
        }
    }
    opts.save("fig10", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 11: 16 machines, PSGD-PA vs SubgraphApprox vs FullSync vs LLCG.
// ---------------------------------------------------------------------------
fn fig11(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "products-s" };
    println!("Fig 11 — large-scale setting ({dataset}, P=16)");
    let mut out = Vec::new();
    for alg in [
        Algorithm::PsgdPa,
        Algorithm::SubgraphApprox,
        Algorithm::FullSync,
        Algorithm::Llcg,
    ] {
        let mut cfg = opts.base_cfg(dataset, if opts.fast { "gcn" } else { "sage" });
        cfg.parts = if opts.fast { 4 } else { 16 };
        setup_llcg(&mut cfg, alg);
        let res = run_one(&cfg, &rt)?;
        println!(
            "  {:<16} final_val={:.4} avg_MB/round={:.3}",
            alg.name(),
            res.final_val,
            res.avg_round_mb()
        );
        out.push(res.to_json());
    }
    opts.save("fig11", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Theory: measure κ_A², κ_X², σ²_bias across partitioners / homophily —
// the quantities behind Thm 1's irreducible residual.
// ---------------------------------------------------------------------------
fn theory(opts: &ReproOpts) -> Result<()> {
    use crate::coordinator::discrepancy;
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "arxiv-s" };
    let ds = generators::by_name(dataset, opts.seed).unwrap();
    let arch = "gcn";
    let meta = rt.meta(&Runtime::train_name(arch, "sgd", dataset))?.clone();
    let mut rng = crate::util::Pcg64::new(opts.seed);
    let params = crate::runtime::ModelState::init(&meta, &mut rng).params;
    println!("Theory — κ², σ²_bias by partitioner ({dataset}, P=8)");
    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>12}",
        "partition", "cut_ratio", "kappa_A^2", "kappa_X^2", "sigma_bias^2"
    );
    let mut out = Vec::new();
    for pname in ["metis", "random"] {
        let p = crate::partition::by_name(pname).unwrap();
        let assignment = p.partition(&ds.graph, 8, &mut rng.split(7));
        let d = discrepancy::measure(
            &rt,
            arch,
            dataset,
            &params,
            &ds,
            &assignment,
            8,
            if opts.fast { 2 } else { 8 },
            opts.seed,
        )?;
        println!(
            "{:<10} {:>10.4} {:>12.4} {:>12.4} {:>12.4}",
            pname,
            ds.graph.cut_ratio(&assignment),
            d.kappa_a,
            d.kappa_x,
            d.sigma_bias
        );
        out.push(Json::obj(vec![
            ("partitioner", Json::str(pname)),
            ("cut_ratio", Json::num(ds.graph.cut_ratio(&assignment))),
            ("kappa_a", Json::num(d.kappa_a)),
            ("kappa_x", Json::num(d.kappa_x)),
            ("sigma_bias", Json::num(d.sigma_bias)),
        ]));
    }
    opts.save("theory", Json::arr(out))
}

fn history_json(res: &driver::RunResult) -> Json {
    Json::arr(
        res.records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("round", Json::num(r.round as f64)),
                    ("val", Json::num(r.val_score)),
                    ("loss", Json::num(r.global_loss)),
                    ("cum_bytes", Json::num(r.cum_bytes as f64)),
                ])
            })
            .collect(),
    )
}
