//! Reproduction harness: one function per paper table/figure (DESIGN.md
//! experiment index). Each prints the paper's rows/series and writes a JSON
//! record under `runs/` for EXPERIMENTS.md.
//!
//! Every figure is a [`Sweep`] over a shared base config: the loaded
//! dataset and the partition assignment are reused across the sweep's
//! points (they used to be recomputed per config), and each point runs
//! through the session API — this module never touches the run loop or
//! dataset plumbing directly.

use anyhow::{bail, Result};

use crate::api::Sweep;
use crate::config::ExperimentConfig;
use crate::coordinator::{driver, Algorithm, Schedule};
use crate::graph::generators;
use crate::runtime::Runtime;
use crate::util::Json;

pub const REPRO_COMMANDS: &[&str] = &[
    "fig1", "fig2", "fig4", "table1", "fig5", "fig6", "fig78", "fig9", "fig10", "fig11",
    "theory",
];

pub fn run_repro(name: &str, flags: &[(String, String)]) -> Result<()> {
    let mut opts = ReproOpts::default();
    for (k, v) in flags {
        match k.as_str() {
            "fast" => opts.fast = v == "true" || v == "1",
            "seed" => opts.seed = v.parse()?,
            "seeds" => opts.seeds = v.parse()?,
            "out-dir" => opts.out_dir = v.clone(),
            "artifacts_dir" | "artifacts-dir" => opts.artifacts_dir = v.clone(),
            _ => bail!("unknown flag --{k}"),
        }
    }
    match name {
        "fig1" => fig1(&opts),
        "fig2" => fig2(&opts),
        "fig4" => fig4(&opts),
        "table1" => table1(&opts),
        "fig5" => fig5(&opts),
        "fig6" => fig6(&opts),
        "fig78" => fig78(&opts),
        "fig9" => fig9(&opts),
        "fig10" => fig10(&opts),
        "fig11" => fig11(&opts),
        "theory" => theory(&opts),
        other => bail!("unknown repro target {other:?} (have {REPRO_COMMANDS:?})"),
    }
}

#[derive(Clone, Debug)]
pub struct ReproOpts {
    /// shrink rounds/datasets for smoke runs
    pub fast: bool,
    pub seed: u64,
    /// repetitions for mean±std rows (Table 1)
    pub seeds: usize,
    pub out_dir: String,
    pub artifacts_dir: String,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            fast: false,
            seed: 0,
            seeds: 2,
            out_dir: "runs".into(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ReproOpts {
    fn base_cfg(&self, dataset: &str, arch: &str) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = dataset.to_string();
        cfg.arch = arch.to_string();
        cfg.artifacts_dir = self.artifacts_dir.clone();
        cfg.seed = self.seed;
        cfg.parts = 8;
        cfg.rounds = if self.fast { 6 } else { 30 };
        cfg.eval_every = if self.fast { 2 } else { 5 };
        cfg.schedule = Schedule::Fixed { k: 4 };
        cfg.eval_max_nodes = 384;
        cfg
    }

    fn save(&self, name: &str, j: Json) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = format!("{}/{}.json", self.out_dir, name);
        std::fs::write(&path, j.to_string_pretty())?;
        eprintln!("wrote {path}");
        Ok(())
    }
}

/// Runtime for a repro run: the configured artifacts when loadable,
/// otherwise the generated native-backend manifest.
fn load_rt(opts: &ReproOpts) -> Result<Runtime> {
    let (rt, dir) = Runtime::load_or_native(&opts.artifacts_dir)?;
    eprintln!("runtime backend: {} (artifacts: {dir})", rt.backend_name());
    Ok(rt)
}

/// Algorithms compared in the headline figures.
fn algos3() -> Vec<Algorithm> {
    vec![Algorithm::PsgdPa, Algorithm::Ggs, Algorithm::Llcg]
}

/// Sweep-point patch selecting `alg`; LLCG gets the paper defaults on top
/// (rho = 1.1 exponential local epochs, S = 8 correction steps).
fn algo_patch(alg: Algorithm) -> Vec<(&'static str, String)> {
    let mut patch = vec![("algorithm", alg.name().to_string())];
    if alg == Algorithm::Llcg {
        patch.push(("rho", "1.1".to_string()));
        patch.push(("correction_steps", "8".to_string()));
    }
    patch
}

// ---------------------------------------------------------------------------
// Fig 1: speedup + per-machine memory vs number of machines (Reddit analog).
// ---------------------------------------------------------------------------
fn fig1(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "reddit-s" };
    let arch = if opts.fast { "gcn" } else { "sage" };
    println!("Fig 1 — distributed speedup & memory vs machines ({dataset})");
    println!(
        "{:>9} {:>12} {:>12} {:>12} {:>14}",
        "machines", "epoch_s", "speedup", "mem_MB/mach", "val"
    );
    let b = rt.meta(&Runtime::train_name(arch, "adam", dataset))?.dims.b;
    let rounds = if opts.fast { 2 } else { 6 };

    let mut sweep = Sweep::points(&opts.base_cfg(dataset, arch));
    for &p in &[1usize, 2, 4, 8] {
        let mut patch = algo_patch(Algorithm::Llcg);
        patch.push(("parts", p.to_string()));
        patch.push(("rounds", rounds.to_string()));
        sweep = sweep.point(&patch);
    }

    let mut rows = Vec::new();
    let mut t1 = 0f64;
    sweep.run(&rt, |_i, exp, res| {
        let ds = exp.dataset();
        let p = exp.config().parts;
        // simulated-parallel *epoch* time: (steps to cover the largest
        // local training shard) x measured per-step time + server work.
        let k: usize = res.records.iter().map(|r| r.local_steps).sum();
        let step_s: f64 = res
            .records
            .iter()
            .map(|r| r.worker_time_s)
            .sum::<f64>()
            / k as f64;
        let shard = ds.splits.train.len().div_ceil(p);
        let epoch_steps = shard.div_ceil(b);
        let server_s: f64 = res
            .records
            .iter()
            .map(|r| r.server_time_s)
            .sum::<f64>()
            / res.records.len() as f64;
        let round_s = step_s * epoch_steps as f64 + server_s;
        if p == 1 {
            t1 = round_s;
        }
        // per-machine memory = features+graph of its partition
        let mem = (ds.n() / p) as f64 * (ds.d as f64 * 4.0)
            + (ds.graph.indices.len() / p) as f64 * 4.0;
        println!(
            "{:>9} {:>12.3} {:>12.2} {:>12.2} {:>14.4}",
            p,
            round_s,
            t1 / round_s,
            mem / 1e6,
            res.final_val
        );
        rows.push(Json::obj(vec![
            ("machines", Json::num(p as f64)),
            ("round_s", Json::num(round_s)),
            ("speedup", Json::num(t1 / round_s)),
            ("mem_mb", Json::num(mem / 1e6)),
            ("val", Json::num(res.final_val)),
        ]));
    })?;
    opts.save("fig1", Json::arr(rows))
}

// ---------------------------------------------------------------------------
// Fig 2: PSGD-PA vs GGS (accuracy per round; bytes per round), Reddit analog.
// ---------------------------------------------------------------------------
fn fig2(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "reddit-s" };
    let arch = if opts.fast { "gcn" } else { "sage" };
    println!("Fig 2 — PSGD-PA vs GGS vs single-machine ({dataset}, P=8)");
    let sweep = Sweep::points(&opts.base_cfg(dataset, arch))
        .point(&[("algorithm", "psgd-pa".to_string())])
        .point(&[("algorithm", "ggs".to_string())])
        // single-machine upper bound rides the same sweep (dataset reused)
        .point(&[
            ("algorithm", "psgd-pa".to_string()),
            ("parts", "1".to_string()),
        ]);
    let mut out = Vec::new();
    sweep.run(&rt, |i, exp, res| {
        if i < 2 {
            println!(
                "  {:<10} final_val={:.4} avg_MB/round={:.3}",
                exp.config().algorithm.name(),
                res.final_val,
                res.avg_round_mb()
            );
        } else {
            println!(
                "  {:<10} final_val={:.4} (upper bound)",
                "single", res.final_val
            );
        }
        out.push(res.to_json());
    })?;
    opts.save("fig2", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 4: val score per round (a–d), global loss per round (e,f), score per
// byte (g,h) — all captured in the per-round records of each run.
// ---------------------------------------------------------------------------
fn fig4(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let datasets: Vec<&str> = if opts.fast {
        vec!["tiny"]
    } else {
        vec!["flickr-s", "proteins-s", "arxiv-s", "reddit-s"]
    };
    let arch = if opts.fast { "gcn" } else { "sage" };
    let mut out = Vec::new();
    for ds_name in &datasets {
        println!("Fig 4 — {ds_name} (P=8): val score / loss / bytes per round");
        println!(
            "  {:<10} {:>9} {:>10} {:>12}",
            "algo", "final", "glob_loss", "avg_MB/round"
        );
        let mut sweep = Sweep::points(&opts.base_cfg(ds_name, arch));
        for alg in algos3() {
            sweep = sweep.point(&algo_patch(alg));
        }
        sweep.run(&rt, |_i, exp, res| {
            let last_loss = res
                .records
                .iter()
                .rev()
                .find(|r| !r.global_loss.is_nan())
                .map(|r| r.global_loss)
                .unwrap_or(f64::NAN);
            println!(
                "  {:<10} {:>9.4} {:>10.4} {:>12.3}",
                exp.config().algorithm.name(),
                res.final_val,
                last_loss,
                res.avg_round_mb()
            );
            out.push(res.to_json());
        })?;
    }
    opts.save("fig4", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Table 1: score + avg MB/round for 3 algos × {GCN|SAGE, GAT, APPNP} × 4
// datasets, mean±std over seeds.
// ---------------------------------------------------------------------------
fn table1(opts: &ReproOpts) -> Result<()> {
    use std::collections::BTreeMap;
    let rt = load_rt(opts)?;
    let rows: Vec<(&str, Vec<&str>)> = if opts.fast {
        vec![("tiny", vec!["gcn", "sage"])]
    } else {
        vec![
            ("flickr-s", vec!["sage", "gat", "appnp"]),
            ("proteins-s", vec!["sage", "gat", "appnp"]),
            ("arxiv-s", vec!["sage", "gat", "appnp"]),
            ("reddit-s", vec!["sage", "gat", "appnp"]),
        ]
    };
    let seeds = if opts.fast { 1 } else { opts.seeds };
    let mut out = Vec::new();
    println!("Table 1 — score ± std and avg MB/round (seeds={seeds})");
    for (ds_name, archs) in &rows {
        // one sweep per seed (dataset + partition shared across its
        // arch × algo grid), results folded per (arch, algo)
        let mut scores: BTreeMap<(String, String), (Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for s in 0..seeds {
            let mut base = opts.base_cfg(ds_name, archs[0]);
            base.seed = opts.seed + s as u64;
            let mut sweep = Sweep::points(&base);
            for arch in archs {
                for alg in algos3() {
                    let mut patch: Vec<(&str, String)> =
                        vec![("arch", arch.to_string())];
                    patch.extend(algo_patch(alg));
                    sweep = sweep.point(&patch);
                }
            }
            sweep.run(&rt, |_i, exp, res| {
                let key = (
                    exp.config().arch.clone(),
                    exp.config().algorithm.name().to_string(),
                );
                let e = scores.entry(key).or_default();
                e.0.push(res.final_test);
                e.1.push(res.avg_round_mb());
            })?;
        }
        for arch in archs {
            for alg in algos3() {
                let (sc, mbs) =
                    &scores[&(arch.to_string(), alg.name().to_string())];
                let mean = crate::util::stats::mean(sc);
                let std = crate::util::stats::std(sc);
                println!(
                    "{:<12} {:<6} {:<10} {:>7.2}±{:<5.2} {:>10.3} MB",
                    ds_name,
                    arch,
                    alg.name(),
                    mean * 100.0,
                    std * 100.0,
                    crate::util::stats::mean(mbs)
                );
                out.push(Json::obj(vec![
                    ("dataset", Json::str(*ds_name)),
                    ("arch", Json::str(*arch)),
                    ("algorithm", Json::str(alg.name())),
                    ("score_mean", Json::num(mean)),
                    ("score_std", Json::num(std)),
                    ("avg_mb", Json::num(crate::util::stats::mean(mbs))),
                ]));
            }
        }
    }
    opts.save("table1", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 5: effect of local epoch size K (arxiv analog).
// ---------------------------------------------------------------------------
fn fig5(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "arxiv-s" };
    let arch = if opts.fast { "gcn" } else { "sage" };
    let ks: Vec<usize> = if opts.fast {
        vec![1, 4]
    } else {
        vec![1, 4, 16, 64, 128]
    };
    println!("Fig 5 — local epoch size K sweep ({dataset}, LLCG)");
    let base = opts.base_cfg(dataset, arch);
    let rounds = base.rounds.min(15); // large K makes rounds expensive
    let mut sweep = Sweep::points(&base);
    for &k in &ks {
        // same *round* budget: more local work per round for larger K
        // (algo_patch's rho survives the later local_steps — the schema
        // composes them in either order)
        let mut patch = algo_patch(Algorithm::Llcg);
        patch.push(("local_steps", k.to_string()));
        patch.push(("rounds", rounds.to_string()));
        sweep = sweep.point(&patch);
    }
    let mut out = Vec::new();
    sweep.run(&rt, |i, _exp, res| {
        let k = ks[i];
        println!(
            "  K={:<4} total_steps={:<6} final_val={:.4}",
            k, res.total_steps, res.final_val
        );
        out.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("total_steps", Json::num(res.total_steps as f64)),
            ("final_val", Json::num(res.final_val)),
            ("history", history_json(res)),
        ]));
    })?;
    opts.save("fig5", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 6: neighbor-sampling ratio × correction steps (reddit analog).
// ---------------------------------------------------------------------------
fn fig6(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "reddit-s" };
    let arch = if opts.fast { "gcn" } else { "sage" };
    let grid: Vec<(f64, usize)> = if opts.fast {
        vec![(1.0, 1), (0.2, 1)]
    } else {
        vec![
            (1.0, 1),
            (0.5, 1),
            (0.2, 1),
            (0.05, 1),
            (0.05, 4),
            (0.2, 4),
        ]
    };
    println!("Fig 6 — sampling ratio × correction steps ({dataset}, LLCG)");
    let mut sweep = Sweep::points(&opts.base_cfg(dataset, arch));
    for &(ratio, s) in &grid {
        let mut patch = algo_patch(Algorithm::Llcg);
        patch.push(("sample_ratio", ratio.to_string()));
        patch.push(("correction_steps", s.to_string()));
        sweep = sweep.point(&patch);
    }
    let mut out = Vec::new();
    sweep.run(&rt, |i, _exp, res| {
        let (ratio, s) = grid[i];
        println!(
            "  ratio={:<5} S={} final_val={:.4}",
            ratio, s, res.final_val
        );
        out.push(Json::obj(vec![
            ("sample_ratio", Json::num(ratio)),
            ("correction_steps", Json::num(s as f64)),
            ("final_val", Json::num(res.final_val)),
            ("history", history_json(res)),
        ]));
    })?;
    opts.save("fig6", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 7/8: full vs sampled neighbors in the correction step.
// ---------------------------------------------------------------------------
fn fig78(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let datasets: Vec<&str> = if opts.fast {
        vec!["tiny"]
    } else {
        vec!["reddit-s", "arxiv-s"]
    };
    let arch = if opts.fast { "gcn" } else { "sage" };
    let mut out = Vec::new();
    for ds_name in &datasets {
        println!("Fig 7/8 — correction sampling ({ds_name}, LLCG)");
        let mut sweep = Sweep::points(&opts.base_cfg(ds_name, arch));
        for full in [true, false] {
            let mut patch = algo_patch(Algorithm::Llcg);
            patch.push(("correction_full_neighbors", full.to_string()));
            sweep = sweep.point(&patch);
        }
        sweep.run(&rt, |_i, exp, res| {
            let full = exp.config().correction_full_neighbors;
            println!(
                "  correction {:<18} final_val={:.4}",
                if full { "full-neighbors" } else { "sampled-neighbors" },
                res.final_val
            );
            out.push(Json::obj(vec![
                ("dataset", Json::str(*ds_name)),
                ("full_neighbors", Json::Bool(full)),
                ("final_val", Json::num(res.final_val)),
                ("history", history_json(res)),
            ]));
        })?;
    }
    opts.save("fig78", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 9: uniform vs max-cut-edge correction batches.
// ---------------------------------------------------------------------------
fn fig9(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let datasets: Vec<&str> = if opts.fast {
        vec!["tiny"]
    } else {
        vec!["reddit-s", "arxiv-s"]
    };
    let arch = if opts.fast { "gcn" } else { "sage" };
    let mut out = Vec::new();
    for ds_name in &datasets {
        println!("Fig 9 — correction batch selection ({ds_name}, LLCG)");
        let mut sweep = Sweep::points(&opts.base_cfg(ds_name, arch));
        for batch in ["uniform", "max_cut"] {
            let mut patch = algo_patch(Algorithm::Llcg);
            patch.push(("correction_batch", batch.to_string()));
            sweep = sweep.point(&patch);
        }
        sweep.run(&rt, |_i, exp, res| {
            let batch = exp.config().correction_batch;
            println!("  {:<12?} final_val={:.4}", batch, res.final_val);
            out.push(Json::obj(vec![
                ("dataset", Json::str(*ds_name)),
                ("batch", Json::str(format!("{batch:?}"))),
                ("final_val", Json::num(res.final_val)),
                ("history", history_json(res)),
            ]));
        })?;
    }
    opts.save("fig9", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 10: structure-independent datasets — PSGD-PA ≈ GGS on yelp analog;
// MLP ≈ GCN there; products analog shows no gap either (small cut + split).
// ---------------------------------------------------------------------------
fn fig10(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let mut out = Vec::new();
    let yelp = if opts.fast { "tiny" } else { "yelp-s" };
    let base_arch = if opts.fast { "gcn" } else { "sage" };
    println!("Fig 10a — PSGD-PA vs GGS on {yelp}");
    let sweep = Sweep::over(
        &opts.base_cfg(yelp, base_arch),
        "algorithm",
        &["psgd-pa", "ggs"],
    );
    sweep.run(&rt, |_i, exp, res| {
        println!(
            "  {:<10} final_val={:.4}",
            exp.config().algorithm.name(),
            res.final_val
        );
        out.push(res.to_json());
    })?;

    println!("Fig 10b — GNN vs MLP on {yelp} (single machine)");
    let mut sweep = Sweep::points(&opts.base_cfg(yelp, base_arch));
    for arch in [base_arch, "mlp"] {
        sweep = sweep.point(&[
            ("arch", arch.to_string()),
            ("parts", "1".to_string()),
            ("algorithm", "psgd-pa".to_string()),
        ]);
    }
    sweep.run(&rt, |_i, exp, res| {
        println!(
            "  {:<10} final_val={:.4}",
            exp.config().arch,
            res.final_val
        );
        out.push(res.to_json());
    })?;

    if !opts.fast {
        println!("Fig 10c — PSGD-PA vs GGS on products-s");
        let sweep = Sweep::over(
            &opts.base_cfg("products-s", "sage"),
            "algorithm",
            &["psgd-pa", "ggs"],
        );
        sweep.run(&rt, |_i, exp, res| {
            println!(
                "  {:<10} final_val={:.4}",
                exp.config().algorithm.name(),
                res.final_val
            );
            out.push(res.to_json());
        })?;
    }
    opts.save("fig10", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Fig 11: 16 machines, PSGD-PA vs SubgraphApprox vs FullSync vs LLCG.
// ---------------------------------------------------------------------------
fn fig11(opts: &ReproOpts) -> Result<()> {
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "products-s" };
    println!("Fig 11 — large-scale setting ({dataset}, P=16)");
    let mut base = opts.base_cfg(dataset, if opts.fast { "gcn" } else { "sage" });
    base.parts = if opts.fast { 4 } else { 16 };
    let mut sweep = Sweep::points(&base);
    for alg in [
        Algorithm::PsgdPa,
        Algorithm::SubgraphApprox,
        Algorithm::FullSync,
        Algorithm::Llcg,
    ] {
        sweep = sweep.point(&algo_patch(alg));
    }
    let mut out = Vec::new();
    sweep.run(&rt, |_i, exp, res| {
        println!(
            "  {:<16} final_val={:.4} avg_MB/round={:.3}",
            exp.config().algorithm.name(),
            res.final_val,
            res.avg_round_mb()
        );
        out.push(res.to_json());
    })?;
    opts.save("fig11", Json::arr(out))
}

// ---------------------------------------------------------------------------
// Theory: measure κ_A², κ_X², σ²_bias across partitioners / homophily —
// the quantities behind Thm 1's irreducible residual.
// ---------------------------------------------------------------------------
fn theory(opts: &ReproOpts) -> Result<()> {
    use crate::coordinator::discrepancy;
    let rt = load_rt(opts)?;
    let dataset = if opts.fast { "tiny" } else { "arxiv-s" };
    let ds = generators::by_name(dataset, opts.seed).unwrap();
    let arch = "gcn";
    let meta = rt.meta(&Runtime::train_name(arch, "sgd", dataset))?.clone();
    let mut rng = crate::util::Pcg64::new(opts.seed);
    let params = crate::runtime::ModelState::init(&meta, &mut rng).params;
    println!("Theory — κ², σ²_bias by partitioner ({dataset}, P=8)");
    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>12}",
        "partition", "cut_ratio", "kappa_A^2", "kappa_X^2", "sigma_bias^2"
    );
    let mut out = Vec::new();
    for pname in ["metis", "random"] {
        let p = crate::api::registry::build_partitioner(pname)
            .map_err(|e| anyhow::anyhow!(e))?;
        let assignment = p.partition(&ds.graph, 8, &mut rng.split(7));
        let d = discrepancy::measure(
            &rt,
            arch,
            dataset,
            &params,
            &ds,
            &assignment,
            8,
            if opts.fast { 2 } else { 8 },
            opts.seed,
        )?;
        println!(
            "{:<10} {:>10.4} {:>12.4} {:>12.4} {:>12.4}",
            pname,
            ds.graph.cut_ratio(&assignment),
            d.kappa_a,
            d.kappa_x,
            d.sigma_bias
        );
        out.push(Json::obj(vec![
            ("partitioner", Json::str(pname)),
            ("cut_ratio", Json::num(ds.graph.cut_ratio(&assignment))),
            ("kappa_a", Json::num(d.kappa_a)),
            ("kappa_x", Json::num(d.kappa_x)),
            ("sigma_bias", Json::num(d.sigma_bias)),
        ]));
    }
    opts.save("theory", Json::arr(out))
}

fn history_json(res: &driver::RunResult) -> Json {
    Json::arr(
        res.records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("round", Json::num(r.round as f64)),
                    ("val", Json::num(r.val_score)),
                    ("loss", Json::num(r.global_loss)),
                    ("cum_bytes", Json::num(r.cum_bytes as f64)),
                ])
            })
            .collect(),
    )
}
