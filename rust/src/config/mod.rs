//! Run configuration: JSON-file + CLI-flag configuration for distributed
//! training runs, with dataset/algorithm/partitioner registries.

use crate::cluster::{Engine, NetModel, RoundMode};
use crate::coordinator::{Algorithm, CorrectionBatch, Schedule};
use crate::util::Json;

/// Everything needed to launch one distributed training run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: String,
    pub arch: String,
    pub algorithm: Algorithm,
    pub parts: usize,
    pub rounds: usize,
    pub schedule: Schedule,
    /// server correction steps per round (LLCG)
    pub correction_steps: usize,
    pub correction_batch: CorrectionBatch,
    /// full neighbors (capped) vs sampled neighbors in correction (Fig 7/8)
    pub correction_full_neighbors: bool,
    pub optimizer: String,
    /// optimizer for server correction steps ("sgd" is Alg. 2's γ-step;
    /// "adam" keeps persistent server Adam state across rounds)
    pub server_optimizer: String,
    pub lr: f32,
    /// server correction learning rate (γ in Alg. 2)
    pub server_lr: f32,
    pub partitioner: String,
    /// local neighbor-sampling ratio (Fig 6)
    pub sample_ratio: f64,
    /// extra-storage fraction for the SubgraphApprox baseline (Fig 11)
    pub approx_storage: f64,
    pub seed: u64,
    /// validate every `eval_every` rounds (1 = every round)
    pub eval_every: usize,
    /// cap on validation nodes scored per eval (0 = all)
    pub eval_max_nodes: usize,
    pub artifacts_dir: String,
    /// execution engine: legacy sequential driver vs threaded cluster
    pub engine: Engine,
    /// cluster round discipline: sync | async:<tau> | pipelined
    pub round_mode: RoundMode,
    /// modeled-network spec (`ideal` | `lan` | `wan` | `key=value,...`);
    /// validated at parse time, bound to the seed at engine start
    pub net: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "tiny".into(),
            arch: "gcn".into(),
            algorithm: Algorithm::Llcg,
            parts: 4,
            rounds: 20,
            schedule: Schedule::Fixed { k: 4 },
            correction_steps: 1,
            correction_batch: CorrectionBatch::Uniform,
            correction_full_neighbors: true,
            optimizer: "adam".into(),
            server_optimizer: "adam".into(),
            lr: 0.01,
            server_lr: 0.01,
            partitioner: "metis".into(),
            sample_ratio: 1.0,
            approx_storage: 0.1,
            seed: 0,
            eval_every: 1,
            eval_max_nodes: 512,
            artifacts_dir: "artifacts".into(),
            engine: Engine::Sequential,
            round_mode: RoundMode::Sync,
            net: "ideal".into(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON object (unknown keys rejected to catch typos).
    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        let obj = j.as_object().ok_or("config must be a json object")?;
        let mut cfg = ExperimentConfig::default();
        for (k, v) in obj {
            match k.as_str() {
                "dataset" => cfg.dataset = req_str(v, k)?,
                "arch" => cfg.arch = req_str(v, k)?,
                "algorithm" => {
                    cfg.algorithm = Algorithm::parse(&req_str(v, k)?)
                        .ok_or_else(|| format!("unknown algorithm {v}"))?
                }
                "parts" => cfg.parts = req_num(v, k)? as usize,
                "rounds" => cfg.rounds = req_num(v, k)? as usize,
                "local_steps" => {
                    cfg.schedule = Schedule::Fixed {
                        k: req_num(v, k)? as usize,
                    }
                }
                "rho" => {
                    let rho = req_num(v, k)?;
                    let k0 = match cfg.schedule {
                        Schedule::Fixed { k } => k,
                        Schedule::Exponential { k0, .. } => k0,
                    };
                    cfg.schedule = Schedule::Exponential { k0, rho };
                }
                "correction_steps" => cfg.correction_steps = req_num(v, k)? as usize,
                "correction_batch" => {
                    cfg.correction_batch = match req_str(v, k)?.as_str() {
                        "uniform" => CorrectionBatch::Uniform,
                        "max_cut" => CorrectionBatch::MaxCutEdges,
                        other => return Err(format!("unknown correction_batch {other}")),
                    }
                }
                "correction_full_neighbors" => {
                    cfg.correction_full_neighbors =
                        v.as_bool().ok_or(format!("{k} must be bool"))?
                }
                "optimizer" => cfg.optimizer = req_str(v, k)?,
                "server_optimizer" => cfg.server_optimizer = req_str(v, k)?,
                "lr" => cfg.lr = req_num(v, k)? as f32,
                "server_lr" => cfg.server_lr = req_num(v, k)? as f32,
                "partitioner" => cfg.partitioner = req_str(v, k)?,
                "sample_ratio" => cfg.sample_ratio = req_num(v, k)?,
                "approx_storage" => cfg.approx_storage = req_num(v, k)?,
                "seed" => cfg.seed = req_num(v, k)? as u64,
                "eval_every" => cfg.eval_every = req_num(v, k)? as usize,
                "eval_max_nodes" => cfg.eval_max_nodes = req_num(v, k)? as usize,
                "artifacts_dir" => cfg.artifacts_dir = req_str(v, k)?,
                "engine" => {
                    cfg.engine = Engine::parse(&req_str(v, k)?)
                        .ok_or_else(|| format!("unknown engine {v} (sequential|cluster)"))?
                }
                "round_mode" => {
                    cfg.round_mode = RoundMode::parse(&req_str(v, k)?).ok_or_else(|| {
                        format!("unknown round_mode {v} (sync|async:<tau>|pipelined)")
                    })?
                }
                "net" => {
                    let spec = req_str(v, k)?;
                    NetModel::parse(&spec)?; // validate here, re-parse at engine start
                    cfg.net = spec;
                }
                other => return Err(format!("unknown config key {other:?}")),
            }
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    /// Apply `--key=value` CLI overrides on top of this config. CLI-style
    /// dashes are accepted (`--round-mode` == `round_mode`).
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<(), String> {
        let key = key.replace('-', "_");
        let j = match key.as_str() {
            "dataset" | "arch" | "algorithm" | "optimizer" | "server_optimizer"
            | "partitioner" | "correction_batch" | "artifacts_dir" | "engine"
            | "round_mode" | "net" => Json::Str(value.to_string()),
            "correction_full_neighbors" => Json::Bool(value == "true" || value == "1"),
            _ => Json::Num(
                value
                    .parse::<f64>()
                    .map_err(|_| format!("bad numeric value for {key}: {value}"))?,
            ),
        };
        let mut obj = std::collections::BTreeMap::new();
        obj.insert(key.to_string(), j);
        let patch = Json::Object(obj);
        let merged = Self::from_json_onto(self.clone(), &patch)?;
        *self = merged;
        Ok(())
    }

    fn from_json_onto(base: ExperimentConfig, j: &Json) -> Result<ExperimentConfig, String> {
        // Re-parse the patch keys onto an existing config.
        let mut cfg = base;
        let obj = j.as_object().ok_or("patch must be object")?;
        for (k, v) in obj {
            let mut single = std::collections::BTreeMap::new();
            single.insert(k.clone(), v.clone());
            let parsed = Self::from_json(&Json::Object(single))?;
            match k.as_str() {
                "dataset" => cfg.dataset = parsed.dataset,
                "arch" => cfg.arch = parsed.arch,
                "algorithm" => cfg.algorithm = parsed.algorithm,
                "parts" => cfg.parts = parsed.parts,
                "rounds" => cfg.rounds = parsed.rounds,
                "local_steps" => cfg.schedule = parsed.schedule,
                "rho" => {
                    let k0 = match cfg.schedule {
                        Schedule::Fixed { k } => k,
                        Schedule::Exponential { k0, .. } => k0,
                    };
                    if let Schedule::Exponential { rho, .. } = parsed.schedule {
                        cfg.schedule = Schedule::Exponential { k0, rho };
                    }
                }
                "correction_steps" => cfg.correction_steps = parsed.correction_steps,
                "correction_batch" => cfg.correction_batch = parsed.correction_batch,
                "correction_full_neighbors" => {
                    cfg.correction_full_neighbors = parsed.correction_full_neighbors
                }
                "optimizer" => cfg.optimizer = parsed.optimizer,
                "server_optimizer" => cfg.server_optimizer = parsed.server_optimizer,
                "lr" => cfg.lr = parsed.lr,
                "server_lr" => cfg.server_lr = parsed.server_lr,
                "partitioner" => cfg.partitioner = parsed.partitioner,
                "sample_ratio" => cfg.sample_ratio = parsed.sample_ratio,
                "approx_storage" => cfg.approx_storage = parsed.approx_storage,
                "seed" => cfg.seed = parsed.seed,
                "eval_every" => cfg.eval_every = parsed.eval_every,
                "eval_max_nodes" => cfg.eval_max_nodes = parsed.eval_max_nodes,
                "artifacts_dir" => cfg.artifacts_dir = parsed.artifacts_dir,
                "engine" => cfg.engine = parsed.engine,
                "round_mode" => cfg.round_mode = parsed.round_mode,
                "net" => cfg.net = parsed.net,
                _ => unreachable!("from_json validated keys"),
            }
        }
        Ok(cfg)
    }
}

fn req_str(v: &Json, k: &str) -> Result<String, String> {
    v.as_str()
        .map(String::from)
        .ok_or(format!("{k} must be a string"))
}

fn req_num(v: &Json, k: &str) -> Result<f64, String> {
    v.as_f64().ok_or(format!("{k} must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let j = Json::parse(
            r#"{"dataset":"reddit-s","arch":"sage","algorithm":"llcg","parts":8,
                "rounds":75,"local_steps":4,"rho":1.1,"correction_steps":2,
                "lr":0.01,"partitioner":"metis","seed":3}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.dataset, "reddit-s");
        assert_eq!(cfg.parts, 8);
        assert!(matches!(
            cfg.schedule,
            Schedule::Exponential { k0: 4, rho } if (rho - 1.1).abs() < 1e-9
        ));
        assert_eq!(cfg.correction_steps, 2);
    }

    #[test]
    fn rejects_unknown_keys() {
        let j = Json::parse(r#"{"datset":"typo"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_override("parts", "8").unwrap();
        cfg.apply_override("algorithm", "psgd-pa").unwrap();
        cfg.apply_override("lr", "0.05").unwrap();
        assert_eq!(cfg.parts, 8);
        assert_eq!(cfg.algorithm, Algorithm::PsgdPa);
        assert!((cfg.lr - 0.05).abs() < 1e-9);
        assert!(cfg.apply_override("nope", "1").is_err());
    }

    #[test]
    fn engine_round_mode_net_keys() {
        let j = Json::parse(
            r#"{"engine":"cluster","round_mode":"async:2","net":"lan,scale=1"}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.engine, Engine::Cluster);
        assert_eq!(cfg.round_mode, RoundMode::AsyncStaleness { tau: 2 });
        assert_eq!(cfg.net, "lan,scale=1");
        // defaults keep legacy behavior
        let d = ExperimentConfig::default();
        assert_eq!(d.engine, Engine::Sequential);
        assert_eq!(d.round_mode, RoundMode::Sync);
        assert_eq!(d.net, "ideal");
        // bad values are rejected at parse time
        for bad in [
            r#"{"engine":"warp"}"#,
            r#"{"round_mode":"async:-1"}"#,
            r#"{"net":"adsl"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        // CLI spelling with dashes reaches the same keys
        let mut cfg = ExperimentConfig::default();
        cfg.apply_override("round-mode", "pipelined").unwrap();
        cfg.apply_override("engine", "cluster").unwrap();
        cfg.apply_override("net", "wan").unwrap();
        assert_eq!(cfg.round_mode, RoundMode::PipelinedCorrection);
        assert_eq!(cfg.engine, Engine::Cluster);
        assert!(cfg.apply_override("net", "nope=1").is_err());
    }
}
