//! Run configuration: the [`ExperimentConfig`] struct plus its JSON-file
//! and CLI-flag entry points.
//!
//! The parsing/override/help logic lives in one place — the
//! [`crate::api::keys`] schema table. Each config key is declared exactly
//! once there (name, kind, doc, parse+apply fn); `from_json`,
//! `apply_override`, unknown-key errors, and the generated
//! `llcg run --help` key listing are all derived from that table.

use crate::cluster::{Engine, RoundMode};
use crate::coordinator::{Algorithm, CorrectionBatch, Schedule};
use crate::util::Json;

/// Everything needed to launch one distributed training run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: String,
    pub arch: String,
    pub algorithm: Algorithm,
    pub parts: usize,
    pub rounds: usize,
    pub schedule: Schedule,
    /// server correction steps per round (LLCG)
    pub correction_steps: usize,
    pub correction_batch: CorrectionBatch,
    /// full neighbors (capped) vs sampled neighbors in correction (Fig 7/8)
    pub correction_full_neighbors: bool,
    pub optimizer: String,
    /// optimizer for server correction steps ("sgd" is Alg. 2's γ-step;
    /// "adam" keeps persistent server Adam state across rounds)
    pub server_optimizer: String,
    pub lr: f32,
    /// server correction learning rate (γ in Alg. 2)
    pub server_lr: f32,
    pub partitioner: String,
    /// local neighbor-sampling ratio (Fig 6)
    pub sample_ratio: f64,
    /// extra-storage fraction for the SubgraphApprox baseline (Fig 11)
    pub approx_storage: f64,
    pub seed: u64,
    /// validate every `eval_every` rounds (1 = every round)
    pub eval_every: usize,
    /// cap on validation nodes scored per eval (0 = all)
    pub eval_max_nodes: usize,
    pub artifacts_dir: String,
    /// execution engine: legacy sequential driver vs threaded cluster
    pub engine: Engine,
    /// cluster round discipline: sync | async:<tau> | pipelined
    pub round_mode: RoundMode,
    /// modeled-network spec (`ideal` | `lan` | `wan` | `key=value,...`);
    /// validated at parse time, bound to the seed at engine start
    pub net: String,
    /// native-kernel pool lanes per runtime (0 = auto: all host cores on
    /// the sequential engine, `cores / P` per cluster worker); results are
    /// bit-identical at any setting
    pub kernel_threads: usize,
    /// serving: micro-batch flush size (requests per inference batch)
    pub serve_batch: usize,
    /// serving: micro-batch flush deadline in microseconds after the
    /// batch's first request
    pub serve_flush_us: u64,
    /// serving: kernel-pool lanes for the inference server (0 = all cores)
    pub serve_threads: usize,
    /// serving: bounded request-queue depth (senders block when full)
    pub serve_queue: usize,
    /// serving: shed load when the queue is full (typed `Overloaded` reply)
    /// instead of blocking the producer
    pub serve_shed: bool,
    /// cluster sync: modeled-time deadline (seconds) after which the round
    /// closes on whatever quorum has arrived (0 = wait for everyone)
    pub round_timeout: f64,
    /// cluster sync: minimum params the server averages when the deadline
    /// fires (K-of-P; 0 = all P workers)
    pub quorum: usize,
    /// respawn crashed workers from the current global params (off = a dead
    /// worker stays dead and contributes nothing to later rounds)
    pub respawn: bool,
    /// write a checkpoint every N rounds (0 = off)
    pub checkpoint_every: usize,
    /// directory checkpoints are written under (`<dir>/round_<r>/`)
    pub checkpoint_dir: String,
    /// resume from a checkpoint directory ("" = fresh run)
    pub resume: String,
    /// cluster worker wire: `inprocess` (threads + modeled net),
    /// `tcp`/`uds` (real worker processes over the versioned wire
    /// protocol), with optional `,kill=p@r` process-kill faults
    pub transport: String,
    /// worker heartbeat period in milliseconds (process transports only);
    /// also the unit for liveness monitoring (a worker silent for several
    /// periods raises a monitor alert). Must be >= 10.
    pub heartbeat_ms: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "tiny".into(),
            arch: "gcn".into(),
            algorithm: Algorithm::Llcg,
            parts: 4,
            rounds: 20,
            schedule: Schedule::Fixed { k: 4 },
            correction_steps: 1,
            correction_batch: CorrectionBatch::Uniform,
            correction_full_neighbors: true,
            optimizer: "adam".into(),
            server_optimizer: "adam".into(),
            lr: 0.01,
            server_lr: 0.01,
            partitioner: "metis".into(),
            sample_ratio: 1.0,
            approx_storage: 0.1,
            seed: 0,
            eval_every: 1,
            eval_max_nodes: 512,
            artifacts_dir: "artifacts".into(),
            engine: Engine::Sequential,
            round_mode: RoundMode::Sync,
            net: "ideal".into(),
            kernel_threads: 0,
            serve_batch: 32,
            serve_flush_us: 200,
            serve_threads: 0,
            serve_queue: 1024,
            serve_shed: false,
            round_timeout: 0.0,
            quorum: 0,
            respawn: true,
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
            resume: String::new(),
            transport: "inprocess".into(),
            heartbeat_ms: 1000,
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON object (unknown keys rejected to catch typos).
    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        crate::api::keys::from_json(j)
    }

    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    /// Apply a `--key=value` CLI override on top of this config. CLI-style
    /// dashes are accepted (`--round-mode` == `round_mode`); unknown keys
    /// report the full key set, bad boolean literals are rejected.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<(), String> {
        crate::api::keys::apply_str(self, key, value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let j = Json::parse(
            r#"{"dataset":"reddit-s","arch":"sage","algorithm":"llcg","parts":8,
                "rounds":75,"local_steps":4,"rho":1.1,"correction_steps":2,
                "lr":0.01,"partitioner":"metis","seed":3}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.dataset, "reddit-s");
        assert_eq!(cfg.parts, 8);
        assert!(matches!(
            cfg.schedule,
            Schedule::Exponential { k0: 4, rho } if (rho - 1.1).abs() < 1e-9
        ));
        assert_eq!(cfg.correction_steps, 2);
    }

    #[test]
    fn rejects_unknown_keys() {
        let j = Json::parse(r#"{"datset":"typo"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
        assert!(err.contains("dataset"), "error lists the key table: {err}");
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_override("parts", "8").unwrap();
        cfg.apply_override("algorithm", "psgd-pa").unwrap();
        cfg.apply_override("lr", "0.05").unwrap();
        assert_eq!(cfg.parts, 8);
        assert_eq!(cfg.algorithm, Algorithm::PsgdPa);
        assert!((cfg.lr - 0.05).abs() < 1e-9);
        // an unknown string-valued key is reported as unknown, not as a
        // bad numeric value
        let err = cfg.apply_override("foo", "bar").unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn engine_round_mode_net_keys() {
        let j = Json::parse(
            r#"{"engine":"cluster","round_mode":"async:2","net":"lan,scale=1"}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.engine, Engine::Cluster);
        assert_eq!(cfg.round_mode, RoundMode::AsyncStaleness { tau: 2 });
        assert_eq!(cfg.net, "lan,scale=1");
        // defaults keep legacy behavior
        let d = ExperimentConfig::default();
        assert_eq!(d.engine, Engine::Sequential);
        assert_eq!(d.round_mode, RoundMode::Sync);
        assert_eq!(d.net, "ideal");
        // bad values are rejected at parse time
        for bad in [
            r#"{"engine":"warp"}"#,
            r#"{"round_mode":"async:-1"}"#,
            r#"{"net":"adsl"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        // CLI spelling with dashes reaches the same keys
        let mut cfg = ExperimentConfig::default();
        cfg.apply_override("round-mode", "pipelined").unwrap();
        cfg.apply_override("engine", "cluster").unwrap();
        cfg.apply_override("net", "wan").unwrap();
        assert_eq!(cfg.round_mode, RoundMode::PipelinedCorrection);
        assert_eq!(cfg.engine, Engine::Cluster);
        assert!(cfg.apply_override("net", "nope=1").is_err());
    }
}
