//! # LLCG — Learn Locally, Correct Globally (ICLR 2022) in Rust + JAX/Pallas
//!
//! A distributed GNN-training framework reproducing Ramezani et al., *"Learn
//! Locally, Correct Globally: A Distributed Algorithm for Training Graph
//! Neural Networks"*.
//!
//! Architecture (see `DESIGN.md`):
//! - **`api`** — the public experiment surface: single-source config
//!   schema (`api::keys`), pluggable dataset/partitioner/arch registries
//!   (`api::registry`), typed builder + streaming run sessions
//!   (`api::session`), and dataset/partition-reusing sweeps
//!   (`api::sweep`).
//! - **L3 (this crate)** — the coordinator: graph substrate, METIS-like
//!   partitioner, neighbor sampler / block builder, parameter server with
//!   *global server correction*, workers, communication accounting, and the
//!   algorithms (LLCG, PSGD-PA, GGS, FullSync, SubgraphApprox). Two
//!   execution engines run the round loop: the legacy sequential driver and
//!   the threaded `cluster` engine (per-worker OS threads + a parameter
//!   server over a modeled network; sync / bounded-staleness / pipelined
//!   round modes).
//! - **`serve`** — online inference on trained models: round-boundary
//!   model snapshots with atomic hot-swap (`serve::SnapshotHub`), a
//!   per-snapshot full-graph embedding cache, a micro-batching request
//!   server, and a deterministic load generator — scores bit-identical to
//!   the training-side eval path.
//! - **`obs`** — observability across all of the above: span tracing with
//!   a Chrome/Perfetto trace exporter behind a single relaxed atomic flag
//!   (zero overhead when off), an atomic counter/gauge/histogram registry,
//!   and a structured JSONL event log (`--trace` / `--metrics` /
//!   `--log-json`).
//! - **L2/L1 (`python/`, build-time only)** — JAX GNN models on Pallas
//!   aggregation kernels, AOT-lowered to HLO text artifacts.
//! - **runtime** — PJRT CPU client (`xla` crate) loading `artifacts/*.hlo.txt`.
//!
//! Python never runs on the training path: `make artifacts` once, then the
//! `llcg` binary is self-contained.

pub mod api;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod graph;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod testkit;
pub mod transport;
pub mod util;

pub use api::{Event, Experiment, ExperimentBuilder, Run, RunControl, Sweep};
pub use cluster::{Engine, NetModel, RoundMode};
pub use config::ExperimentConfig;
pub use coordinator::{Algorithm, RunResult};
pub use graph::{CsrGraph, Dataset};
pub use serve::{ModelSnapshot, Server, SnapshotHub};
