//! Structured JSONL event log: one compact JSON object per line, append
//! order = emit order. `llcg run --log-json runs/events.jsonl` streams the
//! `api::Event` sequence through [`JsonlLog`] and finishes with span
//! summaries (when tracing was on) and a metrics dump, so one file replays
//! the whole run for offline analysis without the binary.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::obs::trace::SpanSummary;
use crate::util::Json;

/// Line-buffered JSONL writer. Every record gets a `schema` field so
/// parsers can detect shape changes (see [`crate::obs::SCHEMA_VERSION`]).
pub struct JsonlLog {
    w: BufWriter<File>,
    path: PathBuf,
    lines: u64,
}

impl JsonlLog {
    /// Create (truncate) the log file, creating parent directories.
    pub fn create(path: &Path) -> Result<JsonlLog> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("creating event log {}", path.display()))?;
        Ok(JsonlLog {
            w: BufWriter::new(f),
            path: path.to_path_buf(),
            lines: 0,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn lines(&self) -> u64 {
        self.lines
    }

    /// Append one record as a single compact line, stamping `schema`.
    pub fn write(&mut self, record: Json) -> Result<()> {
        let stamped = match record {
            Json::Object(mut m) => {
                m.entry("schema".to_string())
                    .or_insert(Json::num(crate::obs::SCHEMA_VERSION as f64));
                Json::Object(m)
            }
            other => other,
        };
        writeln!(self.w, "{stamped}")
            .with_context(|| format!("writing event log {}", self.path.display()))?;
        self.lines += 1;
        Ok(())
    }

    /// Append the run-metadata header record (PID, hostname, wire/schema
    /// versions, config digest). The CLI writes this as the *first* line
    /// of every `--log-json` file so a directory of logs from many worker
    /// processes stays attributable; library users opt in explicitly.
    pub fn write_header(&mut self) -> Result<()> {
        self.write(Json::obj(vec![
            ("event", Json::str("run_meta")),
            ("meta", crate::obs::run_meta_json()),
        ]))
    }

    /// Append the end-of-run span summary records (one line per span name).
    pub fn write_span_summaries(&mut self, sums: &[SpanSummary]) -> Result<()> {
        for s in sums {
            self.write(Json::obj(vec![
                ("event", Json::str("span_summary")),
                ("name", Json::str(s.name)),
                ("count", Json::num(s.count as f64)),
                ("total_s", Json::num(s.total_s)),
                ("max_s", Json::num(s.max_s)),
            ]))?;
        }
        Ok(())
    }

    /// Append the final metrics dump record.
    pub fn write_metrics(&mut self) -> Result<()> {
        self.write(Json::obj(vec![
            ("event", Json::str("metrics")),
            ("metrics", crate::obs::metrics::metrics_json()),
        ]))
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w
            .flush()
            .with_context(|| format!("flushing event log {}", self.path.display()))
    }
}

impl Drop for JsonlLog {
    fn drop(&mut self) {
        let _ = self.w.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_lines_parse_and_carry_schema() {
        let dir = std::env::temp_dir().join("llcg-obs-events-test");
        let path = dir.join("events.jsonl");
        {
            let mut log = JsonlLog::create(&path).expect("create log");
            log.write(Json::obj(vec![
                ("event", Json::str("round_started")),
                ("round", Json::num(1.0)),
            ]))
            .unwrap();
            log.write_span_summaries(&[SpanSummary {
                name: "round.local",
                count: 4,
                total_s: 0.25,
                max_s: 0.1,
            }])
            .unwrap();
            log.write_metrics().unwrap();
            assert_eq!(log.lines(), 3);
            log.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).expect("read back");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let j = Json::parse(line).expect("every line is one JSON object");
            assert_eq!(
                j.req("schema").as_f64().unwrap() as u64,
                crate::obs::SCHEMA_VERSION
            );
        }
        assert_eq!(
            Json::parse(lines[1]).unwrap().req("name").as_str(),
            Some("round.local")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
