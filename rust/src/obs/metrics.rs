//! Process-wide metrics registry: atomic counters, gauges, and fixed-bucket
//! latency histograms.
//!
//! Instruments are registered by name on first use and live for the
//! process (`&'static` handles, leaked once): hot paths look a handle up
//! once and then pay only a relaxed atomic op per update — no locks, which
//! is what lets `serve::ServeStats` drop its per-request mutex.
//!
//! Histograms use power-of-two nanosecond buckets (`[2^i, 2^{i+1})`); the
//! reported percentiles interpolate inside the hit bucket with the same
//! rule as `util::stats` ([`Percentiles::of_buckets`]), so `--metrics`
//! latency columns and `BENCH_*.json` percentiles read on one scale.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::stats::Percentiles;
use crate::util::Json;

/// Monotonic atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the counter to `n` if below it (used for high-watermarks like
    /// the largest micro-batch).
    #[inline]
    pub fn record_max(&self, n: u64) {
        self.0.fetch_max(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins f64 gauge (stored as bits in an `AtomicU64`).
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, x: f64) {
        self.0.store(x.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Bucket count: `[2^0, 2^39)` ns spans 1 ns .. ~9 minutes, which covers
/// every latency this repo measures (bucket 0 also absorbs 0 ns).
pub const HIST_BUCKETS: usize = 40;

/// Fixed-bucket latency histogram (power-of-two nanosecond buckets).
/// Recording is one branch-free bucket index + three relaxed atomic adds.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

#[inline]
fn bucket_of(ns: u64) -> usize {
    // floor(log2(ns)) clamped to the table; 0 ns lands in bucket 0
    ((63 - (ns | 1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.counts[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_s(&self, secs: f64) {
        self.record_ns(if secs <= 0.0 { 0 } else { (secs * 1e9) as u64 });
    }

    /// Consistent-enough copy for reporting (individual fields are read
    /// relaxed; exact cross-field consistency is not needed for a report).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
            counts: std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed)),
        }
    }

    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time histogram contents.
#[derive(Clone, Copy, Debug)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
    pub counts: [u64; HIST_BUCKETS],
}

impl HistSnapshot {
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64 / 1e9
        }
    }

    pub fn max_s(&self) -> f64 {
        self.max_ns as f64 / 1e9
    }

    /// Bucket-interpolated percentiles in seconds (`None` when empty).
    pub fn percentiles_s(&self) -> Option<Percentiles> {
        if self.count == 0 {
            return None;
        }
        let bounds: Vec<(f64, f64)> = (0..HIST_BUCKETS)
            .map(|i| {
                let lo = if i == 0 { 0u64 } else { 1u64 << i };
                let hi = 1u64 << (i + 1);
                (lo as f64 / 1e9, hi as f64 / 1e9)
            })
            .collect();
        Some(Percentiles::of_buckets(&bounds, &self.counts))
    }
}

/// The process-wide instrument tables. One per process, behind
/// [`counter`]/[`gauge`]/[`histogram`] lookups; instruments are leaked so
/// handles are `&'static` and updates never re-enter the registry lock.
struct Registry {
    counters: Mutex<BTreeMap<&'static str, &'static Counter>>,
    gauges: Mutex<BTreeMap<&'static str, &'static Gauge>>,
    histograms: Mutex<BTreeMap<&'static str, &'static Histogram>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        histograms: Mutex::new(BTreeMap::new()),
    })
}

/// Look up (or register) the process-wide counter `name`. Cache the
/// returned handle on hot paths — the lookup itself takes the registry
/// lock.
pub fn counter(name: &'static str) -> &'static Counter {
    registry()
        .counters
        .lock()
        .expect("metrics registry poisoned")
        .entry(name)
        .or_insert_with(|| Box::leak(Box::new(Counter::new())))
}

/// Look up (or register) the process-wide gauge `name`.
pub fn gauge(name: &'static str) -> &'static Gauge {
    registry()
        .gauges
        .lock()
        .expect("metrics registry poisoned")
        .entry(name)
        .or_insert_with(|| Box::leak(Box::new(Gauge::new())))
}

/// Look up (or register) the process-wide histogram `name`.
pub fn histogram(name: &'static str) -> &'static Histogram {
    registry()
        .histograms
        .lock()
        .expect("metrics registry poisoned")
        .entry(name)
        .or_insert_with(|| Box::leak(Box::new(Histogram::new())))
}

/// Zero every registered instrument (bench/test isolation; the instruments
/// themselves stay registered).
pub fn reset_all() {
    let reg = registry();
    for c in reg.counters.lock().expect("poisoned").values() {
        c.reset();
    }
    for g in reg.gauges.lock().expect("poisoned").values() {
        g.reset();
    }
    for h in reg.histograms.lock().expect("poisoned").values() {
        h.reset();
    }
}

/// The `--metrics` end-of-run table: counters, gauges, then histograms
/// with count/mean/p50/p95/p99/max (latencies in milliseconds).
pub fn metrics_table() -> String {
    let reg = registry();
    let mut out = String::new();
    let counters = reg.counters.lock().expect("poisoned");
    let gauges = reg.gauges.lock().expect("poisoned");
    let histograms = reg.histograms.lock().expect("poisoned");
    if !counters.is_empty() {
        out.push_str("counters:\n");
        for (name, c) in counters.iter() {
            out.push_str(&format!("  {:<32} {}\n", name, c.get()));
        }
    }
    if !gauges.is_empty() {
        out.push_str("gauges:\n");
        for (name, g) in gauges.iter() {
            out.push_str(&format!("  {:<32} {:.6}\n", name, g.get()));
        }
    }
    if !histograms.is_empty() {
        out.push_str(&format!(
            "histograms (ms):\n  {:<32} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}\n",
            "name", "count", "mean", "p50", "p95", "p99", "max"
        ));
        for (name, h) in histograms.iter() {
            let s = h.snapshot();
            let p = s.percentiles_s();
            let (p50, p95, p99) = match p {
                Some(p) => (p.p50 * 1e3, p.p95 * 1e3, p.p99 * 1e3),
                None => (0.0, 0.0, 0.0),
            };
            out.push_str(&format!(
                "  {:<32} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3}\n",
                name,
                s.count,
                s.mean_s() * 1e3,
                p50,
                p95,
                p99,
                s.max_s() * 1e3,
            ));
        }
    }
    if out.is_empty() {
        out.push_str("(no metrics recorded)\n");
    }
    out
}

/// Sanitize an instrument name for Prometheus exposition: the repo's
/// dotted names (`transport.heartbeat_rtt_s`) become legal metric names
/// (`llcg_transport_heartbeat_rtt_s`), under one `llcg_` namespace.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 5);
    out.push_str("llcg_");
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() || ch == '_' || ch == ':' {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    out
}

/// Render a float for exposition with NaN/Inf clamped to 0 — the format
/// contract (and the CI scrape check) is that `/metrics` is NaN-free.
fn prom_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

/// The whole registry in Prometheus text exposition format (version
/// 0.0.4): counters and gauges as single samples, histograms as
/// cumulative `le` buckets + `_sum`/`_count`, with the power-of-two
/// nanosecond buckets mapped to their upper bounds in seconds. Bucket
/// lines are emitted only where the cumulative count changes (plus the
/// mandatory `+Inf`), which is valid exposition and keeps 40-bucket
/// histograms readable.
pub fn prometheus_text() -> String {
    let reg = registry();
    let mut out = String::new();
    for (name, c) in reg.counters.lock().expect("poisoned").iter() {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
    }
    for (name, g) in reg.gauges.lock().expect("poisoned").iter() {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", prom_num(g.get())));
    }
    for (name, h) in reg.histograms.lock().expect("poisoned").iter() {
        let n = prom_name(name);
        let s = h.snapshot();
        out.push_str(&format!("# TYPE {n} histogram\n"));
        let mut cum = 0u64;
        for (i, &c) in s.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            // bucket i covers [2^i, 2^(i+1)) ns: upper bound in seconds
            let le = (1u64 << (i + 1)) as f64 / 1e9;
            out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cum}\n"));
        }
        out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", s.count));
        out.push_str(&format!("{n}_sum {}\n", prom_num(s.sum_ns as f64 / 1e9)));
        out.push_str(&format!("{n}_count {}\n", s.count));
    }
    out
}

/// Flat `name -> value` view of the registry for the time-series sampler
/// (`obs/timeseries`): counters and gauges verbatim, histograms as
/// derived `.count`/`.mean_s`/`.p95_s`/`.max_s` series.
pub fn sample_flat() -> Vec<(String, f64)> {
    let reg = registry();
    let mut out = Vec::new();
    for (name, c) in reg.counters.lock().expect("poisoned").iter() {
        out.push(((*name).to_string(), c.get() as f64));
    }
    for (name, g) in reg.gauges.lock().expect("poisoned").iter() {
        out.push(((*name).to_string(), g.get()));
    }
    for (name, h) in reg.histograms.lock().expect("poisoned").iter() {
        let s = h.snapshot();
        let p95 = s.percentiles_s().map_or(0.0, |p| p.p95);
        out.push((format!("{name}.count"), s.count as f64));
        out.push((format!("{name}.mean_s"), s.mean_s()));
        out.push((format!("{name}.p95_s"), p95));
        out.push((format!("{name}.max_s"), s.max_s()));
    }
    out
}

/// Every registered instrument as one JSON object (for the `--log-json`
/// final record).
pub fn metrics_json() -> Json {
    let reg = registry();
    let counters: Vec<Json> = reg
        .counters
        .lock()
        .expect("poisoned")
        .iter()
        .map(|(name, c)| {
            Json::obj(vec![
                ("name", Json::str(*name)),
                ("value", Json::num(c.get() as f64)),
            ])
        })
        .collect();
    let gauges: Vec<Json> = reg
        .gauges
        .lock()
        .expect("poisoned")
        .iter()
        .map(|(name, g)| {
            Json::obj(vec![
                ("name", Json::str(*name)),
                ("value", Json::num(g.get())),
            ])
        })
        .collect();
    let histograms: Vec<Json> = reg
        .histograms
        .lock()
        .expect("poisoned")
        .iter()
        .map(|(name, h)| {
            let s = h.snapshot();
            let p = s.percentiles_s();
            Json::obj(vec![
                ("name", Json::str(*name)),
                ("count", Json::num(s.count as f64)),
                ("mean_s", Json::num(s.mean_s())),
                ("p50_s", Json::num(p.map_or(0.0, |p| p.p50))),
                ("p95_s", Json::num(p.map_or(0.0, |p| p.p95))),
                ("p99_s", Json::num(p.map_or(0.0, |p| p.p99))),
                ("max_s", Json::num(s.max_s())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("meta", super::run_meta_json()),
        ("counters", Json::arr(counters)),
        ("gauges", Json::arr(gauges)),
        ("histograms", Json::arr(histograms)),
    ])
}

/// Every registered instrument in *lossless* form, for shipping a worker
/// process's registry to the server over the transport: histograms carry
/// their raw power-of-two bucket counts (not interpolated percentiles) and
/// `sum_ns`/`max_ns` travel as hex strings so u64 values survive the f64
/// JSON number type exactly. Inverse of [`absorb_metrics_json`].
pub fn metrics_raw_json() -> Json {
    let reg = registry();
    let counters: Vec<Json> = reg
        .counters
        .lock()
        .expect("poisoned")
        .iter()
        .map(|(name, c)| {
            Json::obj(vec![
                ("name", Json::str(*name)),
                ("value", Json::str(&format!("{:x}", c.get()))),
            ])
        })
        .collect();
    let gauges: Vec<Json> = reg
        .gauges
        .lock()
        .expect("poisoned")
        .iter()
        .map(|(name, g)| {
            Json::obj(vec![
                ("name", Json::str(*name)),
                ("value", Json::num(g.get())),
            ])
        })
        .collect();
    let histograms: Vec<Json> = reg
        .histograms
        .lock()
        .expect("poisoned")
        .iter()
        .map(|(name, h)| {
            let s = h.snapshot();
            Json::obj(vec![
                ("name", Json::str(*name)),
                ("count", Json::str(&format!("{:x}", s.count))),
                ("sum_ns", Json::str(&format!("{:x}", s.sum_ns))),
                ("max_ns", Json::str(&format!("{:x}", s.max_ns))),
                (
                    "counts",
                    Json::arr(
                        s.counts
                            .iter()
                            .map(|&c| Json::str(&format!("{c:x}")))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("counters", Json::arr(counters)),
        ("gauges", Json::arr(gauges)),
        ("histograms", Json::arr(histograms)),
    ])
}

fn hex_u64(j: &Json, key: &str) -> Result<u64, String> {
    let s = j
        .get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("metrics payload missing hex field {key:?}"))?;
    u64::from_str_radix(s, 16).map_err(|e| format!("bad hex u64 in {key:?}: {e}"))
}

/// Merge a worker process's [`metrics_raw_json`] payload into this
/// process's registry: counters add, gauges overwrite when the incoming
/// value is non-zero (last writer wins, but a worker that never touched a
/// gauge must not clobber the server's), histograms merge bucket-by-bucket.
/// Lives here because [`Histogram`]'s atomics are private to this module.
pub fn absorb_metrics_json(j: &Json) -> Result<(), String> {
    let name_of = |entry: &Json| -> Result<&'static str, String> {
        entry
            .get("name")
            .and_then(|v| v.as_str())
            .map(super::trace::intern)
            .ok_or_else(|| "metrics entry missing name".to_string())
    };
    for entry in j
        .get("counters")
        .and_then(|v| v.as_array())
        .ok_or("metrics payload missing counters")?
    {
        counter(name_of(entry)?).add(hex_u64(entry, "value")?);
    }
    for entry in j
        .get("gauges")
        .and_then(|v| v.as_array())
        .ok_or("metrics payload missing gauges")?
    {
        let v = entry
            .get("value")
            .and_then(|v| v.as_f64())
            .ok_or("gauge entry missing value")?;
        if v != 0.0 {
            gauge(name_of(entry)?).set(v);
        }
    }
    for entry in j
        .get("histograms")
        .and_then(|v| v.as_array())
        .ok_or("metrics payload missing histograms")?
    {
        let h = histogram(name_of(entry)?);
        let counts = entry
            .get("counts")
            .and_then(|v| v.as_array())
            .ok_or("histogram entry missing counts")?;
        if counts.len() != HIST_BUCKETS {
            return Err(format!(
                "histogram bucket count {} != {HIST_BUCKETS}",
                counts.len()
            ));
        }
        for (i, c) in counts.iter().enumerate() {
            let c = c
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or("bad hex bucket count")?;
            h.counts[i].fetch_add(c, Ordering::Relaxed);
        }
        h.count.fetch_add(hex_u64(entry, "count")?, Ordering::Relaxed);
        h.sum_ns
            .fetch_add(hex_u64(entry, "sum_ns")?, Ordering::Relaxed);
        h.max_ns
            .fetch_max(hex_u64(entry, "max_ns")?, Ordering::Relaxed);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        c.record_max(3); // below current 5: no-op
        assert_eq!(c.get(), 5);
        c.record_max(11);
        assert_eq!(c.get(), 11);
        let g = Gauge::new();
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn histogram_buckets_and_percentiles() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        let h = Histogram::new();
        assert!(h.snapshot().percentiles_s().is_none());
        // 1000 recordings of ~1 us and one ~1 ms outlier
        for _ in 0..1000 {
            h.record_ns(1_000);
        }
        h.record_ns(1_000_000);
        let s = h.snapshot();
        assert_eq!(s.count, 1001);
        assert_eq!(s.max_ns, 1_000_000);
        let p = s.percentiles_s().expect("non-empty");
        // p50 in the 1 us bucket [1024ns, 2048ns); p99 must stay well
        // below the outlier bucket
        assert!(p.p50 > 0.5e-6 && p.p50 < 3e-6, "p50 {}", p.p50);
        assert!(p.p99 < 1e-4, "p99 {}", p.p99);
        assert!(s.mean_s() > 1e-6 && s.mean_s() < 3e-6);
        h.reset();
        assert_eq!(h.snapshot().count, 0);
    }

    #[test]
    fn raw_json_absorb_round_trips_losslessly() {
        let src = counter("test.obs-absorb-counter");
        src.reset();
        src.add(7);
        let h = histogram("test.obs-absorb-hist");
        h.reset();
        h.record_ns(1_000);
        h.record_ns((1u64 << 53) + 1); // not representable as f64
        let g = gauge("test.obs-absorb-gauge");
        g.set(2.5);
        let payload = metrics_raw_json();
        // wipe, then absorb the serialized registry back
        src.reset();
        h.reset();
        g.set(0.0);
        absorb_metrics_json(&payload).expect("absorb");
        assert_eq!(src.get(), 7);
        assert_eq!(g.get(), 2.5);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.max_ns, (1u64 << 53) + 1, "hex fields survive exactly");
        assert_eq!(s.sum_ns, (1u64 << 53) + 1 + 1_000);
        // absorbing again accumulates counters/histograms
        absorb_metrics_json(&payload).expect("absorb twice");
        assert_eq!(src.get(), 14);
        assert_eq!(h.snapshot().count, 4);
        // malformed payloads are typed errors, not panics
        assert!(absorb_metrics_json(&Json::obj(vec![])).is_err());
        src.reset();
        h.reset();
        g.set(0.0);
    }

    #[test]
    fn prometheus_text_golden() {
        let c = counter("test.prom-golden.counter");
        c.reset();
        c.add(7);
        let g = gauge("test.prom-golden.gauge");
        g.set(2.5);
        let h = histogram("test.prom-golden.hist");
        h.reset();
        h.record_ns(1_500); // bucket 10: [1024, 2048) ns -> le 2.048e-6 s
        h.record_ns(1_600); // same bucket
        h.record_ns(3_000_000); // bucket 21: le (1<<22)/1e9 s
        let text = prometheus_text();
        for want in [
            "# TYPE llcg_test_prom_golden_counter counter\nllcg_test_prom_golden_counter 7\n",
            "# TYPE llcg_test_prom_golden_gauge gauge\nllcg_test_prom_golden_gauge 2.5\n",
            "# TYPE llcg_test_prom_golden_hist histogram\n",
            "llcg_test_prom_golden_hist_bucket{le=\"0.000002048\"} 2\n",
            &format!(
                "llcg_test_prom_golden_hist_bucket{{le=\"{}\"}} 3\n",
                (1u64 << 22) as f64 / 1e9
            ),
            "llcg_test_prom_golden_hist_bucket{le=\"+Inf\"} 3\n",
            "llcg_test_prom_golden_hist_sum 0.0030031\n",
            "llcg_test_prom_golden_hist_count 3\n",
        ] {
            assert!(text.contains(want), "missing {want:?} in:\n{text}");
        }
        // cumulative le buckets must be non-decreasing within a histogram
        let b2 = text.find("llcg_test_prom_golden_hist_bucket{le=\"0.000002048\"} 2");
        let b3 = text.find("llcg_test_prom_golden_hist_bucket{le=\"+Inf\"} 3");
        assert!(b2.unwrap() < b3.unwrap(), "bucket order");
        // a NaN gauge must not leak NaN into the exposition
        g.set(f64::NAN);
        let text = prometheus_text();
        assert!(!text.contains("NaN"), "NaN leaked:\n{text}");
        assert!(text.contains("llcg_test_prom_golden_gauge 0\n"));
        c.reset();
        g.set(0.0);
        h.reset();
    }

    #[test]
    fn concurrent_recording_snapshots_are_consistent() {
        // hammer one histogram + counter from 4 threads while snapshotting:
        // every snapshot's bucket sum must equal its count field exactly
        // once quiescent, and mid-flight snapshots must stay monotone
        let h = histogram("test.prom-concurrent.hist");
        h.reset();
        let c = counter("test.prom-concurrent.counter");
        c.reset();
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let workers: Vec<_> = (0..4)
            .map(|t| {
                let stop = std::sync::Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        h.record_ns(100 + t * 1000);
                        c.inc();
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        let mut last_count = 0u64;
        for _ in 0..200 {
            let s = h.snapshot();
            assert!(s.count >= last_count, "count went backwards");
            last_count = s.count;
            let _ = prometheus_text(); // render under fire: no panic
        }
        stop.store(true, Ordering::Relaxed);
        let total: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
        let s = h.snapshot();
        assert_eq!(s.count, total, "histogram lost recordings");
        assert_eq!(s.counts.iter().sum::<u64>(), total, "buckets disagree with count");
        assert_eq!(c.get(), total, "counter lost increments");
        h.reset();
        c.reset();
    }

    #[test]
    fn sample_flat_covers_every_instrument_kind() {
        let c = counter("test.flat.counter");
        c.reset();
        c.add(2);
        let g = gauge("test.flat.gauge");
        g.set(1.5);
        let h = histogram("test.flat.hist");
        h.reset();
        h.record_s(1e-3);
        let flat: std::collections::BTreeMap<String, f64> =
            sample_flat().into_iter().collect();
        assert_eq!(flat["test.flat.counter"], 2.0);
        assert_eq!(flat["test.flat.gauge"], 1.5);
        assert_eq!(flat["test.flat.hist.count"], 1.0);
        assert!(flat["test.flat.hist.mean_s"] > 0.0);
        assert!(flat.contains_key("test.flat.hist.p95_s"));
        assert!(flat.contains_key("test.flat.hist.max_s"));
        c.reset();
        g.set(0.0);
        h.reset();
    }

    #[test]
    fn registry_handles_are_shared() {
        let a = counter("test.obs-registry-counter");
        let b = counter("test.obs-registry-counter");
        let before = a.get();
        b.add(2);
        assert_eq!(a.get(), before + 2);
        let h1 = histogram("test.obs-registry-hist");
        let h2 = histogram("test.obs-registry-hist");
        let n0 = h1.snapshot().count;
        h2.record_s(1e-6);
        assert_eq!(h1.snapshot().count, n0 + 1);
        // tables render without panicking and include the names
        let t = metrics_table();
        assert!(t.contains("test.obs-registry-counter"));
        let j = metrics_json();
        assert!(j.to_string().contains("test.obs-registry-hist"));
    }
}
