//! Std-only HTTP exposition server — the live telemetry plane.
//!
//! [`Exporter::bind`] opens a plain `TcpListener` (the CLI's structural
//! `--listen <addr>` flag on `llcg run` / `llcg serve`) and serves four
//! read-only routes from one accept thread:
//!
//! | route      | content                                              |
//! |------------|------------------------------------------------------|
//! | `/metrics` | the whole registry in Prometheus text format         |
//! | `/health`  | engine state, live workers, last round, staleness    |
//! | `/run`     | the trailing `api::Event` stream as JSON             |
//! | `/series`  | the rolling registry time series (`obs/timeseries`)  |
//!
//! Everything served is a read of state the process already maintains
//! (relaxed-atomic instrument reads, a mutexed health/event tail the run
//! loop pushes into); requests never touch training state, so the
//! bit-exactness contracts hold with the exporter up. With no `--listen`
//! flag none of this exists — no socket, no thread, no cost.
//!
//! The implementation speaks just enough HTTP/1.1 for `curl`, Prometheus,
//! and browsers: request-line parsing, `Connection: close`, fixed
//! `Content-Length` responses.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::timeseries::SeriesRing;
use crate::util::Json;

/// Events retained for the `/run` tail.
const EVENT_TAIL: usize = 256;

/// Run-health snapshot served at `/health`. The run loop overwrites it
/// at every event; the exporter only ever reads.
#[derive(Clone, Debug)]
pub struct RunHealth {
    /// "starting" | "running" | "finished" | "serving"
    pub state: String,
    pub engine: String,
    pub parts: usize,
    pub rounds: usize,
    /// last completed round (0 before the first boundary)
    pub last_round: usize,
    /// contributors to the last completed round (= parts at full strength)
    pub live_workers: usize,
    /// staleness high-water mark (async round modes; 0 under sync)
    pub staleness_hwm: u64,
    /// monitor alerts emitted so far
    pub alerts: u64,
}

impl RunHealth {
    pub fn new(engine: &str, parts: usize, rounds: usize) -> RunHealth {
        RunHealth {
            state: "starting".into(),
            engine: engine.into(),
            parts,
            rounds,
            last_round: 0,
            live_workers: parts,
            staleness_hwm: 0,
            alerts: 0,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::num(super::SCHEMA_VERSION as f64)),
            ("state", Json::str(&self.state)),
            ("engine", Json::str(&self.engine)),
            ("parts", Json::num(self.parts as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("last_round", Json::num(self.last_round as f64)),
            ("live_workers", Json::num(self.live_workers as f64)),
            ("staleness_hwm", Json::num(self.staleness_hwm as f64)),
            ("alerts", Json::num(self.alerts as f64)),
            ("meta", super::run_meta_json()),
        ])
    }
}

struct ExporterState {
    health: Mutex<RunHealth>,
    events: Mutex<VecDeque<Json>>,
    series: Mutex<Option<SeriesRing>>,
}

/// The live exposition server; see the module docs for the routes.
pub struct Exporter {
    addr: SocketAddr,
    state: Arc<ExporterState>,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Exporter {
    /// Bind `addr` (e.g. `127.0.0.1:9184`; port 0 picks a free port —
    /// read the result back from [`Exporter::addr`]) and start serving.
    pub fn bind(addr: &str) -> std::io::Result<Exporter> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ExporterState {
            health: Mutex::new(RunHealth::new("", 0, 0)),
            events: Mutex::new(VecDeque::with_capacity(EVENT_TAIL)),
            series: Mutex::new(None),
        });
        let shutdown = Arc::new(AtomicBool::new(false));
        let thread_state = Arc::clone(&state);
        let thread_shutdown = Arc::clone(&shutdown);
        let handle = std::thread::Builder::new()
            .name("obs-exporter".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if thread_shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        let _ = serve_one(stream, &thread_state);
                    }
                }
            })
            .expect("spawn obs-exporter thread");
        Ok(Exporter {
            addr,
            state,
            shutdown,
            handle: Some(handle),
        })
    }

    /// The actually-bound address (resolves `:0` to the chosen port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Overwrite the `/health` snapshot.
    pub fn set_health(&self, health: RunHealth) {
        *self.state.health.lock().expect("exporter health poisoned") = health;
    }

    /// Append one event to the `/run` tail (oldest fall off past the cap).
    pub fn push_event(&self, event: Json) {
        let mut q = self.state.events.lock().expect("exporter events poisoned");
        if q.len() == EVENT_TAIL {
            q.pop_front();
        }
        q.push_back(event);
    }

    /// Attach the time-series ring backing `/series`.
    pub fn attach_series(&self, ring: SeriesRing) {
        *self.state.series.lock().expect("exporter series poisoned") = Some(ring);
    }

    /// Stop accepting and join the server thread.
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        if self.handle.is_none() {
            return;
        }
        self.shutdown.store(true, Ordering::Relaxed);
        // unblock the accept loop with one throwaway connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Exporter {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Read one request, route it, write one response, close.
fn serve_one(mut stream: TcpStream, state: &ExporterState) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let path = match read_request_path(&mut stream)? {
        Some(p) => p,
        None => return Ok(()), // empty/garbled request (e.g. the shutdown poke)
    };
    let (status, content_type, body) = match path.as_str() {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            super::metrics::prometheus_text(),
        ),
        "/health" => (
            "200 OK",
            "application/json",
            state
                .health
                .lock()
                .expect("exporter health poisoned")
                .to_json()
                .to_string_pretty(),
        ),
        "/run" => {
            let events: Vec<Json> = state
                .events
                .lock()
                .expect("exporter events poisoned")
                .iter()
                .cloned()
                .collect();
            let doc = Json::obj(vec![
                ("schema", Json::num(super::SCHEMA_VERSION as f64)),
                ("events", Json::arr(events)),
            ]);
            ("200 OK", "application/json", doc.to_string_pretty())
        }
        "/series" => {
            let doc = match &*state.series.lock().expect("exporter series poisoned") {
                Some(ring) => ring.to_json(),
                None => Json::obj(vec![
                    ("schema", Json::num(super::SCHEMA_VERSION as f64)),
                    ("samples", Json::arr(Vec::new())),
                ]),
            };
            ("200 OK", "application/json", doc.to_string_pretty())
        }
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "unknown route; try /metrics /health /run /series\n".to_string(),
        ),
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Parse `GET <path> HTTP/1.x` off the wire; drains headers best-effort
/// (the socket closes right after the response anyway).
fn read_request_path(stream: &mut TcpStream) -> std::io::Result<Option<String>> {
    let mut buf = [0u8; 2048];
    let mut filled = 0usize;
    // read until the request line is complete (first "\r\n")
    loop {
        if filled == buf.len() {
            return Ok(None); // request line longer than any route we serve
        }
        let n = match stream.read(&mut buf[filled..]) {
            Ok(0) => return Ok(None),
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(None),
            Err(e) if e.kind() == std::io::ErrorKind::TimedOut => return Ok(None),
            Err(e) => return Err(e),
        };
        filled += n;
        if buf[..filled].windows(2).any(|w| w == b"\r\n") {
            break;
        }
    }
    let line_end = buf[..filled]
        .windows(2)
        .position(|w| w == b"\r\n")
        .unwrap_or(filled);
    let line = String::from_utf8_lossy(&buf[..line_end]);
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    if method != "GET" || target.is_empty() {
        return Ok(None);
    }
    // strip any query string; Prometheus appends none but browsers might
    let path = target.split('?').next().unwrap_or(target);
    Ok(Some(path.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).expect("connect exporter");
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read response");
        let (head, body) = out.split_once("\r\n\r\n").expect("no header break");
        (head.to_string(), body.to_string())
    }

    #[test]
    fn exporter_serves_all_routes_and_404s_unknown() {
        let exporter = Exporter::bind("127.0.0.1:0").expect("bind");
        let addr = exporter.addr();
        assert_ne!(addr.port(), 0, "port 0 must resolve to a real port");

        let c = super::super::counter("test.obs-exporter-counter");
        c.reset();
        c.add(3);
        let (head, body) = http_get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.contains("llcg_test_obs_exporter_counter 3"), "{body}");

        let mut health = RunHealth::new("cluster", 4, 8);
        health.state = "running".into();
        health.last_round = 5;
        exporter.set_health(health);
        let (_, body) = http_get(addr, "/health");
        let j = Json::parse(&body).expect("health json");
        assert_eq!(j.get("state").and_then(Json::as_str), Some("running"));
        assert_eq!(j.get("last_round").and_then(Json::as_f64), Some(5.0));
        assert!(j.get("meta").is_some(), "health carries run metadata");

        exporter.push_event(Json::obj(vec![("event", Json::str("round_started"))]));
        let (_, body) = http_get(addr, "/run");
        let j = Json::parse(&body).expect("run json");
        assert_eq!(
            j.get("events").and_then(Json::as_array).map(|a| a.len()),
            Some(1)
        );

        // /series before a ring is attached: empty but well-formed
        let (_, body) = http_get(addr, "/series");
        let j = Json::parse(&body).expect("series json");
        assert_eq!(
            j.get("samples").and_then(Json::as_array).map(|a| a.len()),
            Some(0)
        );
        let sampler = super::super::timeseries::Sampler::start(1000, 16);
        let ring = sampler.ring();
        ring.sample_now();
        exporter.attach_series(ring);
        let (_, body) = http_get(addr, "/series");
        let j = Json::parse(&body).expect("series json");
        assert_eq!(
            j.get("samples").and_then(Json::as_array).map(|a| a.len()),
            Some(1)
        );

        let (head, _) = http_get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
        c.reset();
        exporter.shutdown();
    }

    #[test]
    fn event_tail_is_bounded() {
        let exporter = Exporter::bind("127.0.0.1:0").expect("bind");
        for i in 0..(EVENT_TAIL + 10) {
            exporter.push_event(Json::num(i as f64));
        }
        let (_, body) = http_get(exporter.addr(), "/run");
        let j = Json::parse(&body).expect("run json");
        let events = j.get("events").and_then(Json::as_array).unwrap();
        assert_eq!(events.len(), EVENT_TAIL);
        assert_eq!(events[0].as_f64(), Some(10.0), "oldest events fell off");
        exporter.shutdown();
    }
}
