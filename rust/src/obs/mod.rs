//! Observability: zero-overhead-when-off span tracing, a process-wide
//! metrics registry, and a structured JSONL event log.
//!
//! Three layers, all std-only (see `rust/src/obs/README.md` for the span
//! naming convention and the overhead contract):
//!
//! - [`trace`] — per-thread span buffers behind one relaxed atomic flag.
//!   `obs::span("name")` costs a single branch while tracing is disabled;
//!   enabled spans record `(name, tid, start, dur)` into a thread-local
//!   buffer (no locks on the hot path) and export as Chrome trace-event
//!   JSON (`llcg run --trace trace.json`, loadable in `chrome://tracing`
//!   or <https://ui.perfetto.dev>).
//! - [`metrics`] — atomic counters/gauges plus fixed-bucket latency
//!   histograms whose percentiles reuse the `util::stats` interpolation
//!   rule. Always on: every instrument is a relaxed atomic op.
//! - [`events`] — a JSONL sink serializing the `api::Event` stream (one
//!   object per line, `llcg run --log-json runs/events.jsonl`) plus
//!   end-of-run span summaries.
//!
//! Instrumentation never touches RNG streams, float accumulation order, or
//! iteration order — only clocks and atomics — so every bit-exactness
//! contract in the repo (cluster sync ≡ sequential, serve ≡ eval path,
//! checkpoint resume replay) holds with tracing and metrics on. This is
//! asserted end-to-end in `rust/tests/obs.rs`.

pub mod events;
pub mod metrics;
pub mod trace;

pub use events::JsonlLog;
pub use metrics::{
    absorb_metrics_json, counter, gauge, histogram, metrics_json, metrics_raw_json, metrics_table,
    reset_all, Counter, Gauge, Histogram,
};
pub use trace::{
    chrome_trace_json, chrome_trace_json_multi, enabled, set_enabled, span, span_round,
    spans_from_json, spans_to_json, summarize, take_spans, write_chrome_trace, Span, SpanRec,
    SpanSummary,
};

/// Version of every JSON shape this repo emits (`llcg run --json`,
/// `BENCH_*.json`, `--trace`, `--log-json`). Bump when a field is added,
/// removed, or changes meaning, so downstream parsers can detect shape
/// changes instead of silently misreading (the p95 columns landed in PR 5
/// with no such marker).
///
/// History: 1 = implicit pre-obs shapes (through PR 6); 2 = `schema` field
/// added everywhere, `RoundRecord` gained `avg_time_s`/`corr_time_s`/
/// `eval_time_s`; 3 = `RunResult` gained `transport`, `RoundRecord` gained
/// `wire_bytes_up`/`wire_bytes_down`, `--trace` may emit multi-process
/// traces (`ph:"M"` process_name metadata when worker processes flushed
/// spans over the transport).
pub const SCHEMA_VERSION: u64 = 3;
