//! Observability: zero-overhead-when-off span tracing, a process-wide
//! metrics registry, a structured JSONL event log, and a live telemetry
//! plane (HTTP exposition + time series + training monitors).
//!
//! Six layers, all std-only (see `rust/src/obs/README.md` for the span
//! naming convention, the endpoint contract, and the overhead contract):
//!
//! - [`trace`] — per-thread span buffers behind one relaxed atomic flag.
//!   `obs::span("name")` costs a single branch while tracing is disabled;
//!   enabled spans record `(name, tid, start, dur)` into a thread-local
//!   buffer (no locks on the hot path) and export as Chrome trace-event
//!   JSON (`llcg run --trace trace.json`, loadable in `chrome://tracing`
//!   or <https://ui.perfetto.dev>).
//! - [`metrics`] — atomic counters/gauges plus fixed-bucket latency
//!   histograms whose percentiles reuse the `util::stats` interpolation
//!   rule. Always on: every instrument is a relaxed atomic op. Renders as
//!   Prometheus text exposition via [`metrics::prometheus_text`].
//! - [`events`] — a JSONL sink serializing the `api::Event` stream (one
//!   object per line, `llcg run --log-json runs/events.jsonl`) plus
//!   end-of-run span summaries.
//! - [`exporter`] — the `--listen <addr>` HTTP server: `/metrics`
//!   (Prometheus), `/health`, `/run` (event tail), `/series`.
//! - [`timeseries`] — a rolling registry sampler feeding `/series` and
//!   the `--out` dump.
//! - [`monitor`] — paper-grounded training monitors (cross-worker
//!   divergence, correction efficacy, straggler skew, liveness) behind
//!   their own relaxed-atomic switch, emitting `api::Event::MonitorAlert`.
//!
//! Instrumentation never touches RNG streams, float accumulation order, or
//! iteration order — only clocks and atomics — so every bit-exactness
//! contract in the repo (cluster sync ≡ sequential, serve ≡ eval path,
//! checkpoint resume replay) holds with tracing, metrics, the exporter,
//! and the monitors on. This is asserted end-to-end in `rust/tests/obs.rs`
//! and `rust/tests/telemetry.rs`.

pub mod events;
pub mod exporter;
pub mod metrics;
pub mod monitor;
pub mod timeseries;
pub mod trace;

pub use events::JsonlLog;
pub use exporter::{Exporter, RunHealth};
pub use metrics::{
    absorb_metrics_json, counter, gauge, histogram, metrics_json, metrics_raw_json, metrics_table,
    prometheus_text, reset_all, sample_flat, Counter, Gauge, Histogram,
};
pub use timeseries::{Sampler, SeriesRing};
pub use trace::{
    chrome_trace_json, chrome_trace_json_multi, enabled, set_enabled, span, span_round,
    spans_from_json, spans_to_json, summarize, take_spans, write_chrome_trace, Span, SpanRec,
    SpanSummary,
};

/// Version of every JSON shape this repo emits (`llcg run --json`,
/// `BENCH_*.json`, `--trace`, `--log-json`). Bump when a field is added,
/// removed, or changes meaning, so downstream parsers can detect shape
/// changes instead of silently misreading (the p95 columns landed in PR 5
/// with no such marker).
///
/// History: 1 = implicit pre-obs shapes (through PR 6); 2 = `schema` field
/// added everywhere, `RoundRecord` gained `avg_time_s`/`corr_time_s`/
/// `eval_time_s`; 3 = `RunResult` gained `transport`, `RoundRecord` gained
/// `wire_bytes_up`/`wire_bytes_down`, `--trace` may emit multi-process
/// traces (`ph:"M"` process_name metadata when worker processes flushed
/// spans over the transport); 4 = run-metadata `meta` header on traces,
/// metrics dumps, and the first JSONL line; `--out` may carry a `series`
/// time-series block; new `monitor_alert` event kind.
pub const SCHEMA_VERSION: u64 = 4;

use std::sync::Mutex;

use crate::util::Json;

/// Config fingerprint for the run-metadata header, set once at CLI
/// startup (`main.rs` computes it from the resolved config via
/// `api::keys::config_fingerprint`). Empty until set.
static CONFIG_DIGEST: Mutex<String> = Mutex::new(String::new());

/// Record the run's config fingerprint for [`run_meta_json`].
pub fn set_config_digest(digest: &str) {
    *CONFIG_DIGEST.lock().expect("config digest poisoned") = digest.to_string();
}

fn hostname() -> String {
    if let Ok(h) = std::env::var("HOSTNAME") {
        if !h.is_empty() {
            return h;
        }
    }
    if let Ok(h) = std::fs::read_to_string("/proc/sys/kernel/hostname") {
        let h = h.trim();
        if !h.is_empty() {
            return h.to_string();
        }
    }
    "unknown".to_string()
}

/// The run-metadata header stamped on every multi-process artifact
/// (Chrome traces, metrics dumps, the first JSONL line, `/health`): which
/// process, on which host, speaking which wire and schema versions, for
/// which config. This is what makes a pile of per-worker artifacts
/// attributable after the fact.
pub fn run_meta_json() -> Json {
    Json::obj(vec![
        ("pid", Json::num(std::process::id() as f64)),
        ("hostname", Json::str(hostname())),
        (
            "wire_version",
            Json::num(crate::transport::wire::WIRE_VERSION as f64),
        ),
        ("schema", Json::num(SCHEMA_VERSION as f64)),
        (
            "config_digest",
            Json::str(CONFIG_DIGEST.lock().expect("config digest poisoned").as_str()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_meta_carries_identity_fields() {
        set_config_digest("cafe1234");
        let m = run_meta_json();
        assert_eq!(
            m.get("pid").and_then(Json::as_f64),
            Some(std::process::id() as f64)
        );
        assert!(!m.get("hostname").and_then(Json::as_str).unwrap().is_empty());
        assert_eq!(
            m.get("wire_version").and_then(Json::as_f64),
            Some(crate::transport::wire::WIRE_VERSION as f64)
        );
        assert_eq!(
            m.get("schema").and_then(Json::as_f64),
            Some(SCHEMA_VERSION as f64)
        );
        assert_eq!(
            m.get("config_digest").and_then(Json::as_str),
            Some("cafe1234")
        );
        set_config_digest("");
    }
}
