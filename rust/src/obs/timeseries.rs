//! Rolling time-series sampler over the metrics registry.
//!
//! [`Sampler::start`] spawns one background thread that snapshots every
//! registered instrument on a fixed interval into a bounded ring buffer
//! ([`SeriesRing`]). The ring is shared (cheaply clonable) so the HTTP
//! exporter serves it live at `/series` while `--out` embeds the same
//! JSON at the end of the run — post-hoc plots of loss / wire bytes /
//! queue depth over wall time without any extra recording code.
//!
//! The sampler only *reads* relaxed atomics; it never touches training
//! state, RNG streams, or iteration order, so the bit-exactness
//! contracts hold with it running. It exists only while `--listen` is up
//! (zero threads, zero cost otherwise).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::Json;

/// Default sampling interval.
pub const DEFAULT_INTERVAL_MS: u64 = 250;
/// Default ring capacity (oldest samples fall off first). At the default
/// interval this holds ~8.5 minutes of history.
pub const DEFAULT_CAPACITY: usize = 2048;

/// One registry snapshot at a point in wall time.
#[derive(Clone, Debug)]
pub struct Sample {
    /// seconds since the sampler started
    pub t_s: f64,
    /// flat `name -> value` view of the registry (histograms contribute
    /// `<name>.count/.mean_s/.p95_s/.max_s` derived series)
    pub values: Vec<(String, f64)>,
}

struct RingInner {
    samples: Mutex<VecDeque<Sample>>,
    capacity: usize,
    interval_ms: u64,
    t0: Instant,
    /// samples dropped off the front of the ring (so truncation is
    /// visible, not silent)
    dropped: Mutex<u64>,
}

/// Shared handle on the bounded sample ring.
#[derive(Clone)]
pub struct SeriesRing(Arc<RingInner>);

impl SeriesRing {
    fn new(capacity: usize, interval_ms: u64) -> SeriesRing {
        SeriesRing(Arc::new(RingInner {
            samples: Mutex::new(VecDeque::with_capacity(capacity.min(256))),
            capacity,
            interval_ms,
            t0: Instant::now(),
            dropped: Mutex::new(0),
        }))
    }

    /// Take one snapshot of the registry now (the sampler thread calls
    /// this on its cadence; tests call it directly).
    pub fn sample_now(&self) {
        let sample = Sample {
            t_s: self.0.t0.elapsed().as_secs_f64(),
            values: super::metrics::sample_flat(),
        };
        let mut q = self.0.samples.lock().expect("series ring poisoned");
        if q.len() == self.0.capacity {
            q.pop_front();
            *self.0.dropped.lock().expect("series ring poisoned") += 1;
        }
        q.push_back(sample);
    }

    pub fn len(&self) -> usize {
        self.0.samples.lock().expect("series ring poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `/series` document: schema stamp, cadence, drop count, and the
    /// retained samples oldest-first.
    pub fn to_json(&self) -> Json {
        let q = self.0.samples.lock().expect("series ring poisoned");
        let samples: Vec<Json> = q
            .iter()
            .map(|s| {
                let values: Vec<(&str, Json)> = s
                    .values
                    .iter()
                    .map(|(k, v)| (k.as_str(), Json::num(*v)))
                    .collect();
                Json::obj(vec![
                    ("t_s", Json::num(s.t_s)),
                    ("values", Json::obj(values)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::num(super::SCHEMA_VERSION as f64)),
            ("interval_ms", Json::num(self.0.interval_ms as f64)),
            (
                "dropped",
                Json::num(*self.0.dropped.lock().expect("series ring poisoned") as f64),
            ),
            ("samples", Json::arr(samples)),
        ])
    }
}

/// The background sampler. Dropping (or [`Sampler::stop`]) ends the
/// thread; the [`SeriesRing`] stays readable afterwards.
pub struct Sampler {
    ring: SeriesRing,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Sampler {
    /// Start sampling every `interval_ms` into a ring of `capacity`.
    pub fn start(interval_ms: u64, capacity: usize) -> Sampler {
        let ring = SeriesRing::new(capacity.max(1), interval_ms.max(1));
        let stop = Arc::new(AtomicBool::new(false));
        let thread_ring = ring.clone();
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("obs-series".into())
            .spawn(move || {
                // sample in <=50ms slices so stop() never waits a full
                // interval
                let interval = Duration::from_millis(interval_ms.max(1));
                let slice = Duration::from_millis(50).min(interval);
                let mut next = Instant::now() + interval;
                while !thread_stop.load(Ordering::Relaxed) {
                    std::thread::sleep(slice);
                    if Instant::now() >= next {
                        thread_ring.sample_now();
                        next += interval;
                    }
                }
            })
            .expect("spawn obs-series thread");
        Sampler {
            ring,
            stop,
            handle: Some(handle),
        }
    }

    /// Shared handle for the exporter's `/series` route.
    pub fn ring(&self) -> SeriesRing {
        self.ring.clone()
    }

    /// Stop the thread and return the ring (one final sample is taken so
    /// short runs always have at least one point).
    pub fn stop(mut self) -> SeriesRing {
        self.halt();
        self.ring.sample_now();
        self.ring.clone()
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_reports_drops() {
        let ring = SeriesRing::new(3, 10);
        for _ in 0..5 {
            ring.sample_now();
        }
        assert_eq!(ring.len(), 3);
        let j = ring.to_json();
        assert_eq!(j.get("dropped").and_then(Json::as_f64), Some(2.0));
        let samples = j.get("samples").and_then(Json::as_array).unwrap();
        assert_eq!(samples.len(), 3);
        // timestamps are monotone non-decreasing
        let ts: Vec<f64> = samples
            .iter()
            .map(|s| s.get("t_s").and_then(Json::as_f64).unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
        assert_eq!(
            j.get("interval_ms").and_then(Json::as_f64),
            Some(10.0)
        );
    }

    #[test]
    fn samples_carry_registry_values() {
        let c = super::super::counter("test.obs-series-counter");
        c.reset();
        c.add(41);
        let ring = SeriesRing::new(8, 10);
        ring.sample_now();
        c.inc();
        ring.sample_now();
        let j = ring.to_json();
        let samples = j.get("samples").and_then(Json::as_array).unwrap();
        let get = |i: usize| -> f64 {
            samples[i]
                .get("values")
                .and_then(|v| v.get("test.obs-series-counter"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(get(0), 41.0);
        assert_eq!(get(1), 42.0);
        c.reset();
    }

    #[test]
    fn sampler_thread_samples_and_stops() {
        let sampler = Sampler::start(5, 64);
        std::thread::sleep(Duration::from_millis(40));
        let ring = sampler.stop();
        assert!(!ring.is_empty(), "no samples after 40ms at 5ms cadence");
        let n = ring.len();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(ring.len(), n, "sampler kept running after stop");
    }
}
