//! Paper-grounded training monitors: cross-worker parameter divergence,
//! correction efficacy, straggler skew, and heartbeat liveness.
//!
//! LLCG's theory (PAPER.md, Thm. 4.3–4.4) bounds the residual error of
//! periodic averaging by how far the workers' parameters drift from their
//! mean between synchronizations; the Global Server Correction exists to
//! cancel exactly that residual. These monitors make the quantities in
//! that story observable *while the run is alive*: every value lands in
//! the process metrics registry (scrapeable at `/metrics` when `--listen`
//! is up), and threshold rules return typed [`Alert`]s that the engines
//! emit as `api::Event::MonitorAlert`.
//!
//! Monitoring is off by default and gated on one relaxed atomic load
//! ([`enabled`]), mirroring the tracing switch: with it off the training
//! path pays a single branch per hook site and the bit-exactness
//! contracts of `tests/obs.rs` hold untouched. With it on, the divergence
//! math reads parameter snapshots the server already holds — no extra
//! worker communication — and the correction-efficacy evals run on
//! *clones* of the eval RNG, so the training-visible RNG streams never
//! advance differently.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Rounds of strictly growing max-divergence before an alert fires.
pub const DIVERGENCE_GROWTH_ROUNDS: usize = 3;
/// A worker is "silent" after this many missed heartbeat periods.
pub const SILENT_HEARTBEAT_PERIODS: f64 = 3.0;
/// Straggler alert threshold: round-time z-score above the fleet mean.
pub const STRAGGLER_Z: f64 = 3.0;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn the monitors on/off process-wide (the CLI does this when
/// `--listen` is given; tests drive it directly).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// One relaxed load — the entire cost of the monitors when disabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A threshold rule that fired. The engines wrap these in
/// `api::Event::MonitorAlert`; the exporter's `/run` tail and the JSONL
/// log both carry them.
#[derive(Clone, Debug)]
pub struct Alert {
    pub round: usize,
    /// which monitor fired ("divergence" | "correction" | "straggler" |
    /// "liveness")
    pub monitor: &'static str,
    pub message: String,
    /// the value that crossed the rule's threshold
    pub value: f64,
}

/// Per-round cross-worker divergence sample (the Thm 4.3–4.4 residual
/// quantity): L2 distance of each contributing worker's parameters from
/// their average, reported as the max and mean over workers.
#[derive(Clone, Copy, Debug)]
pub struct DivSample {
    pub round: usize,
    pub max: f64,
    pub mean: f64,
}

/// Per-round correction-efficacy sample: global train loss immediately
/// before and after `server.correction`, plus the L2 norm of the
/// parameter delta the correction applied.
#[derive(Clone, Copy, Debug)]
pub struct CorrSample {
    pub round: usize,
    pub loss_before: f64,
    pub loss_after: f64,
    pub delta_norm: f64,
}

#[derive(Default)]
struct MonState {
    divergence: Vec<DivSample>,
    growth_streak: usize,
    corrections: Vec<CorrSample>,
    /// part -> last heartbeat arrival (remote transports feed this)
    heartbeats: BTreeMap<u32, Instant>,
}

fn state() -> &'static Mutex<MonState> {
    static STATE: Mutex<MonState> = Mutex::new(MonState {
        divergence: Vec::new(),
        growth_streak: 0,
        corrections: Vec::new(),
        heartbeats: BTreeMap::new(),
    });
    &STATE
}

/// Clear all monitor history (start of a run / test isolation). Leaves
/// the enabled switch alone.
pub fn reset() {
    let mut s = state().lock().expect("monitor state poisoned");
    s.divergence.clear();
    s.growth_streak = 0;
    s.corrections.clear();
    s.heartbeats.clear();
}

/// The run's divergence samples so far, in round order.
pub fn divergence_history() -> Vec<DivSample> {
    state().lock().expect("monitor state poisoned").divergence.clone()
}

/// The run's correction-efficacy samples so far, in round order.
pub fn correction_history() -> Vec<CorrSample> {
    state().lock().expect("monitor state poisoned").corrections.clone()
}

/// Plain L2 distance of each worker's flattened parameters from their
/// elementwise average: `(max, mean)` over workers. Accumulates in f64 on
/// copies of the data — the training tensors are only read.
pub fn divergence_of(workers: &[Vec<&[f32]>]) -> (f64, f64) {
    if workers.is_empty() {
        return (0.0, 0.0);
    }
    let n = workers.len() as f64;
    let mut dists = vec![0f64; workers.len()];
    let n_tensors = workers[0].len();
    for t in 0..n_tensors {
        let len = workers[0][t].len();
        for i in 0..len {
            let mut avg = 0f64;
            for w in workers {
                avg += w[t][i] as f64;
            }
            avg /= n;
            for (wi, w) in workers.iter().enumerate() {
                let d = w[t][i] as f64 - avg;
                dists[wi] += d * d;
            }
        }
    }
    let mut max = 0f64;
    let mut sum = 0f64;
    for d in &mut dists {
        *d = d.sqrt();
        max = max.max(*d);
        sum += *d;
    }
    (max, sum / n)
}

/// Record a round's cross-worker divergence (computed from the parameter
/// uploads the server is about to average — snapshots it already holds).
/// Fires an alert when the max has grown [`DIVERGENCE_GROWTH_ROUNDS`]
/// rounds in a row: under LLCG the correction should keep this quantity
/// bounded, so sustained growth means the residual error is compounding.
pub fn observe_divergence(round: usize, workers: &[Vec<&[f32]>]) -> Vec<Alert> {
    let (max, mean) = divergence_of(workers);
    super::gauge("monitor.divergence_max").set(max);
    super::gauge("monitor.divergence_mean").set(mean);
    let mut s = state().lock().expect("monitor state poisoned");
    let grew = s.divergence.last().map(|p| max > p.max).unwrap_or(false);
    s.growth_streak = if grew { s.growth_streak + 1 } else { 0 };
    s.divergence.push(DivSample { round, max, mean });
    let mut alerts = Vec::new();
    if s.growth_streak >= DIVERGENCE_GROWTH_ROUNDS {
        alerts.push(Alert {
            round,
            monitor: "divergence",
            message: format!(
                "cross-worker divergence grew {} rounds straight (max {max:.3e})",
                s.growth_streak
            ),
            value: max,
        });
    }
    alerts
}

/// Record a round's correction efficacy. Alerts when the post-correction
/// global loss is non-finite — training is diverging and every later
/// round is wasted.
pub fn observe_correction(
    round: usize,
    loss_before: f64,
    loss_after: f64,
    delta_norm: f64,
) -> Vec<Alert> {
    super::gauge("monitor.correction_loss_before").set(loss_before);
    super::gauge("monitor.correction_loss_after").set(loss_after);
    super::gauge("monitor.correction_delta_norm").set(delta_norm);
    state()
        .lock()
        .expect("monitor state poisoned")
        .corrections
        .push(CorrSample {
            round,
            loss_before,
            loss_after,
            delta_norm,
        });
    let mut alerts = Vec::new();
    if !loss_after.is_finite() {
        alerts.push(Alert {
            round,
            monitor: "correction",
            message: format!("global loss non-finite after correction ({loss_after})"),
            value: loss_after,
        });
    }
    alerts
}

/// Record per-worker round times and flag stragglers: any worker whose
/// round time sits more than [`STRAGGLER_Z`] standard deviations above
/// the fleet mean (needs >= 3 contributors for the z-score to mean
/// anything). The max z lands in the `monitor.straggler_z` gauge.
pub fn observe_round_times(round: usize, times: &[(u32, f64)]) -> Vec<Alert> {
    let mut alerts = Vec::new();
    if times.len() < 3 {
        super::gauge("monitor.straggler_z").set(0.0);
        return alerts;
    }
    let n = times.len() as f64;
    let mean = times.iter().map(|(_, t)| t).sum::<f64>() / n;
    let var = times.iter().map(|(_, t)| (t - mean) * (t - mean)).sum::<f64>() / n;
    let sd = var.sqrt();
    let mut z_max = 0f64;
    for &(part, t) in times {
        let z = if sd > 0.0 { (t - mean) / sd } else { 0.0 };
        z_max = z_max.max(z);
        if z > STRAGGLER_Z {
            alerts.push(Alert {
                round,
                monitor: "straggler",
                message: format!(
                    "worker {part} round time {t:.3}s is {z:.1} sd above the fleet mean {mean:.3}s"
                ),
                value: z,
            });
        }
    }
    super::gauge("monitor.straggler_z").set(z_max);
    alerts
}

/// Transport layer: a worker heartbeat arrived (remote transports call
/// this from the per-worker reader thread; gated on [`enabled`] there).
pub fn note_heartbeat(part: u32) {
    state()
        .lock()
        .expect("monitor state poisoned")
        .heartbeats
        .insert(part, Instant::now());
}

/// Flag workers whose last heartbeat is older than
/// [`SILENT_HEARTBEAT_PERIODS`] x `period_s`. Workers that never
/// heartbeated (in-process transport) are skipped, so the hook is safe on
/// every engine/transport combination.
pub fn check_heartbeats(round: usize, period_s: f64) -> Vec<Alert> {
    let s = state().lock().expect("monitor state poisoned");
    let mut alerts = Vec::new();
    let mut live = 0usize;
    for (&part, &last) in &s.heartbeats {
        let age = last.elapsed().as_secs_f64();
        if age > SILENT_HEARTBEAT_PERIODS * period_s {
            alerts.push(Alert {
                round,
                monitor: "liveness",
                message: format!(
                    "worker {part} silent for {age:.1}s (> {SILENT_HEARTBEAT_PERIODS} x {period_s:.1}s heartbeat)"
                ),
                value: age,
            });
        } else {
            live += 1;
        }
    }
    if !s.heartbeats.is_empty() {
        super::gauge("transport.live_workers").set(live as f64);
    }
    alerts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divergence_of_identical_workers_is_zero() {
        let a = vec![vec![1.0f32, 2.0, 3.0]];
        let w: Vec<Vec<&[f32]>> = (0..3).map(|_| vec![a[0].as_slice()]).collect();
        let (max, mean) = divergence_of(&w);
        assert_eq!(max, 0.0);
        assert_eq!(mean, 0.0);
    }

    #[test]
    fn divergence_of_matches_hand_computation() {
        // two workers, one tensor of one element: values 0 and 2, avg 1,
        // each at distance 1
        let a = [0.0f32];
        let b = [2.0f32];
        let w: Vec<Vec<&[f32]>> = vec![vec![&a], vec![&b]];
        let (max, mean) = divergence_of(&w);
        assert!((max - 1.0).abs() < 1e-12, "max {max}");
        assert!((mean - 1.0).abs() < 1e-12, "mean {mean}");
        // empty fleet: zeros, no panic
        assert_eq!(divergence_of(&[]), (0.0, 0.0));
    }

    #[test]
    fn growth_streak_fires_after_k_rounds() {
        reset();
        let mk = |x: f32| -> Vec<f32> { vec![x] };
        let fire_round = |r: usize, spread: f32| -> Vec<Alert> {
            let a = mk(-spread);
            let b = mk(spread);
            let w: Vec<Vec<&[f32]>> = vec![vec![a.as_slice()], vec![b.as_slice()]];
            observe_divergence(r, &w)
        };
        assert!(fire_round(1, 1.0).is_empty());
        assert!(fire_round(2, 2.0).is_empty(), "streak 1");
        assert!(fire_round(3, 3.0).is_empty(), "streak 2");
        let alerts = fire_round(4, 4.0);
        assert_eq!(alerts.len(), 1, "streak 3 fires");
        assert_eq!(alerts[0].monitor, "divergence");
        assert_eq!(alerts[0].round, 4);
        // a non-growing round resets the streak
        assert!(fire_round(5, 1.0).is_empty());
        assert!(fire_round(6, 2.0).is_empty());
        assert_eq!(divergence_history().len(), 6);
        reset();
    }

    #[test]
    fn non_finite_correction_loss_alerts() {
        reset();
        assert!(observe_correction(1, 0.9, 0.7, 0.1).is_empty());
        let alerts = observe_correction(2, 0.7, f64::NAN, 0.1);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].monitor, "correction");
        assert_eq!(correction_history().len(), 2);
        reset();
    }

    #[test]
    fn straggler_z_score_flags_the_slow_worker() {
        // 7 fast workers + one 10x straggler
        let mut times: Vec<(u32, f64)> = (0..7).map(|p| (p, 1.0 + 1e-3 * p as f64)).collect();
        times.push((7, 10.0));
        let alerts = observe_round_times(3, &times);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].monitor, "straggler");
        assert!(alerts[0].message.contains("worker 7"));
        // a uniform fleet never alerts (sd == 0 path)
        let even: Vec<(u32, f64)> = (0..4).map(|p| (p, 1.0)).collect();
        assert!(observe_round_times(4, &even).is_empty());
        // too few contributors: no z-score, no alert
        assert!(observe_round_times(5, &times[..2]).is_empty());
    }

    #[test]
    fn heartbeat_silence_only_covers_workers_that_ever_beat() {
        reset();
        // nobody heartbeated (in-process transport): no alerts at all
        assert!(check_heartbeats(1, 0.001).is_empty());
        note_heartbeat(2);
        // fresh heartbeat, generous period: alive
        assert!(check_heartbeats(1, 10.0).is_empty());
        // tiny period: the same heartbeat is now ancient
        std::thread::sleep(std::time::Duration::from_millis(5));
        let alerts = check_heartbeats(2, 1e-4);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].monitor, "liveness");
        assert!(alerts[0].message.contains("worker 2"));
        reset();
    }
}
