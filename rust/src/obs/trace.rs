//! Span tracing with per-thread lock-free buffers and a Chrome trace-event
//! exporter.
//!
//! The enable gate is one relaxed [`AtomicBool`]: a disabled
//! [`span`] call is a load + branch and touches no clock, no allocation,
//! and no shared state — cheap enough to leave in kernel inner loops
//! (measured per PR by `bench obs`, `BENCH_obs.json`).
//!
//! Enabled spans are recorded at guard drop into a `thread_local` buffer
//! (plain `RefCell` push: no atomics or locks on the record path). Buffers
//! publish into the global sink when their thread exits, when they exceed
//! [`FLUSH_AT`] spans, or when [`take_spans`] drains the calling thread
//! explicitly. Long-lived threads that never exit (the kernel pool) only
//! contribute spans they have overflowed-flushed — in practice all
//! round-loop spans are recorded on threads that exit (or drain) before
//! export.
//!
//! Spans are strictly LIFO per thread (guard scopes), so per-thread spans
//! always nest and never partially overlap — `tests/obs.rs` validates this
//! on the exported JSON.

use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::Json;

/// One completed span: `name` is a `&'static str` so recording never
/// allocates; `round` tags round-scoped phases (`-1` = not round-scoped) so
/// eval on an `eval_every` cadence is attributed to the round that
/// triggered it.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    pub name: &'static str,
    /// small per-thread id assigned on the thread's first recorded span
    pub tid: u32,
    /// nanoseconds since the trace epoch
    pub start_ns: u64,
    pub dur_ns: u64,
    pub round: i64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

/// Thread-local buffers overflow-publish to the global sink at this size.
const FLUSH_AT: usize = 8192;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn sink() -> &'static Mutex<Vec<SpanRec>> {
    static SINK: OnceLock<Mutex<Vec<SpanRec>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

/// Turn tracing on or off (process-wide). Enabling pins the trace epoch
/// first so no span can observe a start before it.
pub fn set_enabled(on: bool) {
    if on {
        epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// The single-branch gate every instrumented path checks.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

struct ThreadBuf {
    tid: u32,
    spans: Vec<SpanRec>,
}

impl ThreadBuf {
    fn publish(&mut self) {
        if !self.spans.is_empty() {
            sink()
                .lock()
                .expect("span sink poisoned")
                .append(&mut self.spans);
        }
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.publish();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        spans: Vec::new(),
    });
}

/// Guard for an in-flight span; records on drop. Obtained from [`span`] /
/// [`span_round`]; hold it in a `let _s = ...` for the scope being timed
/// (`let _ = ...` drops immediately and records nothing).
pub struct Span {
    /// `None` = tracing was disabled at entry: drop is a no-op even if
    /// tracing is flipped on mid-span (half-measured spans are worse than
    /// missing ones)
    start: Option<Instant>,
    name: &'static str,
    round: i64,
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let dur_ns = start.elapsed().as_nanos() as u64;
        let start_ns = start
            .checked_duration_since(epoch())
            .unwrap_or_default()
            .as_nanos() as u64;
        let (name, round) = (self.name, self.round);
        // try_with: a span dropped during TLS teardown is silently lost
        let _ = BUF.try_with(|b| {
            let mut b = b.borrow_mut();
            let tid = b.tid;
            b.spans.push(SpanRec {
                name,
                tid,
                start_ns,
                dur_ns,
                round,
            });
            if b.spans.len() >= FLUSH_AT {
                b.publish();
            }
        });
    }
}

/// Open a span named `name`. When tracing is disabled this is one relaxed
/// load and a branch.
#[inline]
pub fn span(name: &'static str) -> Span {
    span_round(name, -1)
}

/// Open a round-tagged span: the round lands in the Chrome trace's `args`
/// so phase durations can be grouped by the round that *triggered* them
/// (eval under `eval_every > 1` belongs to the cadence round, not to
/// whatever comes after).
#[inline]
pub fn span_round(name: &'static str, round: i64) -> Span {
    let start = if enabled() {
        Some(Instant::now())
    } else {
        None
    };
    Span { start, name, round }
}

/// Drain every published span plus the calling thread's buffer, sorted by
/// `(tid, start)`. Used by the exporter and tests; leaves the sink empty.
pub fn take_spans() -> Vec<SpanRec> {
    let _ = BUF.try_with(|b| b.borrow_mut().publish());
    let mut out = std::mem::take(&mut *sink().lock().expect("span sink poisoned"));
    // equal starts: longer span first, so parents precede their children
    out.sort_by(|a, b| {
        (a.tid, a.start_ns, std::cmp::Reverse(a.dur_ns))
            .cmp(&(b.tid, b.start_ns, std::cmp::Reverse(b.dur_ns)))
    });
    out
}

/// Chrome trace-event JSON (the "JSON object format": `traceEvents` +
/// metadata) over complete (`ph:"X"`) events; `ts`/`dur` in microseconds.
pub fn chrome_trace_json(spans: &[SpanRec]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let mut fields = vec![
                ("name", Json::str(s.name)),
                ("cat", Json::str("llcg")),
                ("ph", Json::str("X")),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(s.tid as f64)),
                ("ts", Json::num(s.start_ns as f64 / 1e3)),
                ("dur", Json::num(s.dur_ns as f64 / 1e3)),
            ];
            if s.round >= 0 {
                fields.push((
                    "args",
                    Json::obj(vec![("round", Json::num(s.round as f64))]),
                ));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::num(crate::obs::SCHEMA_VERSION as f64)),
        ("meta", crate::obs::run_meta_json()),
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(events)),
    ])
}

/// Drain all spans and write them as a Chrome/Perfetto-loadable trace file.
/// Returns the number of spans written.
pub fn write_chrome_trace(path: &Path) -> Result<usize> {
    let spans = take_spans();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, chrome_trace_json(&spans).to_string_pretty())
        .with_context(|| format!("writing trace {}", path.display()))?;
    Ok(spans.len())
}

/// Intern an arbitrary string as `&'static str` (leaked once per distinct
/// name). [`SpanRec::name`] and the metrics registry key on `&'static str`
/// so the record paths never allocate; names arriving from *another
/// process* (a worker's obs flush) go through here. The span/metric name
/// universe is small and fixed, so the leak is bounded.
pub(crate) fn intern(s: &str) -> &'static str {
    static INTERNED: Mutex<std::collections::BTreeMap<String, &'static str>> =
        Mutex::new(std::collections::BTreeMap::new());
    let mut map = INTERNED.lock().expect("intern table poisoned");
    if let Some(&v) = map.get(s) {
        return v;
    }
    let v: &'static str = Box::leak(s.to_string().into_boxed_str());
    map.insert(s.to_string(), v);
    v
}

/// Serialize spans for a worker process's end-of-run obs flush (the exact
/// inverse of [`spans_from_json`]).
pub fn spans_to_json(spans: &[SpanRec]) -> Json {
    Json::arr(
        spans
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(s.name)),
                    ("tid", Json::num(s.tid as f64)),
                    ("start_ns", Json::num(s.start_ns as f64)),
                    ("dur_ns", Json::num(s.dur_ns as f64)),
                    ("round", Json::num(s.round as f64)),
                ])
            })
            .collect(),
    )
}

/// Decode spans a worker process shipped over the transport. Names are
/// interned (span records hold `&'static str`); timestamps stay on the
/// worker's own epoch — tracks are per-process, so cross-process skew only
/// shifts a track, never reorders one.
pub fn spans_from_json(j: &Json) -> Result<Vec<SpanRec>, String> {
    let arr = j.as_array().ok_or("spans must be a json array")?;
    arr.iter()
        .map(|s| {
            let num = |k: &str| -> Result<f64, String> {
                s.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("span missing numeric {k:?}"))
            };
            let name = s
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("span missing name")?;
            Ok(SpanRec {
                name: intern(name),
                tid: num("tid")? as u32,
                start_ns: num("start_ns")? as u64,
                dur_ns: num("dur_ns")? as u64,
                round: num("round")? as i64,
            })
        })
        .collect()
}

/// Chrome trace-event JSON over multiple *processes*: one `pid` per named
/// track (`("server", ...)` first by convention, then each `worker-<rank>`),
/// with `process_name` metadata events so Perfetto labels the tracks.
/// Single-process traces keep using [`chrome_trace_json`] — its event list
/// is pure `ph:"X"`, which downstream tooling asserts.
pub fn chrome_trace_json_multi(tracks: &[(String, Vec<SpanRec>)]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (pid, (name, spans)) in tracks.iter().enumerate() {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
            (
                "args",
                Json::obj(vec![("name", Json::str(name.as_str()))]),
            ),
        ]));
        for s in spans {
            let mut fields = vec![
                ("name", Json::str(s.name)),
                ("cat", Json::str("llcg")),
                ("ph", Json::str("X")),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(s.tid as f64)),
                ("ts", Json::num(s.start_ns as f64 / 1e3)),
                ("dur", Json::num(s.dur_ns as f64 / 1e3)),
            ];
            if s.round >= 0 {
                fields.push((
                    "args",
                    Json::obj(vec![("round", Json::num(s.round as f64))]),
                ));
            }
            events.push(Json::obj(fields));
        }
    }
    Json::obj(vec![
        ("schema", Json::num(crate::obs::SCHEMA_VERSION as f64)),
        ("meta", crate::obs::run_meta_json()),
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(events)),
    ])
}

/// Per-name rollup of a span set (for `--log-json` summaries and the
/// `--metrics` table).
#[derive(Clone, Copy, Debug)]
pub struct SpanSummary {
    pub name: &'static str,
    pub count: u64,
    pub total_s: f64,
    pub max_s: f64,
}

/// Aggregate spans by name (sorted by name).
pub fn summarize(spans: &[SpanRec]) -> Vec<SpanSummary> {
    let mut by_name: std::collections::BTreeMap<&'static str, SpanSummary> =
        std::collections::BTreeMap::new();
    for s in spans {
        let e = by_name.entry(s.name).or_insert(SpanSummary {
            name: s.name,
            count: 0,
            total_s: 0.0,
            max_s: 0.0,
        });
        let dur_s = s.dur_ns as f64 / 1e9;
        e.count += 1;
        e.total_s += dur_s;
        e.max_s = e.max_s.max(dur_s);
    }
    by_name.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // the enable flag and sink are process-wide; tests touching them must
    // not interleave (the test harness runs #[test]s on parallel threads)
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = test_lock();
        set_enabled(false);
        for _ in 0..100 {
            let _s = span("test.disabled-xyzzy");
        }
        assert!(!take_spans().iter().any(|s| s.name == "test.disabled-xyzzy"));
    }

    #[test]
    fn enabled_spans_nest_and_export() {
        let _g = test_lock();
        set_enabled(true);
        {
            let _outer = span_round("test.outer-xyzzy", 3);
            let _inner = span("test.inner-xyzzy");
        }
        set_enabled(false);
        let spans = take_spans();
        let outer = spans
            .iter()
            .find(|s| s.name == "test.outer-xyzzy")
            .expect("outer recorded");
        let inner = spans
            .iter()
            .find(|s| s.name == "test.inner-xyzzy")
            .expect("inner recorded");
        assert_eq!(outer.round, 3);
        assert_eq!(inner.round, -1);
        assert_eq!(outer.tid, inner.tid);
        // inner is contained in outer
        assert!(inner.start_ns >= outer.start_ns);
        assert!(inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns);
        // and the export parses back
        let j = chrome_trace_json(&spans);
        let txt = j.to_string_pretty();
        let re = Json::parse(&txt).expect("chrome trace parses");
        assert!(re.req("traceEvents").as_array().unwrap().len() >= 2);
        assert_eq!(
            re.req("schema").as_f64().unwrap() as u64,
            crate::obs::SCHEMA_VERSION
        );
    }

    #[test]
    fn spans_round_trip_through_json_and_merge_multi_process() {
        let spans = [
            SpanRec {
                name: "round",
                tid: 0,
                start_ns: 10,
                dur_ns: 40,
                round: 2,
            },
            SpanRec {
                name: "worker_round",
                tid: 3,
                start_ns: 12,
                dur_ns: 20,
                round: -1,
            },
        ];
        let j = spans_to_json(&spans);
        let back = spans_from_json(&Json::parse(&j.to_string_pretty()).unwrap()).expect("decode");
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "round");
        assert_eq!(back[0].round, 2);
        assert_eq!(back[1].tid, 3);
        assert_eq!(back[1].start_ns, 12);
        assert_eq!(back[1].dur_ns, 20);
        assert!(spans_from_json(&Json::num(1.0)).is_err());
        // interning maps equal strings to one static
        assert!(std::ptr::eq(intern("zz-interned"), intern("zz-interned")));

        let multi = chrome_trace_json_multi(&[
            ("server".to_string(), spans.to_vec()),
            ("worker-0".to_string(), back),
        ]);
        let re = Json::parse(&multi.to_string_pretty()).unwrap();
        let events = re.req("traceEvents").as_array().unwrap();
        // one process_name metadata event per track + the spans
        let metas: Vec<_> = events
            .iter()
            .filter(|e| e.req("ph").as_str() == Some("M"))
            .collect();
        assert_eq!(metas.len(), 2);
        assert_eq!(
            metas[1].req("args").req("name").as_str(),
            Some("worker-0")
        );
        let pids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter(|e| e.req("ph").as_str() == Some("X"))
            .map(|e| e.req("pid").as_f64().unwrap() as u64)
            .collect();
        assert_eq!(pids.into_iter().collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn summaries_roll_up_by_name() {
        let spans = [
            SpanRec {
                name: "a",
                tid: 0,
                start_ns: 0,
                dur_ns: 1_000_000_000,
                round: -1,
            },
            SpanRec {
                name: "a",
                tid: 1,
                start_ns: 5,
                dur_ns: 3_000_000_000,
                round: 1,
            },
            SpanRec {
                name: "b",
                tid: 0,
                start_ns: 9,
                dur_ns: 500_000_000,
                round: -1,
            },
        ];
        let sums = summarize(&spans);
        assert_eq!(sums.len(), 2);
        assert_eq!(sums[0].name, "a");
        assert_eq!(sums[0].count, 2);
        assert!((sums[0].total_s - 4.0).abs() < 1e-9);
        assert!((sums[0].max_s - 3.0).abs() < 1e-9);
        assert_eq!(sums[1].name, "b");
    }
}
