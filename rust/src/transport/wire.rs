//! The versioned, length-prefixed wire protocol spoken between the cluster
//! server and `llcg worker` processes (contract: `rust/src/cluster/README.md`).
//!
//! Every message is one frame: `[len: u32 LE][tag: u8][payload: len-1 B]`.
//! Tensor payloads reuse the checkpoint codec (`cluster/checkpoint.rs`):
//! raw `f32` little-endian in shape-manifest order, so parameters cross the
//! socket bit-exactly — the foundation of the sync-over-TCP ≡ sequential
//! parity contract.
//!
//! A connection opens with a handshake — `ClientHello` (magic +
//! [`WIRE_VERSION`] + rank + config digest) answered by `Welcome` or a
//! coded `Reject` — and then carries framed round traffic. Version or
//! digest mismatches surface as a typed [`HandshakeError`] on both ends;
//! nothing past the handshake is parsed on a rejected connection.

use std::fmt;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::cluster::checkpoint::{push_tensors, take_tensors, Digest};
use crate::runtime::{ModelState, Tensor};

use super::ParamsUp;

/// First bytes of every `ClientHello`; anything else is not this protocol.
pub const MAGIC: [u8; 4] = *b"LLCG";

/// Wire-format version. Bump on any frame-layout or tag change; the
/// handshake rejects a mismatch with a typed error (compatibility rule:
/// exact match only — no cross-version negotiation).
pub const WIRE_VERSION: u32 = 1;

/// Upper bound on one frame; larger prefixes mean a corrupt/foreign stream.
const MAX_FRAME: u32 = 1 << 30;

// frame tags ----------------------------------------------------------------
pub const TAG_HELLO: u8 = 1;
pub const TAG_WELCOME: u8 = 2;
pub const TAG_REJECT: u8 = 3;
pub const TAG_ROUND: u8 = 4;
pub const TAG_SNAPSHOT: u8 = 5;
pub const TAG_SHUTDOWN: u8 = 6;
pub const TAG_FEATURES: u8 = 7;
pub const TAG_ROUND_REPLY: u8 = 8;
pub const TAG_SNAPSHOT_REPLY: u8 = 9;
pub const TAG_FAILED: u8 = 10;
pub const TAG_HEARTBEAT: u8 = 11;
pub const TAG_OBS_FLUSH: u8 = 12;
pub const TAG_RESTORE: u8 = 13;

/// `Welcome` flag bit: span tracing is on server-side; the worker enables
/// its own tracing and ships spans back in `ObsFlush`.
pub const WELCOME_TRACE: u8 = 1;

// reject codes --------------------------------------------------------------
pub const REJ_VERSION: u8 = 1;
pub const REJ_DIGEST: u8 = 2;
pub const REJ_RANK: u8 = 3;
pub const REJ_MAGIC: u8 = 4;

// ---------------------------------------------------------------------------
// streams and listeners (TCP + unix-domain sockets behind one enum)
// ---------------------------------------------------------------------------

/// One connected byte stream, TCP or UDS.
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    pub fn try_clone(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    pub fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(t),
        }
    }

    /// Close both directions (used to unblock the peer on an abort path).
    pub fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound accept socket, TCP or UDS.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    pub fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    pub fn accept(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Listener::Tcp(l) => Stream::Tcp(l.accept()?.0),
            #[cfg(unix)]
            Listener::Unix(l) => Stream::Unix(l.accept()?.0),
        })
    }
}

/// Dial `addr` (`host:port`, or `unix:<path>` for a UDS socket) with
/// retry/backoff: 50 ms doubling to a 1 s cap, giving up after `deadline`.
pub fn connect_retry(addr: &str, deadline: Duration) -> Result<Stream> {
    let t0 = std::time::Instant::now();
    let mut backoff = Duration::from_millis(50);
    loop {
        let got: std::io::Result<Stream> = if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                UnixStream::connect(path).map(Stream::Unix)
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "unix-domain sockets are not available on this platform",
                ))
            }
        } else {
            TcpStream::connect(addr).map(Stream::Tcp)
        };
        match got {
            Ok(s) => return Ok(s),
            Err(e) => {
                if t0.elapsed() >= deadline {
                    bail!("connecting to {addr}: {e} (gave up after {:?})", t0.elapsed());
                }
                std::thread::sleep(backoff.min(deadline.saturating_sub(t0.elapsed())));
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// frame I/O
// ---------------------------------------------------------------------------

/// Write one `[len][tag][payload]` frame; returns the wire bytes written.
pub fn write_frame(w: &mut Stream, tag: u8, payload: &[u8]) -> std::io::Result<u64> {
    let len = (payload.len() + 1) as u32;
    let mut buf = Vec::with_capacity(5 + payload.len());
    buf.extend_from_slice(&len.to_le_bytes());
    buf.push(tag);
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(buf.len() as u64)
}

/// Read one frame; returns `(tag, payload, wire bytes read)`. A clean EOF
/// at a frame boundary and a read timeout both surface as `Err` — the
/// caller decides whether the connection was expected to close.
pub fn read_frame(r: &mut Stream) -> std::io::Result<(u8, Vec<u8>, u64)> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4);
    if len == 0 || len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let mut payload = vec![0u8; len as usize - 1];
    r.read_exact(&mut payload)?;
    Ok((tag[0], payload, 4 + len as u64))
}

// ---------------------------------------------------------------------------
// payload codecs (fixed-width little-endian scalars + checkpoint tensor codec)
// ---------------------------------------------------------------------------

fn put_u32(b: &mut Vec<u8>, x: u32) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, x: u64) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, x: f64) {
    b.extend_from_slice(&x.to_le_bytes());
}

/// Payload reader with bounds-checked typed takes.
struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, off: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.b.len() {
            bail!("frame payload truncated (need {n} bytes at offset {})", self.off);
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn rest_str(&mut self) -> Result<String> {
        let s = &self.b[self.off..];
        self.off = self.b.len();
        String::from_utf8(s.to_vec()).map_err(|_| anyhow!("frame payload is not UTF-8"))
    }

    fn tensors(&mut self, shapes: &[Vec<usize>]) -> Result<Vec<Tensor>> {
        // take_tensors advances its own offset over the raw f32 region
        let out = take_tensors(self.b, &mut self.off, shapes)?;
        Ok(out)
    }
}

pub fn enc_hello(version: u32, rank: u32, digest: &Digest) -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(&MAGIC);
    put_u32(&mut b, version);
    put_u32(&mut b, rank);
    b.extend_from_slice(digest.to_json().to_string_pretty().as_bytes());
    b
}

pub fn enc_round(round: usize, k: usize, params: &[Tensor]) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, round as u64);
    put_u64(&mut b, k as u64);
    push_tensors(&mut b, params);
    b
}

pub fn dec_round(payload: &[u8], shapes: &[Vec<usize>]) -> Result<(usize, usize, Vec<Tensor>)> {
    let mut r = Rd::new(payload);
    let round = r.u64()? as usize;
    let k = r.u64()? as usize;
    let params = r.tensors(shapes)?;
    Ok((round, k, params))
}

pub fn enc_state(state: &ModelState) -> Vec<u8> {
    let mut b = Vec::new();
    push_tensors(&mut b, &state.params);
    push_tensors(&mut b, &state.opt);
    b
}

pub fn dec_state(
    payload: &[u8],
    param_shapes: &[Vec<usize>],
    opt_shapes: &[Vec<usize>],
) -> Result<ModelState> {
    let mut r = Rd::new(payload);
    Ok(ModelState {
        params: r.tensors(param_shapes)?,
        opt: r.tensors(opt_shapes)?,
    })
}

pub(crate) fn enc_round_reply(u: &ParamsUp) -> Vec<u8> {
    let mut b = Vec::new();
    put_u32(&mut b, u.part);
    put_u64(&mut b, u.round as u64);
    put_f64(&mut b, u.loss_sum);
    put_u64(&mut b, u.loss_n as u64);
    put_f64(&mut b, u.net_s);
    put_f64(&mut b, u.elapsed_s);
    push_tensors(&mut b, &u.params);
    b
}

pub(crate) fn dec_round_reply(payload: &[u8], shapes: &[Vec<usize>]) -> Result<ParamsUp> {
    let mut r = Rd::new(payload);
    Ok(ParamsUp {
        part: r.u32()?,
        round: r.u64()? as usize,
        loss_sum: r.f64()?,
        loss_n: r.u64()? as usize,
        net_s: r.f64()?,
        elapsed_s: r.f64()?,
        params: r.tensors(shapes)?,
    })
}

pub fn enc_snapshot_reply(part: u32, state: &ModelState) -> Vec<u8> {
    let mut b = Vec::new();
    put_u32(&mut b, part);
    push_tensors(&mut b, &state.params);
    push_tensors(&mut b, &state.opt);
    b
}

pub fn dec_snapshot_reply(
    payload: &[u8],
    param_shapes: &[Vec<usize>],
    opt_shapes: &[Vec<usize>],
) -> Result<(u32, ModelState)> {
    let mut r = Rd::new(payload);
    let part = r.u32()?;
    Ok((
        part,
        ModelState {
            params: r.tensors(param_shapes)?,
            opt: r.tensors(opt_shapes)?,
        },
    ))
}

pub fn enc_features(bytes: u64) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, bytes);
    b
}

pub fn dec_features(payload: &[u8]) -> Result<u64> {
    Rd::new(payload).u64()
}

pub fn enc_failed(part: u32, msg: &str) -> Vec<u8> {
    let mut b = Vec::new();
    put_u32(&mut b, part);
    b.extend_from_slice(msg.as_bytes());
    b
}

pub fn dec_failed(payload: &[u8]) -> Result<(u32, String)> {
    let mut r = Rd::new(payload);
    let part = r.u32()?;
    Ok((part, r.rest_str()?))
}

// ---------------------------------------------------------------------------
// handshake
// ---------------------------------------------------------------------------

/// Typed handshake failure — both ends see the same variant for the same
/// cause (the server also writes a coded `Reject` frame before erroring so
/// the client can map it back).
#[derive(Debug)]
pub enum HandshakeError {
    /// the first frame did not start with [`MAGIC`]
    BadMagic,
    /// wire-format versions differ; exact match is required
    VersionMismatch { ours: u32, theirs: u32 },
    /// the config digests differ — the peer is running a different
    /// experiment (message lists both digests)
    DigestMismatch(String),
    /// the server refused the connection for another coded reason
    /// (e.g. an unexpected rank)
    Rejected { code: u8, msg: String },
    Io(std::io::Error),
}

impl fmt::Display for HandshakeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HandshakeError::BadMagic => write!(f, "handshake: bad protocol magic"),
            HandshakeError::VersionMismatch { ours, theirs } => write!(
                f,
                "handshake: wire version mismatch (ours {ours}, peer {theirs})"
            ),
            HandshakeError::DigestMismatch(msg) => {
                write!(f, "handshake: config digest mismatch: {msg}")
            }
            HandshakeError::Rejected { code, msg } => {
                write!(f, "handshake: rejected (code {code}): {msg}")
            }
            HandshakeError::Io(e) => write!(f, "handshake: {e}"),
        }
    }
}

impl std::error::Error for HandshakeError {}

fn write_reject(s: &mut Stream, code: u8, ours: u32, msg: &str) {
    let mut b = Vec::new();
    b.push(code);
    put_u32(&mut b, ours);
    b.extend_from_slice(msg.as_bytes());
    let _ = write_frame(s, TAG_REJECT, &b);
}

/// Server side: read one `ClientHello` and validate magic, version, digest,
/// and rank. Writes `Welcome { flags }` on success, a coded `Reject` on any
/// mismatch (then returns the matching typed error).
pub fn server_accept_hello(
    s: &mut Stream,
    expect: &Digest,
    expect_rank: u32,
    flags: u8,
) -> std::result::Result<u32, HandshakeError> {
    let (tag, payload, _) = read_frame(s).map_err(HandshakeError::Io)?;
    if tag != TAG_HELLO || payload.len() < 12 {
        write_reject(s, REJ_MAGIC, WIRE_VERSION, "expected ClientHello");
        return Err(HandshakeError::BadMagic);
    }
    if payload[0..4] != MAGIC {
        write_reject(s, REJ_MAGIC, WIRE_VERSION, "bad magic");
        return Err(HandshakeError::BadMagic);
    }
    let theirs = u32::from_le_bytes(payload[4..8].try_into().expect("4 bytes"));
    if theirs != WIRE_VERSION {
        write_reject(
            s,
            REJ_VERSION,
            WIRE_VERSION,
            &format!("wire version {theirs} (this server speaks {WIRE_VERSION})"),
        );
        return Err(HandshakeError::VersionMismatch {
            ours: WIRE_VERSION,
            theirs,
        });
    }
    let rank = u32::from_le_bytes(payload[8..12].try_into().expect("4 bytes"));
    let digest_text = std::str::from_utf8(&payload[12..]).unwrap_or("");
    let theirs_digest = crate::util::Json::parse(digest_text)
        .ok()
        .and_then(|j| Digest::from_json(&j).ok());
    match theirs_digest {
        Some(d) if d == *expect => {}
        other => {
            let msg = format!("worker digest {other:?} != server digest {expect:?}");
            write_reject(s, REJ_DIGEST, WIRE_VERSION, &msg);
            return Err(HandshakeError::DigestMismatch(msg));
        }
    }
    if rank != expect_rank {
        let msg = format!("rank {rank} (expected {expect_rank})");
        write_reject(s, REJ_RANK, WIRE_VERSION, &msg);
        return Err(HandshakeError::Rejected { code: REJ_RANK, msg });
    }
    write_frame(s, TAG_WELCOME, &[flags]).map_err(HandshakeError::Io)?;
    Ok(rank)
}

/// Client side with an explicit version (tests drive mismatches through
/// this); returns the server's `Welcome` flags.
pub fn client_hello_versioned(
    s: &mut Stream,
    version: u32,
    rank: u32,
    digest: &Digest,
) -> std::result::Result<u8, HandshakeError> {
    write_frame(s, TAG_HELLO, &enc_hello(version, rank, digest)).map_err(HandshakeError::Io)?;
    let (tag, payload, _) = read_frame(s).map_err(HandshakeError::Io)?;
    match tag {
        TAG_WELCOME => Ok(payload.first().copied().unwrap_or(0)),
        TAG_REJECT => {
            let mut r = Rd::new(&payload);
            let code = r.take(1).map(|b| b[0]).unwrap_or(0);
            let server_version = r.u32().unwrap_or(0);
            let msg = r.rest_str().unwrap_or_default();
            Err(match code {
                REJ_VERSION => HandshakeError::VersionMismatch {
                    ours: version,
                    theirs: server_version,
                },
                REJ_DIGEST => HandshakeError::DigestMismatch(msg),
                REJ_MAGIC => HandshakeError::BadMagic,
                _ => HandshakeError::Rejected { code, msg },
            })
        }
        other => Err(HandshakeError::Io(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("unexpected handshake frame tag {other}"),
        ))),
    }
}

/// Client side of the handshake at this build's [`WIRE_VERSION`].
pub fn client_hello(
    s: &mut Stream,
    rank: u32,
    digest: &Digest,
) -> std::result::Result<u8, HandshakeError> {
    client_hello_versioned(s, WIRE_VERSION, rank, digest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn pair() -> (Stream, Stream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let c = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = l.accept().unwrap();
        (Stream::Tcp(srv), Stream::Tcp(c.join().unwrap()))
    }

    #[test]
    fn frames_roundtrip_with_byte_counts() {
        let (mut a, mut b) = pair();
        let n = write_frame(&mut a, TAG_FEATURES, &enc_features(7)).unwrap();
        let (tag, payload, m) = read_frame(&mut b).unwrap();
        assert_eq!(tag, TAG_FEATURES);
        assert_eq!(dec_features(&payload).unwrap(), 7);
        assert_eq!(n, m);
        assert_eq!(n, 4 + 1 + 8);
    }

    #[test]
    fn tensor_payloads_are_bit_exact() {
        let t = Tensor {
            shape: vec![2, 3],
            data: vec![1.5, -0.25, f32::MIN_POSITIVE, 3.0e7, -0.0, 42.0],
        };
        let state = ModelState {
            params: vec![t.clone()],
            opt: vec![t.clone(), t.clone()],
        };
        let payload = enc_state(&state);
        let got = dec_state(&payload, &[vec![2, 3]], &[vec![2, 3], vec![2, 3]]).unwrap();
        for (a, b) in got.params.iter().chain(&got.opt).zip([&t, &t, &t]) {
            assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn handshake_accepts_matching_config() {
        let cfg = ExperimentConfig::default();
        let d = Digest::of(&cfg);
        let (mut srv, mut cli) = pair();
        let d2 = d.clone();
        let t = std::thread::spawn(move || server_accept_hello(&mut srv, &d2, 3, WELCOME_TRACE));
        let flags = client_hello(&mut cli, 3, &d).unwrap();
        assert_eq!(flags, WELCOME_TRACE);
        assert_eq!(t.join().unwrap().unwrap(), 3);
    }

    #[test]
    fn handshake_rejects_version_and_digest_mismatch_typed() {
        let cfg = ExperimentConfig::default();
        let d = Digest::of(&cfg);
        // version skew: both sides report the same (ours, theirs) pair
        let (mut srv, mut cli) = pair();
        let d2 = d.clone();
        let t = std::thread::spawn(move || server_accept_hello(&mut srv, &d2, 0, 0));
        let err = client_hello_versioned(&mut cli, WIRE_VERSION + 1, 0, &d).unwrap_err();
        assert!(
            matches!(err, HandshakeError::VersionMismatch { ours, theirs }
                if ours == WIRE_VERSION + 1 && theirs == WIRE_VERSION),
            "{err}"
        );
        assert!(matches!(
            t.join().unwrap().unwrap_err(),
            HandshakeError::VersionMismatch { .. }
        ));
        // digest skew (different seed)
        let mut other = ExperimentConfig::default();
        other.seed = 99;
        let d_other = Digest::of(&other);
        let (mut srv, mut cli) = pair();
        let t = std::thread::spawn(move || server_accept_hello(&mut srv, &d_other, 0, 0));
        let err = client_hello(&mut cli, 0, &d).unwrap_err();
        assert!(matches!(err, HandshakeError::DigestMismatch(_)), "{err}");
        assert!(matches!(
            t.join().unwrap().unwrap_err(),
            HandshakeError::DigestMismatch(_)
        ));
        // wrong rank is a coded rejection
        let (mut srv, mut cli) = pair();
        let d2 = d.clone();
        let t = std::thread::spawn(move || server_accept_hello(&mut srv, &d2, 1, 0));
        let err = client_hello(&mut cli, 2, &d).unwrap_err();
        assert!(
            matches!(err, HandshakeError::Rejected { code: REJ_RANK, .. }),
            "{err}"
        );
        assert!(t.join().unwrap().is_err());
    }
}
