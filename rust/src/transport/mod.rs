//! Transport layer under the cluster engine: how `ParamsDown`/`ParamsUp`/
//! `RemoteFeatures`/`Snapshot`/`Shutdown` actually move between the
//! parameter server and its workers.
//!
//! Two implementations behind one [`Transport`] front:
//!
//! - **in-process** (the default): workers are OS threads in this process,
//!   wired over mpsc channels, with all network cost *modeled* by
//!   [`NetModel`]. Kept verbatim from the original engine for simulation
//!   and determinism tests.
//! - **tcp / uds**: workers are real OS processes (`llcg worker --connect
//!   <addr> --rank p`) spawned by the server and speaking the versioned,
//!   length-prefixed wire format in [`wire`]. A pair of bridge threads per
//!   worker adapts the socket to the engine's existing channel protocol,
//!   so the engine body is transport-agnostic; per-connection heartbeats
//!   replace the in-process liveness guard, and a dead connection surfaces
//!   as [`Up::Failed`] feeding the PR-6 respawn/quorum machinery.
//!
//! Sync mode stays bit-identical to the sequential driver across the
//! socket boundary: parameters cross as raw `f32` little-endian (the
//! checkpoint tensor codec), the worker process rebuilds its run state
//! from the same config via `setup_run`, and the server overwrites it
//! with an exact [`wire::TAG_RESTORE`] image so optimizer moments survive
//! respawn/resume exactly as they do in-process. Measured wire bytes
//! (all framed traffic after the handshake, both legs) are tallied per
//! round next to the modeled `CommStats`.

pub mod wire;
mod worker;

pub use worker::run_worker;

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::Scope;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::checkpoint::Digest;
use crate::cluster::NetModel;
use crate::config::ExperimentConfig;
use crate::coordinator::driver::{self, PartInfo, RunSetup};
use crate::graph::Dataset;
use crate::obs::SpanRec;
use crate::runtime::{ModelState, Runtime, Tensor};
use crate::sampler::{BlockArena, BlockBuilder, NodeScratch};
use crate::util::Json;

use wire::{Listener, Stream};

/// How long a worker process gets to spawn + connect back before the
/// server gives up on it (covers binary startup, not model setup).
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(30);

/// Server-side read-timeout floor on a worker connection. Heartbeats
/// arrive every `cfg.heartbeat_ms`, so silence for `max(this, several
/// periods)` means the process is wedged or the link is gone — the bridge
/// reports the worker as failed.
const CONN_TIMEOUT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// engine-side messages (shared by both transports)
// ---------------------------------------------------------------------------

/// Server → worker.
pub(crate) enum Down {
    /// `ParamsDown`: run local round `round` (`k` steps) from `params`.
    Round {
        round: usize,
        k: usize,
        params: Vec<Tensor>,
    },
    /// Checkpoint boundary: reply with the full local state (params +
    /// optimizer moments) via [`Up::Snapshot`].
    Snapshot,
    /// Terminal: the run is over; exit the worker loop.
    Shutdown,
}

/// Worker → server (one shared channel, tagged by worker).
pub(crate) enum Up {
    /// `RemoteFeatures`: a mini-batch fetched remote node features (GGS);
    /// the server folds the bytes into the current round's accounting.
    Features { bytes: u64 },
    /// `ParamsUp`: end-of-round parameter upload + round stats.
    Round(ParamsUp),
    /// Reply to [`Down::Snapshot`]: the worker's full resumable state.
    Snapshot { part: u32, state: Box<ModelState> },
    /// Unrecoverable worker error; with fault tolerance off the server
    /// aborts the run, with it on the worker is respawned next round.
    Failed { part: u32, err: String },
}

/// Payload of [`Up::Round`].
pub(crate) struct ParamsUp {
    pub part: u32,
    pub round: usize,
    pub params: Vec<Tensor>,
    pub loss_sum: f64,
    pub loss_n: usize,
    pub net_s: f64,
    pub elapsed_s: f64,
}

/// A failed `Down` send means the worker is gone; it usually queued an
/// `Up::Failed` with the root cause (e.g. its `Runtime::load` error) before
/// exiting — surface that instead of a generic channel error.
pub(crate) fn worker_send_error(up_rx: &Receiver<Up>, fallback: &str) -> anyhow::Error {
    while let Ok(msg) = up_rx.try_recv() {
        if let Up::Failed { part, err } = msg {
            return anyhow!("worker {part} failed: {err}");
        }
    }
    anyhow!("{fallback}")
}

// ---------------------------------------------------------------------------
// in-process worker body (moved verbatim from cluster/engine.rs)
// ---------------------------------------------------------------------------

/// Everything a worker thread needs; refs point at run-owned data that
/// outlives the thread scope.
pub(crate) struct WorkerSpec<'a> {
    pub cfg: &'a ExperimentConfig,
    pub ds: &'a Dataset,
    pub assignment: &'a [u32],
    pub info: &'a PartInfo,
    pub netm: &'a NetModel,
    pub dir: PathBuf,
    pub train_name: String,
    pub builder: BlockBuilder,
    pub param_bytes: u64,
    /// kernel-pool lanes for this worker's private runtime, sized so that
    /// `P workers × T lanes` does not oversubscribe the host
    pub kernel_threads: usize,
}

/// Worker thread body: build a private native `Runtime`, then serve
/// `Down::Round` requests until shutdown / disconnect. Model + optimizer
/// state, block arena, and sampling scratch live here for the whole run.
pub(crate) fn worker_main(
    spec: WorkerSpec<'_>,
    rx: Receiver<Down>,
    up: Sender<Up>,
    mut state: ModelState,
) {
    let rt = match Runtime::load(&spec.dir) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = up.send(Up::Failed {
                part: spec.info.part,
                err: format!("{e:#}"),
            });
            return;
        }
    };
    rt.set_kernel_threads(spec.kernel_threads);
    let mut arena = BlockArena::new();
    let mut scratch = NodeScratch::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            Down::Round { round, k, params } => {
                if spec.netm.crashed(spec.info.part, round as u64) {
                    // injected fault: die silently at round start, like a
                    // lost node (the server knows the schedule and does not
                    // wait for this worker)
                    return;
                }
                let out = driver::run_worker_round(
                    &rt,
                    &spec.train_name,
                    spec.cfg,
                    spec.ds,
                    spec.assignment,
                    spec.info,
                    &spec.builder,
                    spec.netm,
                    spec.param_bytes,
                    &mut state,
                    &params,
                    round,
                    k,
                    &mut arena,
                    &mut scratch,
                    |fb| {
                        let _ = up.send(Up::Features { bytes: fb });
                    },
                );
                let reply = match out {
                    Ok(o) => Up::Round(ParamsUp {
                        part: spec.info.part,
                        round,
                        params: state.params.clone(),
                        loss_sum: o.loss_sum,
                        loss_n: o.loss_n,
                        net_s: o.net_s,
                        elapsed_s: o.elapsed_s,
                    }),
                    Err(e) => Up::Failed {
                        part: spec.info.part,
                        err: format!("{e:#}"),
                    },
                };
                let fatal = matches!(reply, Up::Failed { .. });
                if up.send(reply).is_err() || fatal {
                    break;
                }
            }
            Down::Snapshot => {
                let reply = Up::Snapshot {
                    part: spec.info.part,
                    state: Box::new(state.clone()),
                };
                if up.send(reply).is_err() {
                    break;
                }
            }
            Down::Shutdown => break,
        }
    }
}

// ---------------------------------------------------------------------------
// transport selection
// ---------------------------------------------------------------------------

/// Which wire the workers ride.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// worker threads + mpsc channels, network cost modeled by `NetModel`
    InProcess,
    /// worker processes over loopback TCP
    Tcp,
    /// worker processes over a unix-domain socket
    Uds,
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inprocess",
            TransportKind::Tcp => "tcp",
            TransportKind::Uds => "uds",
        }
    }
}

/// Parsed `--transport` spec: `inprocess|tcp|uds[,kill=p@r]*`. `kill=p@r`
/// SIGKILLs the worker *process* serving part `p` right after round `r`'s
/// `ParamsDown` is written to it — the real-process analogue of the
/// modeled `net=...,crash=p@r` fault (and it feeds the same respawn
/// machinery), so it requires a real transport.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportSpec {
    pub kind: TransportKind,
    pub kills: Vec<(u32, u64)>,
}

impl TransportSpec {
    pub fn parse(s: &str) -> std::result::Result<TransportSpec, String> {
        let mut toks = s.split(',');
        let kind = match toks.next().map(str::trim).unwrap_or("") {
            "" | "inprocess" => TransportKind::InProcess,
            "tcp" => TransportKind::Tcp,
            "uds" => {
                if cfg!(not(unix)) {
                    return Err("transport=uds needs unix-domain sockets (unix only)".into());
                }
                TransportKind::Uds
            }
            other => {
                return Err(format!(
                    "unknown transport '{other}' (expected inprocess, tcp, or uds)"
                ))
            }
        };
        let mut kills = Vec::new();
        for tok in toks {
            let tok = tok.trim();
            let Some(spec) = tok.strip_prefix("kill=") else {
                return Err(format!(
                    "unknown transport option '{tok}' (expected kill=part@round)"
                ));
            };
            let (p, r) = spec
                .split_once('@')
                .ok_or_else(|| format!("kill spec '{spec}' must be part@round"))?;
            let p: u32 = p
                .trim()
                .parse()
                .map_err(|_| format!("bad part in kill spec '{spec}'"))?;
            let r: u64 = r
                .trim()
                .parse()
                .map_err(|_| format!("bad round in kill spec '{spec}'"))?;
            if r == 0 {
                return Err("kill rounds are 1-based (kill=p@r with r >= 1)".into());
            }
            kills.push((p, r));
        }
        if !kills.is_empty() && kind == TransportKind::InProcess {
            return Err(
                "kill=p@r needs a real transport (tcp or uds); the in-process \
                 transport injects crashes via net=...,crash=p@r"
                    .into(),
            );
        }
        Ok(TransportSpec { kind, kills })
    }
}

/// Run-owned data every spawned worker borrows; built once by the engine
/// before its thread scope so both transports can spawn (and respawn)
/// workers from it.
pub(crate) struct WorkerHost<'a> {
    pub cfg: &'a ExperimentConfig,
    pub ds: &'a Dataset,
    pub assignment: &'a [u32],
    pub netm: &'a NetModel,
    pub dir: PathBuf,
    pub train_name: String,
    pub builder: BlockBuilder,
    pub param_bytes: u64,
}

/// Tensor shape manifests for decoding worker frames (every worker shares
/// one model shape).
struct WireShapes {
    params: Vec<Vec<usize>>,
    opt: Vec<Vec<usize>>,
}

/// The engine's handle on its worker fleet.
pub(crate) enum Transport {
    InProcess,
    Remote(RemoteCluster),
}

impl Transport {
    /// Build the transport for this run (binds the listener for remote
    /// kinds; spawns nothing yet).
    pub(crate) fn new(cfg: &ExperimentConfig, setup: &RunSetup) -> Result<Transport> {
        let spec = TransportSpec::parse(&cfg.transport).map_err(|e| anyhow!(e))?;
        if spec.kind == TransportKind::InProcess {
            return Ok(Transport::InProcess);
        }
        let (listener, addr, uds_dir) = match spec.kind {
            TransportKind::Tcp => {
                let l = std::net::TcpListener::bind("127.0.0.1:0")
                    .context("binding the worker listener")?;
                let addr = l.local_addr()?.to_string();
                (Listener::Tcp(l), addr, None)
            }
            #[cfg(unix)]
            TransportKind::Uds => {
                let dir = std::env::temp_dir().join(format!(
                    "llcg-uds-{}-{:x}",
                    std::process::id(),
                    UDS_SEQ.fetch_add(1, Ordering::Relaxed)
                ));
                std::fs::create_dir_all(&dir)?;
                let path = dir.join("w.sock");
                let l = std::os::unix::net::UnixListener::bind(&path)
                    .with_context(|| format!("binding {}", path.display()))?;
                (Listener::Unix(l), format!("unix:{}", path.display()), Some(dir))
            }
            _ => unreachable!("parse rejects unsupported kinds"),
        };
        listener.set_nonblocking(true)?;
        // `LLCG_WORKER_EXE` override: integration tests are not the `llcg`
        // binary themselves, so they point this at env!("CARGO_BIN_EXE_llcg")
        let exe = match std::env::var_os("LLCG_WORKER_EXE") {
            Some(p) => PathBuf::from(p),
            None => std::env::current_exe().context("locating the llcg binary")?,
        };
        let shapes = WireShapes {
            params: setup
                .workers
                .first()
                .map(|w| w.params.iter().map(|t| t.shape.clone()).collect())
                .unwrap_or_default(),
            opt: setup
                .workers
                .first()
                .map(|w| w.opt.iter().map(|t| t.shape.clone()).collect())
                .unwrap_or_default(),
        };
        Ok(Transport::Remote(RemoteCluster {
            kind: spec.kind,
            kills: spec.kills,
            listener,
            addr,
            exe,
            cfg: cfg.clone(),
            digest: Digest::of(cfg),
            trace: crate::obs::enabled(),
            wire_up: AtomicU64::new(0),
            wire_down: AtomicU64::new(0),
            children: Mutex::new(Vec::new()),
            uds_dir,
            shapes,
        }))
    }

    pub(crate) fn name(&self) -> &'static str {
        match self {
            Transport::InProcess => "inprocess",
            Transport::Remote(r) => r.kind.name(),
        }
    }

    /// Whether this transport injects its own faults (scheduled process
    /// kills) — folded into the engine's fault-tolerance switch.
    pub(crate) fn has_faults(&self) -> bool {
        matches!(self, Transport::Remote(r) if !r.kills.is_empty())
    }

    /// Measured `(up, down)` wire bytes so far: every framed byte after the
    /// handshake (rounds, snapshots, restore images, heartbeats, obs
    /// flushes), summed over all worker connections. Always zero for the
    /// in-process transport — its traffic is modeled, not measured.
    pub(crate) fn wire_totals(&self) -> (u64, u64) {
        match self {
            Transport::InProcess => (0, 0),
            Transport::Remote(r) => (
                r.wire_up.load(Ordering::Relaxed),
                r.wire_down.load(Ordering::Relaxed),
            ),
        }
    }

    /// Spawn (or respawn) the worker for `info` seeded with `state`;
    /// returns its `Down` sender. Infallible by contract: a spawn that
    /// cannot come up reports `Up::Failed` on the shared channel (the
    /// fault-tolerant path respawns it; the fault-free path surfaces the
    /// root cause via `worker_send_error`) and returns a dangling sender.
    pub(crate) fn spawn_worker<'scope, 'env>(
        &'env self,
        s: &'scope Scope<'scope, 'env>,
        host: &'env WorkerHost<'env>,
        info: &'env PartInfo,
        state: ModelState,
        up_tx: &Sender<Up>,
        lanes: usize,
    ) -> Sender<Down> {
        match self {
            Transport::InProcess => {
                let (dtx, drx) = channel::<Down>();
                let spec = WorkerSpec {
                    cfg: host.cfg,
                    ds: host.ds,
                    assignment: host.assignment,
                    info,
                    netm: host.netm,
                    dir: host.dir.clone(),
                    train_name: host.train_name.clone(),
                    builder: host.builder.clone(),
                    param_bytes: host.param_bytes,
                    kernel_threads: lanes,
                };
                let up = up_tx.clone();
                s.spawn(move || worker_main(spec, drx, up, state));
                dtx
            }
            Transport::Remote(r) => match r.spawn_remote(s, host, info, state, up_tx, lanes) {
                Ok(dtx) => dtx,
                Err(e) => {
                    let _ = up_tx.send(Up::Failed {
                        part: info.part,
                        err: format!("{e:#}"),
                    });
                    // dangling sender: every send fails, like a dead thread
                    let (dtx, _drx) = channel::<Down>();
                    dtx
                }
            },
        }
    }

    /// End-of-run cleanup: reap worker processes (they exit on `Shutdown`
    /// or socket EOF; anything still alive after a grace period is killed)
    /// and remove the UDS socket directory.
    pub(crate) fn finish(&self) {
        if let Transport::Remote(r) = self {
            r.reap(Duration::from_secs(5));
        }
    }
}

static UDS_SEQ: AtomicU64 = AtomicU64::new(0);

// ---------------------------------------------------------------------------
// the remote (process) transport
// ---------------------------------------------------------------------------

pub(crate) struct RemoteCluster {
    kind: TransportKind,
    kills: Vec<(u32, u64)>,
    listener: Listener,
    /// what workers dial: `host:port`, or `unix:<path>`
    addr: String,
    exe: PathBuf,
    /// the run config, re-serialized to CLI flags for each worker process
    cfg: ExperimentConfig,
    digest: Digest,
    trace: bool,
    wire_up: AtomicU64,
    wire_down: AtomicU64,
    children: Mutex<Vec<Arc<Mutex<Child>>>>,
    uds_dir: Option<PathBuf>,
    /// shape manifests for decoding worker frames (fixed per run)
    shapes: WireShapes,
}

impl RemoteCluster {
    fn spawn_remote<'scope, 'env>(
        &'env self,
        s: &'scope Scope<'scope, 'env>,
        host: &'env WorkerHost<'env>,
        info: &'env PartInfo,
        state: ModelState,
        up_tx: &Sender<Up>,
        lanes: usize,
    ) -> Result<Sender<Down>> {
        let part = info.part;
        // the worker derives everything from the config; pin its kernel
        // lanes to the same budget an in-process worker would get
        let mut wcfg = self.cfg.clone();
        wcfg.kernel_threads = lanes;
        let mut cmd = Command::new(&self.exe);
        cmd.arg("worker")
            .arg("--connect")
            .arg(&self.addr)
            .arg("--rank")
            .arg(part.to_string())
            .args(crate::api::keys::cli_args(&wcfg))
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit());
        let child = Arc::new(Mutex::new(cmd.spawn().with_context(|| {
            format!("spawning worker {part} ({})", self.exe.display())
        })?));
        self.children.lock().expect("children lock").push(child.clone());

        let mut stream = self.accept_one(part)?;
        // exact state image: the worker re-derives its run state from the
        // config, then overwrites params + optimizer moments with this, so
        // resume and respawn stay bit-exact across the socket
        let n = wire::write_frame(&mut stream, wire::TAG_RESTORE, &wire::enc_state(&state))
            .context("sending the restore image")?;
        self.wire_down.fetch_add(n, Ordering::Relaxed);

        let reader = stream.try_clone()?;
        // the writer is shared: the down bridge sends rounds/snapshots, the
        // up bridge echoes timestamped heartbeats back for RTT measurement
        let writer = Arc::new(Mutex::new(stream));
        let (dtx, drx) = channel::<Down>();
        let up = up_tx.clone();
        let shutdown = Arc::new(AtomicBool::new(false));
        let last_round = Arc::new(AtomicU64::new(0));
        let kills: Vec<u64> = self
            .kills
            .iter()
            .filter(|&&(p, _)| p == part)
            .map(|&(_, r)| r)
            .collect();
        {
            let writer = writer.clone();
            let shutdown = shutdown.clone();
            let last_round = last_round.clone();
            let kills = kills.clone();
            s.spawn(move || {
                down_bridge(writer, drx, &self.wire_down, kills, child, shutdown, last_round)
            });
        }
        {
            let netm = host.netm;
            s.spawn(move || {
                up_bridge(
                    reader,
                    writer,
                    up,
                    part,
                    self,
                    netm,
                    shutdown,
                    last_round,
                )
            });
        }
        Ok(dtx)
    }

    /// Accept + handshake the connection for `part` (spawns are serialized,
    /// so exactly one worker is dialing at a time). Connections failing the
    /// handshake are rejected and dropped; accepting continues until the
    /// deadline.
    fn accept_one(&self, part: u32) -> Result<Stream> {
        let t0 = Instant::now();
        loop {
            match self.listener.accept() {
                Ok(mut s) => {
                    s.set_read_timeout(Some(Duration::from_secs(5)))?;
                    let flags = if self.trace { wire::WELCOME_TRACE } else { 0 };
                    match wire::server_accept_hello(&mut s, &self.digest, part, flags) {
                        Ok(_) => {
                            s.set_read_timeout(None)?;
                            return Ok(s);
                        }
                        Err(e) => {
                            // rejected (wrong version/digest/rank) or broken:
                            // drop it and keep listening for the real worker
                            crate::obs::counter("transport.handshake_rejected").add(1);
                            let _ = e;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if t0.elapsed() >= ACCEPT_TIMEOUT {
                        bail!(
                            "worker {part} did not connect within {:?} ({})",
                            ACCEPT_TIMEOUT,
                            self.addr
                        );
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Reap worker processes: poll for voluntary exit up to `grace`, then
    /// kill whatever is left.
    fn reap(&self, grace: Duration) {
        let children = std::mem::take(&mut *self.children.lock().expect("children lock"));
        let deadline = Instant::now() + grace;
        for c in children {
            loop {
                let mut ch = c.lock().expect("child lock");
                match ch.try_wait() {
                    Ok(Some(_)) | Err(_) => break,
                    Ok(None) => {
                        if Instant::now() >= deadline {
                            let _ = ch.kill();
                            let _ = ch.wait();
                            break;
                        }
                    }
                }
                drop(ch);
                std::thread::sleep(Duration::from_millis(10));
            }
        }
        if let Some(dir) = &self.uds_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

impl Drop for RemoteCluster {
    fn drop(&mut self) {
        // safety net for abort paths that never reach `Transport::finish`
        self.reap(Duration::ZERO);
    }
}

/// Engine → socket: serialize `Down` messages as frames. Executes this
/// connection's scheduled `kill=p@r` faults (SIGKILL right after round
/// `r`'s frame is written, so the worker dies mid-round like a lost node).
fn down_bridge(
    w: Arc<Mutex<Stream>>,
    rx: Receiver<Down>,
    wire_down: &AtomicU64,
    kills: Vec<u64>,
    child: Arc<Mutex<Child>>,
    shutdown: Arc<AtomicBool>,
    last_round: Arc<AtomicU64>,
) {
    let send = |tag: u8, payload: &[u8]| -> std::io::Result<u64> {
        wire::write_frame(&mut *w.lock().expect("writer lock"), tag, payload)
    };
    loop {
        match rx.recv() {
            Ok(Down::Round { round, k, params }) => {
                last_round.store(round as u64, Ordering::SeqCst);
                match send(wire::TAG_ROUND, &wire::enc_round(round, k, &params)) {
                    Ok(n) => {
                        wire_down.fetch_add(n, Ordering::Relaxed);
                    }
                    Err(_) => break, // connection dead; the up bridge reports it
                }
                if kills.contains(&(round as u64)) {
                    let _ = child.lock().expect("child lock").kill();
                    break;
                }
            }
            Ok(Down::Snapshot) => match send(wire::TAG_SNAPSHOT, &[]) {
                Ok(n) => {
                    wire_down.fetch_add(n, Ordering::Relaxed);
                }
                Err(_) => break,
            },
            Ok(Down::Shutdown) => {
                // flag before the frame so the up bridge treats the EOF that
                // follows the worker's obs flush as expected
                shutdown.store(true, Ordering::SeqCst);
                if let Ok(n) = send(wire::TAG_SHUTDOWN, &[]) {
                    wire_down.fetch_add(n, Ordering::Relaxed);
                }
                break;
            }
            Err(_) => {
                // the engine dropped this sender (abort, or respawn replaced
                // it): close the socket so the worker sees EOF and exits
                shutdown.store(true, Ordering::SeqCst);
                w.lock().expect("writer lock").shutdown();
                break;
            }
        }
    }
}

/// Socket → engine: decode worker frames back into `Up` messages. Absorbs
/// heartbeats and obs flushes; an unexpected EOF/timeout becomes
/// `Up::Failed` so a killed process feeds the respawn machinery exactly
/// like a crashed thread.
#[allow(clippy::too_many_arguments)]
fn up_bridge(
    mut r: Stream,
    w: Arc<Mutex<Stream>>,
    up: Sender<Up>,
    part: u32,
    rc: &RemoteCluster,
    netm: &NetModel,
    shutdown: Arc<AtomicBool>,
    last_round: Arc<AtomicU64>,
) {
    // a slow configured heartbeat must not trip the liveness timeout
    let timeout = CONN_TIMEOUT.max(Duration::from_millis(rc.cfg.heartbeat_ms.saturating_mul(5)));
    let _ = r.set_read_timeout(Some(timeout));
    let mut failed_seen = false;
    loop {
        let (tag, payload, n) = match wire::read_frame(&mut r) {
            Ok(f) => f,
            Err(e) => {
                let expected = failed_seen
                    || shutdown.load(Ordering::SeqCst)
                    || netm.crashed(part, last_round.load(Ordering::SeqCst));
                if !expected {
                    let _ = up.send(Up::Failed {
                        part,
                        err: format!("worker connection lost: {e}"),
                    });
                }
                break;
            }
        };
        rc.wire_up.fetch_add(n, Ordering::Relaxed);
        let res: Result<()> = (|| {
            match tag {
                wire::TAG_HEARTBEAT => {
                    if crate::obs::monitor::enabled() {
                        crate::obs::monitor::note_heartbeat(part);
                    }
                    // echo the worker's timestamp back so it can measure
                    // the round trip (transport.heartbeat_rtt_s, merged
                    // home with its next obs flush)
                    if let Ok(n) = wire::write_frame(
                        &mut *w.lock().expect("writer lock"),
                        wire::TAG_HEARTBEAT,
                        &payload,
                    ) {
                        rc.wire_down.fetch_add(n, Ordering::Relaxed);
                    }
                }
                wire::TAG_FEATURES => {
                    let bytes = wire::dec_features(&payload)?;
                    let _ = up.send(Up::Features { bytes });
                }
                wire::TAG_ROUND_REPLY => {
                    let u = wire::dec_round_reply(&payload, &rc.shapes.params)?;
                    let _ = up.send(Up::Round(u));
                }
                wire::TAG_SNAPSHOT_REPLY => {
                    let sh = &rc.shapes;
                    let (p, state) = wire::dec_snapshot_reply(&payload, &sh.params, &sh.opt)?;
                    let _ = up.send(Up::Snapshot {
                        part: p,
                        state: Box::new(state),
                    });
                }
                wire::TAG_FAILED => {
                    let (p, err) = wire::dec_failed(&payload)?;
                    failed_seen = true;
                    let _ = up.send(Up::Failed { part: p, err });
                }
                wire::TAG_OBS_FLUSH => {
                    absorb_obs_flush(part, &payload);
                }
                other => bail!("unexpected frame tag {other} from worker {part}"),
            }
            Ok(())
        })();
        if let Err(e) = res {
            let _ = up.send(Up::Failed {
                part,
                err: format!("{e:#}"),
            });
            break;
        }
    }
}

/// Fold a worker process's end-of-run obs flush into this process's
/// registries: metrics merge into the global registry immediately, spans
/// land in the remote-span store for the merged multi-process trace.
fn absorb_obs_flush(part: u32, payload: &[u8]) {
    let Ok(text) = std::str::from_utf8(payload) else {
        return;
    };
    let Ok(j) = Json::parse(text) else { return };
    if let Some(m) = j.get("metrics") {
        let _ = crate::obs::absorb_metrics_json(m);
    }
    if let Some(sp) = j.get("spans") {
        if let Ok(spans) = crate::obs::spans_from_json(sp) {
            add_remote_spans(format!("worker-{part}"), spans);
        }
    }
}

/// Spans shipped home by worker processes, keyed by track name
/// (`worker-<rank>`); drained by the trace exporter at the end of the run.
static REMOTE_SPANS: Mutex<Vec<(String, Vec<SpanRec>)>> = Mutex::new(Vec::new());

fn add_remote_spans(track: String, spans: Vec<SpanRec>) {
    let mut store = REMOTE_SPANS.lock().expect("remote span store");
    if let Some((_, existing)) = store.iter_mut().find(|(t, _)| *t == track) {
        existing.extend(spans); // a respawned worker extends its track
    } else {
        store.push((track, spans));
    }
}

/// Drain the spans worker processes flushed over the transport. Non-empty
/// only after a remote-transport run with tracing on; the trace exporter
/// switches to the multi-process layout when it is.
pub fn take_remote_spans() -> Vec<(String, Vec<SpanRec>)> {
    std::mem::take(&mut *REMOTE_SPANS.lock().expect("remote span store"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_spec_parses_kinds_and_kills() {
        assert_eq!(
            TransportSpec::parse("inprocess").unwrap().kind,
            TransportKind::InProcess
        );
        assert_eq!(TransportSpec::parse("").unwrap().kind, TransportKind::InProcess);
        assert_eq!(TransportSpec::parse("tcp").unwrap().kind, TransportKind::Tcp);
        #[cfg(unix)]
        assert_eq!(TransportSpec::parse("uds").unwrap().kind, TransportKind::Uds);
        let spec = TransportSpec::parse("tcp,kill=1@3,kill=0@2").unwrap();
        assert_eq!(spec.kills, vec![(1, 3), (0, 2)]);
    }

    #[test]
    fn transport_spec_rejects_bad_input() {
        assert!(TransportSpec::parse("smoke").is_err());
        assert!(TransportSpec::parse("tcp,kill=1").is_err());
        assert!(TransportSpec::parse("tcp,kill=x@2").is_err());
        // kill rounds are 1-based, like net=...,crash=p@r
        assert!(TransportSpec::parse("tcp,kill=1@0").is_err());
        // kills need a real process to kill
        assert!(TransportSpec::parse("inprocess,kill=1@2").is_err());
        assert!(TransportSpec::parse("tcp,frob=1").is_err());
    }

    #[test]
    fn remote_spans_merge_by_track() {
        let _ = take_remote_spans();
        let sp = |tid: u32| SpanRec {
            name: "x",
            tid,
            start_ns: 1,
            dur_ns: 2,
            round: -1,
        };
        add_remote_spans("worker-0".into(), vec![sp(1)]);
        add_remote_spans("worker-1".into(), vec![sp(2)]);
        add_remote_spans("worker-0".into(), vec![sp(3)]);
        let got = take_remote_spans();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, "worker-0");
        assert_eq!(got[0].1.len(), 2);
        assert!(take_remote_spans().is_empty());
    }
}
