//! The `llcg worker` process body: dial the server, handshake, restore the
//! exact worker state, then serve framed rounds until shutdown.
//!
//! The worker re-derives its whole run state (dataset, partition,
//! builders) from the same config the server used — shipped to it as CLI
//! flags — and then overwrites its params + optimizer moments with the
//! server's `Restore` image, so a remote worker is bit-identical to an
//! in-process worker thread: same inputs, same kernels, same outputs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::checkpoint::Digest;
use crate::config::ExperimentConfig;
use crate::coordinator::driver;
use crate::runtime::Runtime;
use crate::sampler::{BlockArena, NodeScratch};
use crate::util::Json;

use super::wire::{self, Stream};

/// Guarded writer shared by the reply path and the heartbeat thread (the
/// socket has one reader — the main loop — but two writers).
type SharedWriter = Arc<Mutex<Stream>>;

fn send(w: &SharedWriter, tag: u8, payload: &[u8]) -> std::io::Result<u64> {
    wire::write_frame(&mut w.lock().expect("writer lock"), tag, payload)
}

/// Serialize this process's spans + metrics for an `ObsFlush` frame.
fn obs_flush_json() -> Json {
    Json::obj(vec![
        ("schema", Json::num(crate::obs::SCHEMA_VERSION as f64)),
        ("spans", crate::obs::spans_to_json(&crate::obs::take_spans())),
        ("metrics", crate::obs::metrics_raw_json()),
    ])
}

/// Ship spans + metric deltas home, then zero the local registry. Called
/// at every round boundary (so a SIGKILLed worker's telemetry survives up
/// to its last completed round) and once more at exit. The server's
/// absorb is additive for counters/histograms, so each flush must carry
/// only the delta since the previous one; `take_spans` already drains.
fn flush_obs(w: &SharedWriter) {
    let _ = send(
        w,
        wire::TAG_OBS_FLUSH,
        obs_flush_json().to_string_pretty().as_bytes(),
    );
    crate::obs::reset_all();
}

/// Entry point behind `llcg worker --connect <addr> --rank <p>`; every
/// other flag is the run config, reproduced verbatim by the server.
pub fn run_worker(connect: &str, rank: u32, cfg: ExperimentConfig) -> Result<()> {
    let digest = Digest::of(&cfg);
    let mut stream = wire::connect_retry(connect, Duration::from_secs(30))?;
    let flags = wire::client_hello(&mut stream, rank, &digest)
        .map_err(|e| anyhow!("worker {rank}: {e}"))?;
    if flags & wire::WELCOME_TRACE != 0 {
        crate::obs::set_enabled(true);
    }

    let reader = stream.try_clone()?;
    let writer: SharedWriter = Arc::new(Mutex::new(stream));
    let mut reader = reader;

    // heartbeat immediately (setup below takes real time; the server's
    // per-connection read timeout must not mistake it for a wedged worker).
    // Each beat carries this process's monotonic clock in nanoseconds; the
    // server echoes it back verbatim so the main loop below can measure
    // the round trip without any cross-host clock agreement.
    let epoch = Instant::now();
    let heartbeat = Duration::from_millis(cfg.heartbeat_ms);
    let stop = Arc::new(AtomicBool::new(false));
    {
        let w = writer.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(heartbeat);
                let sent = (epoch.elapsed().as_nanos() as u64).to_le_bytes();
                if send(&w, wire::TAG_HEARTBEAT, &sent).is_err() {
                    break;
                }
            }
        });
    }

    // the restore image arrives right behind the welcome; buffer its raw
    // payload now (decoding needs the shape manifests from setup below)
    let (tag, restore_raw, _) = wire::read_frame(&mut reader)?;
    if tag != wire::TAG_RESTORE {
        bail!("worker {rank}: expected a restore frame, got tag {tag}");
    }

    // re-derive the run exactly as the server did
    let ds = driver::load_dataset(&cfg)?;
    let (rt, _adir) = Runtime::load_or_native(&cfg.artifacts_dir)?;
    if rt.backend_name() != "native" {
        bail!("worker processes need the native backend");
    }
    rt.set_kernel_threads(cfg.kernel_threads.max(1));
    let setup = driver::setup_run(&cfg, &ds, &rt, None)?;
    let part = rank as usize;
    if part >= setup.parts.len() {
        bail!("worker rank {rank} out of range (parts = {})", setup.parts.len());
    }
    let info = &setup.parts[part];
    let netm = &setup.net;
    let mut state = setup.workers[part].clone();
    // overwrite with the server's exact image: initial spawn ships the
    // setup-time state (a no-op by construction), resume ships checkpointed
    // optimizer moments, respawn ships the current global params
    {
        let pshapes: Vec<Vec<usize>> = state.params.iter().map(|t| t.shape.clone()).collect();
        let oshapes: Vec<Vec<usize>> = state.opt.iter().map(|t| t.shape.clone()).collect();
        state = wire::dec_state(&restore_raw, &pshapes, &oshapes)
            .context("decoding the restore image")?;
    }

    let down_shapes: Vec<Vec<usize>> =
        state.params.iter().map(|t| t.shape.clone()).collect();
    let mut arena = BlockArena::new();
    let mut scratch = NodeScratch::new();
    loop {
        let (tag, payload, _) = match wire::read_frame(&mut reader) {
            Ok(f) => f,
            // server gone (abort path closes the socket); exit quietly
            Err(_) => break,
        };
        match tag {
            wire::TAG_ROUND => {
                let (round, k, params) = wire::dec_round(&payload, &down_shapes)?;
                if netm.crashed(info.part, round as u64) {
                    // modeled fault: die silently at round start, exactly
                    // like the in-process worker (the server knows the
                    // schedule and does not wait)
                    stop.store(true, Ordering::Relaxed);
                    return Ok(());
                }
                let out = driver::run_worker_round(
                    &rt,
                    &setup.train_name,
                    &cfg,
                    &ds,
                    &setup.assignment,
                    info,
                    &setup.local_builder,
                    netm,
                    setup.param_bytes,
                    &mut state,
                    &params,
                    round,
                    k,
                    &mut arena,
                    &mut scratch,
                    |fb| {
                        let _ = send(&writer, wire::TAG_FEATURES, &wire::enc_features(fb));
                    },
                );
                match out {
                    Ok(o) => {
                        let up = super::ParamsUp {
                            part: info.part,
                            round,
                            params: state.params.clone(),
                            loss_sum: o.loss_sum,
                            loss_n: o.loss_n,
                            net_s: o.net_s,
                            elapsed_s: o.elapsed_s,
                        };
                        if send(&writer, wire::TAG_ROUND_REPLY, &wire::enc_round_reply(&up))
                            .is_err()
                        {
                            break;
                        }
                        // round boundary: telemetry must not wait for a
                        // clean exit a fault run never reaches
                        flush_obs(&writer);
                    }
                    Err(e) => {
                        // report and exit: the obs flush rides ahead of the
                        // failure so the server still merges this process's
                        // spans/metrics
                        flush_obs(&writer);
                        let _ = send(
                            &writer,
                            wire::TAG_FAILED,
                            &wire::enc_failed(info.part, &format!("{e:#}")),
                        );
                        stop.store(true, Ordering::Relaxed);
                        return Ok(());
                    }
                }
            }
            wire::TAG_HEARTBEAT => {
                // server echo of a timestamped beat: record the round trip
                if payload.len() == 8 {
                    let sent = u64::from_le_bytes(payload[..8].try_into().expect("len checked"));
                    let now = epoch.elapsed().as_nanos() as u64;
                    if now > sent {
                        crate::obs::histogram("transport.heartbeat_rtt_s").record_ns(now - sent);
                    }
                }
            }
            wire::TAG_SNAPSHOT => {
                if send(
                    &writer,
                    wire::TAG_SNAPSHOT_REPLY,
                    &wire::enc_snapshot_reply(info.part, &state),
                )
                .is_err()
                {
                    break;
                }
            }
            wire::TAG_SHUTDOWN => break,
            other => bail!("worker {rank}: unexpected frame tag {other}"),
        }
    }
    stop.store(true, Ordering::Relaxed);
    flush_obs(&writer);
    Ok(())
}
