//! Synthetic dataset generators — the offline substitutes for the paper's
//! datasets (Table 2), per DESIGN.md §Substitutions.
//!
//! The generator is a **two-level degree-corrected block model** built so
//! that the quantities LLCG's theory cares about are directly tunable:
//!
//! - *Topology communities* are dense label-agnostic blocks — what a
//!   min-cut partitioner (our METIS substitute) discovers and assigns to
//!   machines.
//! - Every node carries a random *attribute* `a(v)` (same alphabet as the
//!   classes) whose centroid is embedded in its **own** features — a pure
//!   distractor for classifying the node itself.
//! - A `cross_frac` fraction of each node's edges are **informative**: they
//!   connect `v` to nodes `u` with `a(u) = y(v)`, preferentially in *other*
//!   communities. The label is therefore readable only by *aggregating
//!   neighbor features* — and those edges are exactly the ones a min-cut
//!   partition cuts. This realizes the κ_A structure term of §4.1 as a
//!   knob, producing the PSGD-PA accuracy drop of Fig 2/4: local (induced)
//!   aggregation sees topology neighbors with random attributes, the global
//!   aggregation sees the label.
//! - `self_signal` additionally embeds the true class centroid in the
//!   node's own features: it sets the MLP floor (what a model can do with
//!   no graph at all).
//! - `coupled_labels` ties label = community (the OGB-Products regime,
//!   Fig 10c: METIS keeps label homophily local ⇒ no PSGD-PA gap), and
//!   `FeatureMultiLabel` labels ignore the graph entirely (the Yelp regime,
//!   Fig 10 a/b: MLP ≈ GCN and PSGD-PA ≈ GGS).
//!
//! Every named analog matches the feature/class dimensions of the artifacts
//! compiled by `python/compile/aot.py`.

use super::{CsrGraph, Dataset, Labels, Splits};
use crate::util::Pcg64;

/// Two-level block-model configuration.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub name: String,
    pub n: usize,
    /// number of topology communities (dense blocks; what METIS finds)
    pub communities: usize,
    /// target average degree (undirected)
    pub avg_degree: f64,
    /// fraction of edges that are informative (`a(u) = y(v)`, cross-community
    /// biased) — the κ_A knob; min-cut partitions destroy these
    pub cross_frac: f64,
    /// of the remaining edges, P(partner in own community) (vs uniform)
    pub homophily: f64,
    /// Pareto weight for per-node degree multipliers; 0 = regular degrees
    pub degree_skew: f64,
    /// label = community (mod c) instead of independent (products regime)
    pub coupled_labels: bool,
    pub d: usize,
    pub c: usize,
    /// class-centroid scale in the node's OWN features (the MLP floor)
    pub self_signal: f64,
    /// attribute-centroid scale (the neighbor-borne signal read via edges)
    pub attr_signal: f64,
    pub label_mode: LabelMode,
    /// fraction of labels flipped/corrupted
    pub label_noise: f64,
    pub train_frac: f64,
    pub val_frac: f64,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LabelMode {
    /// one class per node (primary label drives edges + centroids)
    MultiClass,
    /// multi-hot derived from the primary label via a random class->labels
    /// mapping (the proteins regime)
    MultiLabel,
    /// multi-hot from random feature projections — structure-independent
    /// (the Yelp regime)
    FeatureMultiLabel,
}

impl SynthConfig {
    /// Named analogs of the paper's datasets. Dimensions (d, c, loss) match
    /// `python/compile/aot.py::DATASETS`; sizes are scaled for the CPU
    /// testbed (DESIGN.md §Substitutions).
    pub fn by_name(name: &str) -> Option<SynthConfig> {
        let mut cfg = match name {
            // fast + easy (coupled): for unit/integration tests
            "tiny" => SynthConfig {
                name: String::new(),
                n: 300,
                communities: 4,
                avg_degree: 6.0,
                cross_frac: 0.0,
                homophily: 0.85,
                degree_skew: 0.0,
                coupled_labels: true,
                d: 16,
                c: 4,
                self_signal: 0.5,
                attr_signal: 0.0,
                label_mode: LabelMode::MultiClass,
                label_noise: 0.0,
                train_frac: 0.5,
                val_frac: 0.25,
            },
            // small decoupled variant for gap smoke-tests
            "tiny-hetero" => SynthConfig {
                name: String::new(),
                n: 600,
                communities: 4,
                avg_degree: 12.0,
                cross_frac: 0.55,
                homophily: 0.95,
                degree_skew: 0.0,
                coupled_labels: false,
                d: 16,
                c: 4,
                self_signal: 0.3,
                attr_signal: 1.3,
                label_mode: LabelMode::MultiClass,
                label_noise: 0.0,
                train_frac: 0.5,
                val_frac: 0.25,
            },
            "flickr-s" => SynthConfig {
                name: String::new(),
                n: 6_000,
                communities: 8,
                avg_degree: 10.0,
                cross_frac: 0.35,
                homophily: 0.95,
                degree_skew: 1.0,
                coupled_labels: false,
                d: 64,
                c: 7,
                self_signal: 0.40,
                attr_signal: 0.8,
                label_mode: LabelMode::MultiClass,
                label_noise: 0.05,
                train_frac: 0.50,
                val_frac: 0.25,
            },
            "proteins-s" => SynthConfig {
                name: String::new(),
                n: 6_000,
                communities: 8,
                avg_degree: 30.0,
                cross_frac: 0.30,
                homophily: 0.95,
                degree_skew: 0.5,
                coupled_labels: false,
                d: 16,
                c: 16,
                self_signal: 0.25,
                attr_signal: 0.7,
                label_mode: LabelMode::MultiLabel,
                label_noise: 0.05,
                train_frac: 0.65,
                val_frac: 0.16,
            },
            "arxiv-s" => SynthConfig {
                name: String::new(),
                n: 8_000,
                communities: 8,
                avg_degree: 7.0,
                cross_frac: 0.35,
                homophily: 0.95,
                degree_skew: 1.0,
                coupled_labels: false,
                d: 32,
                c: 16,
                self_signal: 0.45,
                attr_signal: 0.90,
                label_mode: LabelMode::MultiClass,
                label_noise: 0.05,
                train_frac: 0.54,
                val_frac: 0.17,
            },
            // the big-gap dataset: nearly no self signal; the label lives in
            // cross-community neighbor attributes (cut by METIS)
            "reddit-s" => SynthConfig {
                name: String::new(),
                n: 8_000,
                communities: 8,
                avg_degree: 25.0,
                cross_frac: 0.45,
                homophily: 0.95,
                degree_skew: 1.2,
                coupled_labels: false,
                d: 64,
                c: 16,
                self_signal: 0.40,
                attr_signal: 1.30,
                label_mode: LabelMode::MultiClass,
                label_noise: 0.02,
                train_frac: 0.66,
                val_frac: 0.10,
            },
            // structure-independent labels: MLP ≈ GCN, PSGD-PA ≈ GGS
            "yelp-s" => SynthConfig {
                name: String::new(),
                n: 8_000,
                communities: 12,
                avg_degree: 20.0,
                cross_frac: 0.0,
                homophily: 0.6,
                degree_skew: 0.8,
                coupled_labels: false,
                d: 32,
                c: 12,
                self_signal: 1.5,
                attr_signal: 0.0,
                label_mode: LabelMode::FeatureMultiLabel,
                label_noise: 0.02,
                train_frac: 0.75,
                val_frac: 0.15,
            },
            // coupled labels + tiny train split + strong communities:
            // METIS cut is small and label-homophily stays local (Fig 10c)
            "products-s" => SynthConfig {
                name: String::new(),
                n: 12_000,
                communities: 12,
                avg_degree: 15.0,
                cross_frac: 0.0,
                homophily: 0.95,
                degree_skew: 1.0,
                coupled_labels: true,
                d: 32,
                c: 12,
                self_signal: 0.45,
                attr_signal: 0.0,
                label_mode: LabelMode::MultiClass,
                label_noise: 0.03,
                train_frac: 0.08,
                val_frac: 0.02,
            },
            _ => return None,
        };
        cfg.name = name.to_string();
        Some(cfg)
    }

    pub fn all_names() -> &'static [&'static str] {
        &[
            "tiny",
            "tiny-hetero",
            "flickr-s",
            "proteins-s",
            "arxiv-s",
            "reddit-s",
            "yelp-s",
            "products-s",
        ]
    }
}

/// Generate a dataset from `cfg`, fully determined by `seed`.
pub fn generate(cfg: &SynthConfig, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed ^ 0x11c6_u64.wrapping_mul(0x9e3779b97f4a7c15));
    let n = cfg.n;
    let k = cfg.communities;
    let c_out = cfg.c;
    assert!(k >= 1 && n >= k, "bad block-model config");

    // --- communities (balanced), primary labels, attributes ----------------
    let mut community: Vec<u32> = (0..n).map(|v| (v % k) as u32).collect();
    rng.shuffle(&mut community);
    let primary: Vec<u16> = if cfg.coupled_labels {
        community
            .iter()
            .map(|&cc| (cc as usize % c_out) as u16)
            .collect()
    } else {
        (0..n).map(|_| rng.gen_range(c_out as u64) as u16).collect()
    };
    // distractor attribute, independent of everything else
    let attr: Vec<u16> = (0..n).map(|_| rng.gen_range(c_out as u64) as u16).collect();

    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &cc) in community.iter().enumerate() {
        members[cc as usize].push(v as u32);
    }
    let mut by_attr: Vec<Vec<u32>> = vec![Vec::new(); c_out];
    for (v, &a) in attr.iter().enumerate() {
        by_attr[a as usize].push(v as u32);
    }

    // --- edges --------------------------------------------------------------
    let half_deg = (cfg.avg_degree / 2.0).max(0.5);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity((n as f64 * half_deg) as usize);
    let all: Vec<u32> = (0..n as u32).collect();
    for v in 0..n as u32 {
        let mult = if cfg.degree_skew > 0.0 {
            let u = rng.f64().max(1e-9);
            (u.powf(-1.0 / 2.5)).min(8.0) * cfg.degree_skew + (1.0 - cfg.degree_skew)
        } else {
            1.0
        };
        let mut deg = (half_deg * mult).round() as usize;
        if deg == 0 && rng.bernoulli(half_deg * mult) {
            deg = 1;
        }
        let cv = community[v as usize] as usize;
        let yv = primary[v as usize] as usize;
        for _ in 0..deg {
            let u = if rng.bernoulli(cfg.cross_frac) && k > 1 && !by_attr[yv].is_empty()
            {
                // informative edge: partner whose ATTRIBUTE equals v's label,
                // biased away from v's own community — readable only by
                // aggregation, destroyed by min-cut partitioning
                let pool = &by_attr[yv];
                let mut pick = *rng.choose(pool);
                for _ in 0..16 {
                    if community[pick as usize] as usize != cv && pick != v {
                        break;
                    }
                    pick = *rng.choose(pool);
                }
                pick
            } else if rng.bernoulli(cfg.homophily) || k == 1 {
                // topology edge: own community (label-agnostic)
                *rng.choose(&members[cv])
            } else {
                // background noise edge
                *rng.choose(&all)
            };
            if u != v {
                edges.push((v, u));
            }
        }
    }
    let graph = CsrGraph::from_edges(n, &edges);

    // --- features: self-label centroid + attribute centroid + noise --------
    let d = cfg.d;
    let mut centroids = vec![0f32; c_out * d];
    for x in centroids.iter_mut() {
        *x = rng.normal_f32();
    }
    let mut features = vec![0f32; n * d];
    let s_self = cfg.self_signal as f32;
    let s_attr = cfg.attr_signal as f32;
    for v in 0..n {
        let yv = primary[v] as usize;
        let av = attr[v] as usize;
        for j in 0..d {
            features[v * d + j] = s_self * centroids[yv * d + j]
                + s_attr * centroids[av * d + j]
                + rng.normal_f32();
        }
    }

    // --- labels --------------------------------------------------------------
    let labels = match cfg.label_mode {
        LabelMode::MultiClass => {
            let mut y = primary.clone();
            for yy in y.iter_mut() {
                if rng.bernoulli(cfg.label_noise) {
                    *yy = rng.gen_range(c_out as u64) as u16;
                }
            }
            Labels::MultiClass(y)
        }
        LabelMode::MultiLabel => {
            // output label j active for a random ~40% subset of primary classes
            let mut active = vec![false; c_out * c_out];
            for j in 0..c_out {
                for l in 0..c_out {
                    active[j * c_out + l] = rng.bernoulli(0.4);
                }
                if !(0..c_out).any(|l| active[j * c_out + l]) {
                    active[j * c_out + rng.gen_range(c_out as u64) as usize] = true;
                }
            }
            let mut data = vec![0f32; n * c_out];
            for v in 0..n {
                let l = primary[v] as usize;
                for j in 0..c_out {
                    let mut on = active[j * c_out + l];
                    if rng.bernoulli(cfg.label_noise) {
                        on = !on;
                    }
                    data[v * c_out + j] = if on { 1.0 } else { 0.0 };
                }
            }
            Labels::MultiLabel { data, c: c_out }
        }
        LabelMode::FeatureMultiLabel => {
            // random projection of features only — graph-independent labels
            let mut w = vec![0f32; d * c_out];
            for x in w.iter_mut() {
                *x = rng.normal_f32();
            }
            let mut data = vec![0f32; n * c_out];
            for v in 0..n {
                for j in 0..c_out {
                    let s: f32 =
                        (0..d).map(|i| features[v * d + i] * w[i * c_out + j]).sum();
                    let mut on = s > 0.0;
                    if rng.bernoulli(cfg.label_noise) {
                        on = !on;
                    }
                    data[v * c_out + j] = if on { 1.0 } else { 0.0 };
                }
            }
            Labels::MultiLabel { data, c: c_out }
        }
    };

    let splits = Splits::random(n, cfg.train_frac, cfg.val_frac, &mut rng);
    Dataset {
        name: cfg.name.clone(),
        graph,
        features,
        d,
        labels,
        splits,
    }
}

/// Convenience: generate a named analog.
pub fn by_name(name: &str, seed: u64) -> Option<Dataset> {
    SynthConfig::by_name(name).map(|cfg| generate(&cfg, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dataset_shape() {
        let ds = by_name("tiny", 0).unwrap();
        assert_eq!(ds.n(), 300);
        assert_eq!(ds.d, 16);
        assert_eq!(ds.c(), 4);
        assert_eq!(ds.features.len(), 300 * 16);
        assert!(ds.graph.avg_degree() > 3.0 && ds.graph.avg_degree() < 12.0);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = by_name("tiny", 7).unwrap();
        let b = by_name("tiny", 7).unwrap();
        assert_eq!(a.graph.indices, b.graph.indices);
        assert_eq!(a.features, b.features);
        let c = by_name("tiny", 8).unwrap();
        assert_ne!(a.graph.indices, c.graph.indices);
    }

    #[test]
    fn coupled_homophily_is_respected() {
        let mut cfg = SynthConfig::by_name("tiny").unwrap();
        cfg.n = 2000;
        cfg.homophily = 0.9;
        let ds = generate(&cfg, 1);
        let labels = match &ds.labels {
            Labels::MultiClass(y) => y.clone(),
            _ => unreachable!(),
        };
        let g = &ds.graph;
        let (mut same, mut total) = (0usize, 0usize);
        for v in 0..g.n as u32 {
            for &u in g.neighbors(v) {
                if u > v {
                    total += 1;
                    if labels[u as usize] == labels[v as usize] {
                        same += 1;
                    }
                }
            }
        }
        let frac = same as f64 / total as f64;
        assert!(frac > 0.8, "coupled homophily frac={frac}");
    }

    #[test]
    fn all_presets_generate() {
        for name in SynthConfig::all_names() {
            let cfg = SynthConfig::by_name(name).unwrap();
            let mut small = cfg.clone();
            small.n = 500.max(small.communities * 4);
            let ds = generate(&small, 3);
            assert_eq!(ds.d, cfg.d);
            assert!(ds.c() <= cfg.c);
            assert!(ds.graph.num_edges() > 0);
            match &ds.labels {
                Labels::MultiClass(y) => assert_eq!(y.len(), small.n),
                Labels::MultiLabel { data, c } => assert_eq!(data.len(), small.n * c),
            }
        }
    }

    #[test]
    fn yelp_labels_ignore_structure() {
        let ds = by_name("yelp-s", 11).unwrap();
        if let Labels::MultiLabel { data, c } = &ds.labels {
            let pos: f64 =
                data.iter().map(|&x| x as f64).sum::<f64>() / (ds.n() * c) as f64;
            assert!((pos - 0.5).abs() < 0.1, "pos rate {pos}");
        } else {
            panic!("yelp-s should be multilabel");
        }
    }

    #[test]
    fn degree_skew_creates_heavy_tail() {
        let mut cfg = SynthConfig::by_name("tiny").unwrap();
        cfg.n = 3000;
        cfg.degree_skew = 1.2;
        let ds = generate(&cfg, 5);
        let max_deg = (0..3000u32).map(|v| ds.graph.degree(v)).max().unwrap();
        let avg = ds.graph.avg_degree();
        assert!(max_deg as f64 > 3.0 * avg, "max={max_deg} avg={avg}");
    }

    /// Nearest-class-mean classifier on mean-aggregated features — a
    /// model-free probe of how much label signal aggregation exposes.
    fn agg_probe_accuracy(ds: &Dataset, adj: &CsrGraph) -> f64 {
        let labels = match &ds.labels {
            Labels::MultiClass(y) => y,
            _ => unreachable!(),
        };
        let c = ds.c();
        let d = ds.d;
        let agg = |v: u32| -> Vec<f32> {
            let mut out = ds.feature(v).to_vec();
            let nbrs = adj.neighbors(v);
            for &u in nbrs {
                for (o, &x) in out.iter_mut().zip(ds.feature(u)) {
                    *o += x;
                }
            }
            let denom = (nbrs.len() + 1) as f32;
            out.iter_mut().for_each(|x| *x /= denom);
            out
        };
        // class means from train split
        let mut means = vec![0f32; c * d];
        let mut counts = vec![0f32; c];
        for &v in &ds.splits.train {
            let a = agg(v);
            let l = labels[v as usize] as usize;
            counts[l] += 1.0;
            for j in 0..d {
                means[l * d + j] += a[j];
            }
        }
        for l in 0..c {
            if counts[l] > 0.0 {
                for j in 0..d {
                    means[l * d + j] /= counts[l];
                }
            }
        }
        // nearest-mean on val split
        let mut correct = 0usize;
        for &v in &ds.splits.val {
            let a = agg(v);
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for l in 0..c {
                let dist: f32 = (0..d)
                    .map(|j| (a[j] - means[l * d + j]).powi(2))
                    .sum();
                if dist < best_d {
                    best_d = dist;
                    best = l;
                }
            }
            if best == labels[v as usize] as usize {
                correct += 1;
            }
        }
        correct as f64 / ds.splits.val.len() as f64
    }

    #[test]
    fn decoupled_label_signal_is_neighbor_borne_and_cut_sensitive() {
        // The core mechanism behind the PSGD-PA gap: full-graph aggregation
        // reveals the label; after a min-cut partition the induced views
        // don't. Probed with a model-free nearest-class-mean classifier.
        use crate::partition::{MultilevelPartitioner, Partitioner};
        let ds = by_name("tiny-hetero", 3).unwrap();
        let full_acc = agg_probe_accuracy(&ds, &ds.graph);
        assert!(full_acc > 0.6, "full-graph aggregation too weak: {full_acc}");

        let mut rng = Pcg64::new(4);
        let assign = MultilevelPartitioner::default().partition(&ds.graph, 4, &mut rng);
        // stitch per-part induced views into one adjacency (same ids)
        let mut indptr = vec![0usize; ds.n() + 1];
        let mut indices = Vec::new();
        let views: Vec<CsrGraph> =
            (0..4).map(|p| ds.graph.induced_view(&assign, p)).collect();
        for v in 0..ds.n() as u32 {
            let p = assign[v as usize] as usize;
            indices.extend_from_slice(views[p].neighbors(v));
            indptr[v as usize + 1] = indices.len();
        }
        let local = CsrGraph {
            n: ds.n(),
            indptr,
            indices,
        };
        let local_acc = agg_probe_accuracy(&ds, &local);
        // the 1-hop nearest-class-mean probe understates what a trained
        // 2-layer GNN extracts, so the margin here is conservative
        assert!(
            local_acc < full_acc - 0.05,
            "cut did not hurt: full={full_acc:.3} local={local_acc:.3}"
        );
    }
}
