//! Graph substrate: CSR storage, datasets (features/labels/splits), and the
//! partition-aware views the distributed algorithms train on.

pub mod generators;

use crate::util::Pcg64;

/// Compressed-sparse-row undirected graph. `indices[indptr[v]..indptr[v+1]]`
/// are the neighbors of `v`; edges are stored in both directions.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    pub n: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
}

impl CsrGraph {
    /// Build from an undirected edge list; symmetrizes, sorts, dedups, and
    /// drops self-loops (models add self-contributions explicitly).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> CsrGraph {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in edges {
            let (u, v) = (u as usize, v as usize);
            assert!(u < n && v < n, "edge ({u},{v}) out of range n={n}");
            if u == v {
                continue;
            }
            adj[u].push(v as u32);
            adj[v].push(u as u32);
        }
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        for list in adj.iter_mut() {
            list.sort_unstable();
            list.dedup();
            indices.extend_from_slice(list);
            indptr.push(indices.len());
        }
        CsrGraph { n, indptr, indices }
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.indices[self.indptr[v as usize]..self.indptr[v as usize + 1]]
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        self.indptr[v as usize + 1] - self.indptr[v as usize]
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.indices.len() as f64 / self.n as f64
        }
    }

    /// Count of undirected edges whose endpoints live in different parts.
    pub fn edge_cut(&self, assignment: &[u32]) -> usize {
        assert_eq!(assignment.len(), self.n);
        let mut cut = 0usize;
        for v in 0..self.n as u32 {
            for &u in self.neighbors(v) {
                if u > v && assignment[u as usize] != assignment[v as usize] {
                    cut += 1;
                }
            }
        }
        cut
    }

    /// Fraction of undirected edges that are cut by `assignment`.
    pub fn cut_ratio(&self, assignment: &[u32]) -> f64 {
        let e = self.num_edges();
        if e == 0 {
            0.0
        } else {
            self.edge_cut(assignment) as f64 / e as f64
        }
    }

    /// Induced-subgraph adjacency restricted to one part: neighbors of `v`
    /// that share `v`'s part. Returned as a new CSR over *global* ids, with
    /// non-member rows empty — exactly the "ignore cut-edges" view of Eq. 3.
    pub fn induced_view(&self, assignment: &[u32], part: u32) -> CsrGraph {
        assert_eq!(assignment.len(), self.n);
        let mut indptr = Vec::with_capacity(self.n + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        for v in 0..self.n as u32 {
            if assignment[v as usize] == part {
                for &u in self.neighbors(v) {
                    if assignment[u as usize] == part {
                        indices.push(u);
                    }
                }
            }
            indptr.push(indices.len());
        }
        CsrGraph {
            n: self.n,
            indptr,
            indices,
        }
    }

    /// Connected components (labels), for generator sanity checks.
    pub fn components(&self) -> Vec<u32> {
        let mut comp = vec![u32::MAX; self.n];
        let mut next = 0u32;
        let mut stack = Vec::new();
        for s in 0..self.n as u32 {
            if comp[s as usize] != u32::MAX {
                continue;
            }
            comp[s as usize] = next;
            stack.push(s);
            while let Some(v) = stack.pop() {
                for &u in self.neighbors(v) {
                    if comp[u as usize] == u32::MAX {
                        comp[u as usize] = next;
                        stack.push(u);
                    }
                }
            }
            next += 1;
        }
        comp
    }
}

/// Node labels: one class per node, or a multi-hot vector per node.
#[derive(Clone, Debug)]
pub enum Labels {
    /// `labels[v]` in `0..c`
    MultiClass(Vec<u16>),
    /// row-major `[n, c]` in {0.0, 1.0}
    MultiLabel { data: Vec<f32>, c: usize },
}

impl Labels {
    pub fn num_classes(&self) -> usize {
        match self {
            Labels::MultiClass(v) => (v.iter().copied().max().unwrap_or(0) + 1) as usize,
            Labels::MultiLabel { c, .. } => *c,
        }
    }

    pub fn is_multilabel(&self) -> bool {
        matches!(self, Labels::MultiLabel { .. })
    }
}

/// Train/val/test split masks.
#[derive(Clone, Debug)]
pub struct Splits {
    pub train: Vec<u32>,
    pub val: Vec<u32>,
    pub test: Vec<u32>,
}

impl Splits {
    /// Random split by fraction; remainder goes to test.
    pub fn random(n: usize, train_frac: f64, val_frac: f64, rng: &mut Pcg64) -> Splits {
        let mut ids: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut ids);
        let nt = ((n as f64) * train_frac).round() as usize;
        let nv = ((n as f64) * val_frac).round() as usize;
        Splits {
            train: ids[..nt].to_vec(),
            val: ids[nt..(nt + nv).min(n)].to_vec(),
            test: ids[(nt + nv).min(n)..].to_vec(),
        }
    }
}

/// A complete node-classification dataset: graph + features + labels + split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub graph: CsrGraph,
    /// row-major `[n, d]`
    pub features: Vec<f32>,
    pub d: usize,
    pub labels: Labels,
    pub splits: Splits,
}

impl Dataset {
    #[inline]
    pub fn feature(&self, v: u32) -> &[f32] {
        let v = v as usize;
        &self.features[v * self.d..(v + 1) * self.d]
    }

    pub fn n(&self) -> usize {
        self.graph.n
    }

    pub fn c(&self) -> usize {
        self.labels.num_classes()
    }

    /// Table-2-style statistics row.
    pub fn stats(&self) -> String {
        format!(
            "{:<12} nodes={:<8} edges={:<9} d={:<4} c={:<3} train/val/test={}/{}/{} avg_deg={:.1}",
            self.name,
            self.n(),
            self.graph.num_edges(),
            self.d,
            self.c(),
            self.splits.train.len(),
            self.splits.val.len(),
            self.splits.test.len(),
            self.graph.avg_degree()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> CsrGraph {
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        CsrGraph::from_edges(n, &edges)
    }

    #[test]
    fn csr_basics() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 1), (3, 3)]);
        assert_eq!(g.num_edges(), 3); // dedup + self-loop dropped
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn symmetry() {
        let g = CsrGraph::from_edges(5, &[(0, 3), (3, 4), (1, 2)]);
        for v in 0..5u32 {
            for &u in g.neighbors(v) {
                assert!(g.neighbors(u).contains(&v), "asymmetric edge {v}-{u}");
            }
        }
    }

    #[test]
    fn edge_cut_counts() {
        let g = path_graph(4); // 0-1-2-3
        assert_eq!(g.edge_cut(&[0, 0, 1, 1]), 1);
        assert_eq!(g.edge_cut(&[0, 1, 0, 1]), 3);
        assert_eq!(g.edge_cut(&[0, 0, 0, 0]), 0);
        assert!((g.cut_ratio(&[0, 0, 1, 1]) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn induced_view_drops_cut_edges() {
        let g = path_graph(4);
        let view = g.induced_view(&[0, 0, 1, 1], 0);
        assert_eq!(view.neighbors(0), &[1]);
        assert_eq!(view.neighbors(1), &[0]); // edge 1-2 is cut
        assert_eq!(view.neighbors(2), &[] as &[u32]); // not a member
        let view1 = g.induced_view(&[0, 0, 1, 1], 1);
        assert_eq!(view1.neighbors(2), &[3]);
    }

    #[test]
    fn components_on_disconnected() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (2, 3)]);
        let c = g.components();
        assert_eq!(c[0], c[1]);
        assert_eq!(c[2], c[3]);
        assert_ne!(c[0], c[2]);
        assert_ne!(c[4], c[0]);
        assert_ne!(c[4], c[2]);
    }

    #[test]
    fn splits_are_disjoint_and_cover() {
        let mut rng = Pcg64::new(1);
        let s = Splits::random(100, 0.6, 0.2, &mut rng);
        assert_eq!(s.train.len(), 60);
        assert_eq!(s.val.len(), 20);
        assert_eq!(s.test.len(), 20);
        let mut all: Vec<u32> = s
            .train
            .iter()
            .chain(&s.val)
            .chain(&s.test)
            .copied()
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }
}
