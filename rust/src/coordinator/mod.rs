//! L3 coordinator — the paper's system contribution.
//!
//! Implements Algorithm 1 (PSGD-PA) and Algorithm 2 (LLCG) plus the GGS,
//! FullSync and SubgraphApprox baselines over the PJRT runtime:
//!
//! ```text
//! round r:                                  bytes accounted
//!   server ──params──▶ each worker          P · |θ|          (download)
//!   worker p: K·ρ^r local steps on its      (GGS: + remote-feature bytes
//!             partition (cut-edges dropped)  per mini-batch)
//!   worker ──params──▶ server               P · |θ|          (upload)
//!   server: θ̄ = mean(θ_p)                                    (Alg 2 l.12)
//!   server: S correction steps on the       —                (Alg 2 l.13-18)
//!           full graph, full neighbors
//! ```

pub mod discrepancy;
pub mod driver;

pub use driver::{run_experiment, PartInfo, RoundRecord, RunResult};

use crate::util::Pcg64;

/// Distributed training algorithm (DESIGN.md experiment index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Alg. 1: parallel SGD + periodic parameter averaging, cut-edges
    /// ignored — suffers the irreducible O(κ² + σ²_bias) residual (Thm 1).
    PsgdPa,
    /// Alg. 2: PSGD-PA + exponential local epochs + global server correction.
    Llcg,
    /// Global Graph Sampling: workers sample the *full* graph; features of
    /// remote (cut-edge) nodes are transferred and accounted per batch.
    Ggs,
    /// Fully synchronous baseline: GGS with K=1 (sync every step) — the
    /// "single machine equivalent" upper line of Fig 11.
    FullSync,
    /// Angerd et al. subgraph-approximation baseline: each worker stores a
    /// sampled extra subgraph (≈10% storage) of remote nodes (Fig 11).
    SubgraphApprox,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "psgd-pa" | "psgdpa" | "psgd" => Some(Algorithm::PsgdPa),
            "llcg" => Some(Algorithm::Llcg),
            "ggs" => Some(Algorithm::Ggs),
            "full-sync" | "fullsync" => Some(Algorithm::FullSync),
            "subgraph-approx" | "subgraph" => Some(Algorithm::SubgraphApprox),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::PsgdPa => "psgd-pa",
            Algorithm::Llcg => "llcg",
            Algorithm::Ggs => "ggs",
            Algorithm::FullSync => "full-sync",
            Algorithm::SubgraphApprox => "subgraph-approx",
        }
    }

    /// Does this algorithm train on the full (global) adjacency?
    pub fn uses_global_view(&self) -> bool {
        matches!(self, Algorithm::Ggs | Algorithm::FullSync)
    }

    /// Does this algorithm run server correction steps?
    pub fn corrects(&self) -> bool {
        matches!(self, Algorithm::Llcg)
    }
}

/// Local-epoch schedule (Alg. 2 line 4: `K·ρ^r` steps in round `r`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Fixed { k: usize },
    Exponential { k0: usize, rho: f64 },
}

impl Schedule {
    /// Local steps for 1-indexed round `r`; capped to keep runs bounded.
    pub fn steps_for_round(&self, r: usize) -> usize {
        match *self {
            Schedule::Fixed { k } => k.max(1),
            Schedule::Exponential { k0, rho } => {
                let steps = (k0 as f64) * rho.powi(r as i32 - 1);
                (steps.round() as usize).clamp(1, 4096)
            }
        }
    }

    /// Total local steps over `rounds` rounds (T in the paper).
    pub fn total_steps(&self, rounds: usize) -> usize {
        (1..=rounds).map(|r| self.steps_for_round(r)).sum()
    }
}

/// Server-correction mini-batch selection (Appendix A.3 / Fig 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorrectionBatch {
    /// uniform over the global training set — unbiased (the default)
    Uniform,
    /// prefer endpoints of cut-edges — the biased variant the appendix
    /// shows does *not* help
    MaxCutEdges,
}

/// Per-round communication accounting (bytes).
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// server -> workers parameter broadcast
    pub down_bytes: u64,
    /// workers -> server parameter upload
    pub up_bytes: u64,
    /// node-feature transfer (GGS / SubgraphApprox storage)
    pub feature_bytes: u64,
}

impl CommStats {
    pub fn total(&self) -> u64 {
        self.down_bytes + self.up_bytes + self.feature_bytes
    }
}

/// Deterministic per-(run, worker, round) RNG derivation.
pub fn worker_rng(seed: u64, part: usize, round: usize) -> Pcg64 {
    let mut root = Pcg64::new(seed);
    let mut stream = root.split(0x1000 + part as u64);
    stream.split(round as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_fixed() {
        let s = Schedule::Fixed { k: 4 };
        assert_eq!(s.steps_for_round(1), 4);
        assert_eq!(s.steps_for_round(100), 4);
        assert_eq!(s.total_steps(10), 40);
    }

    #[test]
    fn schedule_exponential_grows() {
        let s = Schedule::Exponential { k0: 4, rho: 1.1 };
        assert_eq!(s.steps_for_round(1), 4);
        let k10 = s.steps_for_round(10);
        let k20 = s.steps_for_round(20);
        assert!(k10 > 4 && k20 > k10, "k10={k10} k20={k20}");
        // R = log_rho(T/K): total steps grow geometrically
        assert!(s.total_steps(20) > 20 * 4);
    }

    #[test]
    fn schedule_exponential_caps() {
        let s = Schedule::Exponential { k0: 64, rho: 2.0 };
        assert_eq!(s.steps_for_round(30), 4096);
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in [
            Algorithm::PsgdPa,
            Algorithm::Llcg,
            Algorithm::Ggs,
            Algorithm::FullSync,
            Algorithm::SubgraphApprox,
        ] {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("LLCG"), Some(Algorithm::Llcg));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn algorithm_properties() {
        assert!(Algorithm::Ggs.uses_global_view());
        assert!(!Algorithm::PsgdPa.uses_global_view());
        assert!(Algorithm::Llcg.corrects());
        assert!(!Algorithm::Ggs.corrects());
    }

    #[test]
    fn comm_stats_total() {
        let c = CommStats {
            down_bytes: 10,
            up_bytes: 20,
            feature_bytes: 5,
        };
        assert_eq!(c.total(), 35);
    }

    #[test]
    fn worker_rngs_are_decorrelated() {
        let mut a = worker_rng(1, 0, 0);
        let mut b = worker_rng(1, 1, 0);
        let mut c = worker_rng(1, 0, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(va, vb);
        assert_ne!(va, vc);
        // but deterministic
        let mut a2 = worker_rng(1, 0, 0);
        let va2: Vec<u64> = (0..8).map(|_| a2.next_u64()).collect();
        assert_eq!(va, va2);
    }
}
