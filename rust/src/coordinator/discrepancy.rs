//! Empirical measurement of the paper's theoretical quantities:
//! the local-global gradient discrepancy κ² (§4.1) and the neighbor-sampling
//! bias σ²_bias — the two terms that make the PSGD-PA residual irreducible
//! (Thm 1) and that size the correction-step count S (Thm 2).
//!
//! Gradients are extracted through the SGD artifact: one SGD step with
//! learning rate ε gives  g = (θ − θ') / ε  without a dedicated grad
//! entry point.

use anyhow::Result;

use crate::graph::Dataset;
use crate::runtime::{ModelState, Runtime, Tensor};
use crate::sampler::{BlockBuilder, Fanout};
use crate::util::Pcg64;

const EPS: f32 = 1e-3;

/// Gradient of the loss at `params` on mini-batches drawn from `ids` with
/// adjacency `adj`, averaged over `batches` batches (flattened).
#[allow(clippy::too_many_arguments)]
pub fn estimate_gradient(
    rt: &Runtime,
    sgd_name: &str,
    params: &[Tensor],
    ds: &Dataset,
    adj: &crate::graph::CsrGraph,
    ids: &[u32],
    builder: &BlockBuilder,
    batches: usize,
    rng: &mut Pcg64,
) -> Result<Vec<f32>> {
    let total: usize = params.iter().map(|p| p.numel()).sum();
    let mut grad = vec![0f32; total];
    let meta = rt.meta(sgd_name)?.clone();
    for _ in 0..batches {
        let batch = rng.sample_without_replacement(ids, meta.dims.b);
        if batch.is_empty() {
            continue;
        }
        let blk = builder.build(&batch, adj, ds, rng);
        let mut state = ModelState {
            params: params.to_vec(),
            opt: vec![],
        };
        rt.train_step(sgd_name, &mut state, &blk, EPS)?;
        let mut off = 0usize;
        for (p_new, p_old) in state.params.iter().zip(params) {
            for (g, (&pn, &po)) in grad[off..off + p_old.numel()]
                .iter_mut()
                .zip(p_new.data.iter().zip(&p_old.data))
            {
                *g += (po - pn) / EPS / batches as f32;
            }
            off += p_old.numel();
        }
    }
    Ok(grad)
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64) * ((x - y) as f64))
        .sum()
}

/// Measured discrepancy report.
#[derive(Clone, Debug)]
pub struct Discrepancy {
    /// max_p ‖∇L_p^local − ∇L_p^full‖² — cut-edge structure term κ_A²
    pub kappa_a: f64,
    /// max_p ‖∇L_p^full − ∇L‖² — feature/label heterogeneity term κ_X²
    pub kappa_x: f64,
    /// ‖∇̃ (sampled) − ∇ (full-neighbor)‖² on the global graph — σ²_bias proxy
    pub sigma_bias: f64,
}

impl Discrepancy {
    pub fn kappa(&self) -> f64 {
        self.kappa_a + self.kappa_x
    }
}

/// Measure κ_A², κ_X², σ²_bias at `params` for a given partition.
#[allow(clippy::too_many_arguments)]
pub fn measure(
    rt: &Runtime,
    arch: &str,
    dataset: &str,
    params: &[Tensor],
    ds: &Dataset,
    assignment: &[u32],
    parts: usize,
    batches: usize,
    seed: u64,
) -> Result<Discrepancy> {
    let sgd_name = Runtime::train_name(arch, "sgd", dataset);
    let meta = rt.meta(&sgd_name)?.clone();
    let mut builder = BlockBuilder::new(
        meta.dims.b,
        meta.dims.f1,
        meta.dims.f2,
        meta.dims.d,
        meta.dims.c,
        meta.multilabel(),
    );
    builder.fanout = Fanout::Full; // full-neighbor gradients for κ terms
    let mut rng = Pcg64::new(seed);

    // global full-neighbor gradient
    let g_global = estimate_gradient(
        rt,
        &sgd_name,
        params,
        ds,
        &ds.graph,
        &ds.splits.train,
        &builder,
        batches,
        &mut rng.split(0),
    )?;

    let mut kappa_a = 0f64;
    let mut kappa_x = 0f64;
    for p in 0..parts as u32 {
        let ids: Vec<u32> = ds
            .splits
            .train
            .iter()
            .copied()
            .filter(|&v| assignment[v as usize] == p)
            .collect();
        if ids.is_empty() {
            continue;
        }
        let local_adj = ds.graph.induced_view(assignment, p);
        // ∇L_p^local: local nodes, local adjacency (cut-edges dropped)
        let g_local = estimate_gradient(
            rt, &sgd_name, params, ds, &local_adj, &ids, &builder, batches,
            &mut rng.split(1 + p as u64),
        )?;
        // ∇L_p^full: local nodes, FULL adjacency (Eq. 5)
        let g_full = estimate_gradient(
            rt, &sgd_name, params, ds, &ds.graph, &ids, &builder, batches,
            &mut rng.split(101 + p as u64),
        )?;
        kappa_a = kappa_a.max(sq_dist(&g_local, &g_full));
        kappa_x = kappa_x.max(sq_dist(&g_full, &g_global));
    }

    // σ²_bias: neighbor-sampled vs full-neighbor gradient on the full graph
    let mut sampled_builder = builder.clone();
    sampled_builder.fanout = Fanout::Sample;
    sampled_builder.sample_ratio = 0.5;
    let g_sampled = estimate_gradient(
        rt,
        &sgd_name,
        params,
        ds,
        &ds.graph,
        &ds.splits.train,
        &sampled_builder,
        batches,
        &mut rng.split(999),
    )?;
    let sigma_bias = sq_dist(&g_sampled, &g_global);

    Ok(Discrepancy {
        kappa_a,
        kappa_x,
        sigma_bias,
    })
}
