//! The distributed-training driver: partitions the graph, sets up workers
//! and the parameter server, and runs the round loop of Algorithm 1/2.
//!
//! [`run_experiment`] is a thin front-end over two execution engines that
//! share all of the setup, per-worker round, correction, and eval code in
//! this module (so their numerics cannot drift):
//!
//! - **sequential** ([`run_sequential`], the default) — every worker runs
//!   on the caller's thread against the shared `Runtime`. This is the only
//!   engine that works on the PJRT backend (the `xla` client is not
//!   `Send`), and it is what the paper itself does (Section 5, "Real-world
//!   simulation"): report *communication rounds and bytes*, with the
//!   simulated-parallel round time back-computed as `max_p(worker time)`.
//! - **cluster** ([`crate::cluster`]) — one OS thread per worker plus a
//!   parameter-server loop, typed message channels, and a modeled network;
//!   sync mode reproduces this driver's per-round losses/bytes bit-for-bit
//!   while actually measuring overlap, stragglers, and pipelining.
//!
//! Either way, every round also passes its byte counters through the run's
//! [`NetModel`], so `RoundRecord` carries modeled network time next to the
//! measured wall-clock.

use anyhow::{bail, Result};

use super::{Algorithm, CommStats, CorrectionBatch};
use crate::api::registry;
use crate::api::session::{Event, RunControl, RunCtx};
use crate::cluster::checkpoint::Checkpoint;
use crate::cluster::{net, Engine, NetModel, RoundMode};
use crate::config::ExperimentConfig;
use crate::graph::{CsrGraph, Dataset, Labels};
#[cfg(test)]
use crate::graph::generators;
use crate::metrics;
use crate::runtime::{Dims, ModelState, Runtime, Tensor};
use crate::sampler::{BatchIter, BlockArena, BlockBuilder, Fanout, NodeScratch};
use crate::util::{Json, Pcg64};

/// One worker's static setup.
pub struct PartInfo {
    pub part: u32,
    /// adjacency this worker trains on (induced / global / augmented)
    pub adj: CsrGraph,
    /// training nodes owned by this worker
    pub train_ids: Vec<u32>,
    /// one-time feature-storage bytes (SubgraphApprox)
    pub storage_bytes: u64,
}

/// Server-phase wall breakdown for one round, attributed to the round that
/// ran the phase: eval on an `eval_every` cadence lands in the round that
/// triggered it (asserted by the event-parity test in `tests/obs.rs`), and
/// `avg_s + corr_s + eval_s` accounts for `server_time_s` up to the
/// epilogue's own bookkeeping.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    /// parameter averaging (async engine: the window's accumulated folds)
    pub avg_s: f64,
    /// server correction steps (0 when the algorithm has none; pipelined
    /// mode runs correction overlapped and reports the delta-apply time)
    pub corr_s: f64,
    /// round-boundary evaluation (0 on non-eval rounds)
    pub eval_s: f64,
}

/// Per-round measurements — one row of every figure in the paper.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub local_steps: usize,
    /// mean local training loss across workers this round
    pub local_loss: f64,
    /// loss of the (corrected) global model on a global train sample
    pub global_loss: f64,
    /// validation score of the (corrected) global model (F1 or ROC-AUC)
    pub val_score: f64,
    pub comm: CommStats,
    /// cumulative bytes including this round
    pub cum_bytes: u64,
    /// parallel worker time: max over workers (measured; sequential engine
    /// runs workers one after another and takes the max)
    pub worker_time_s: f64,
    /// server averaging + correction + eval time (pipelined mode: the
    /// overlapped correction is excluded — see `cluster` docs)
    pub server_time_s: f64,
    /// modeled network time on the round's critical path: the slowest
    /// worker's link time under the run's `NetModel`
    pub net_time_s: f64,
    /// measured end-to-end wall-clock of the round on the server
    pub wall_time_s: f64,
    /// where `server_time_s` went: averaging / correction / eval, each
    /// attributed to the round that ran it
    pub phases: PhaseTimes,
    /// messages lost this round (injected drops + discarded stale params)
    pub drops: u64,
    /// workers respawned at the start of this round
    pub respawns: u32,
    /// param sets averaged into the global model this round (= P when
    /// every worker contributed; fewer under quorum rounds / dead workers)
    pub quorum: usize,
    /// measured bytes written to worker sockets this round (remote
    /// transports only; zero in-process, where `net_time_s` models the link)
    pub wire_bytes_down: u64,
    /// measured bytes read from worker sockets this round (remote only)
    pub wire_bytes_up: u64,
}

/// Complete result of one distributed run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub algorithm: Algorithm,
    pub dataset: String,
    pub arch: String,
    pub parts: usize,
    /// execution engine that produced this result ("sequential" | "cluster")
    pub engine: &'static str,
    /// worker wire under the cluster engine ("inprocess" | "tcp" | "uds")
    pub transport: String,
    pub records: Vec<RoundRecord>,
    pub final_val: f64,
    pub final_test: f64,
    pub cut_ratio: f64,
    /// avg bytes communicated per round
    pub avg_round_bytes: f64,
    pub total_steps: usize,
    /// max observed round-staleness (async-staleness mode only)
    pub max_staleness: Option<u64>,
    /// messages lost over the whole run (fault injection)
    pub total_drops: u64,
    /// workers respawned over the whole run
    pub total_respawns: u32,
}

impl RoundRecord {
    /// One JSON row, shared by `RunResult::to_json` and the `--log-json`
    /// event stream (so both shapes change together, under one `schema`
    /// version).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("local_steps", Json::num(self.local_steps as f64)),
            ("local_loss", Json::num(self.local_loss)),
            ("global_loss", Json::num(self.global_loss)),
            ("val_score", Json::num(self.val_score)),
            ("bytes", Json::num(self.comm.total() as f64)),
            ("cum_bytes", Json::num(self.cum_bytes as f64)),
            ("worker_time_s", Json::num(self.worker_time_s)),
            ("server_time_s", Json::num(self.server_time_s)),
            ("net_time_s", Json::num(self.net_time_s)),
            ("wall_time_s", Json::num(self.wall_time_s)),
            ("avg_time_s", Json::num(self.phases.avg_s)),
            ("corr_time_s", Json::num(self.phases.corr_s)),
            ("eval_time_s", Json::num(self.phases.eval_s)),
            ("drops", Json::num(self.drops as f64)),
            ("respawns", Json::num(self.respawns as f64)),
            ("quorum", Json::num(self.quorum as f64)),
            ("wire_bytes_down", Json::num(self.wire_bytes_down as f64)),
            ("wire_bytes_up", Json::num(self.wire_bytes_up as f64)),
        ])
    }
}

impl RunResult {
    pub fn avg_round_mb(&self) -> f64 {
        self.avg_round_bytes / 1e6
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::num(crate::obs::SCHEMA_VERSION as f64)),
            ("algorithm", Json::str(self.algorithm.name())),
            ("dataset", Json::str(&self.dataset)),
            ("arch", Json::str(&self.arch)),
            ("parts", Json::num(self.parts as f64)),
            ("engine", Json::str(self.engine)),
            ("transport", Json::str(&self.transport)),
            ("final_val", Json::num(self.final_val)),
            ("final_test", Json::num(self.final_test)),
            ("cut_ratio", Json::num(self.cut_ratio)),
            ("avg_round_mb", Json::num(self.avg_round_mb())),
            ("total_steps", Json::num(self.total_steps as f64)),
            ("total_drops", Json::num(self.total_drops as f64)),
            ("total_respawns", Json::num(self.total_respawns as f64)),
            (
                "rounds",
                Json::arr(self.records.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

/// Build each worker's adjacency view + train-node ownership.
pub fn build_parts(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    assignment: &[u32],
    rng: &mut Pcg64,
) -> Vec<PartInfo> {
    let mut parts = Vec::with_capacity(cfg.parts);
    for p in 0..cfg.parts as u32 {
        let train_ids: Vec<u32> = ds
            .splits
            .train
            .iter()
            .copied()
            .filter(|&v| assignment[v as usize] == p)
            .collect();
        let (adj, storage_bytes) = match cfg.algorithm {
            Algorithm::Ggs | Algorithm::FullSync => (ds.graph.clone(), 0),
            Algorithm::SubgraphApprox => {
                build_approx_view(ds, assignment, p, cfg.approx_storage, rng)
            }
            Algorithm::PsgdPa | Algorithm::Llcg => {
                (ds.graph.induced_view(assignment, p), 0)
            }
        };
        parts.push(PartInfo {
            part: p,
            adj,
            train_ids,
            storage_bytes,
        });
    }
    parts
}

/// SubgraphApprox (Angerd et al.): store a sampled `storage` fraction of
/// remote nodes; the worker's adjacency is the subgraph induced by
/// (members ∪ stored remotes). Storage features are a one-time transfer.
fn build_approx_view(
    ds: &Dataset,
    assignment: &[u32],
    part: u32,
    storage: f64,
    rng: &mut Pcg64,
) -> (CsrGraph, u64) {
    let n = ds.n();
    let members: Vec<u32> = (0..n as u32)
        .filter(|&v| assignment[v as usize] == part)
        .collect();
    let remotes: Vec<u32> = (0..n as u32)
        .filter(|&v| assignment[v as usize] != part)
        .collect();
    let extra = ((members.len() as f64) * storage).round() as usize;
    let stored = rng.sample_without_replacement(&remotes, extra);
    let mut keep = vec![false; n];
    for &v in members.iter().chain(&stored) {
        keep[v as usize] = true;
    }
    let mut indptr = Vec::with_capacity(n + 1);
    indptr.push(0usize);
    let mut indices = Vec::new();
    for v in 0..n as u32 {
        if keep[v as usize] {
            for &u in ds.graph.neighbors(v) {
                if keep[u as usize] {
                    indices.push(u);
                }
            }
        }
        indptr.push(indices.len());
    }
    let bytes = (stored.len() * ds.d * 4) as u64;
    (
        CsrGraph {
            n,
            indptr,
            indices,
        },
        bytes,
    )
}

/// Pick the correction mini-batch (Fig 9): uniform over global training
/// nodes, or biased toward endpoints of cut edges.
fn correction_batch(
    batch_kind: CorrectionBatch,
    ds: &Dataset,
    assignment: &[u32],
    b: usize,
    rng: &mut Pcg64,
) -> Vec<u32> {
    match batch_kind {
        CorrectionBatch::Uniform => rng.sample_without_replacement(&ds.splits.train, b),
        CorrectionBatch::MaxCutEdges => {
            let mut cut_nodes: Vec<u32> = Vec::new();
            for v in 0..ds.n() as u32 {
                if ds
                    .graph
                    .neighbors(v)
                    .iter()
                    .any(|&u| assignment[u as usize] != assignment[v as usize])
                {
                    cut_nodes.push(v);
                }
            }
            let train_set: std::collections::HashSet<u32> =
                ds.splits.train.iter().copied().collect();
            let cut_train: Vec<u32> = cut_nodes
                .into_iter()
                .filter(|v| train_set.contains(v))
                .collect();
            if cut_train.len() >= b {
                rng.sample_without_replacement(&cut_train, b)
            } else {
                let mut batch = cut_train;
                let rest: Vec<u32> = ds
                    .splits
                    .train
                    .iter()
                    .copied()
                    .filter(|v| !batch.contains(v))
                    .collect();
                batch.extend(rng.sample_without_replacement(&rest, b - batch.len()));
                batch
            }
        }
    }
}

/// Evaluate `params` on `ids` (chunked, full-neighbor blocks on the full
/// graph); returns logits in `ids` order. Parameters are uploaded to the
/// device once for the whole sweep and block buffers are arena-recycled
/// across chunks.
///
/// This is the full-logits path, retained for ROC-AUC datasets (the rank
/// statistic needs every score) and as the reference for
/// [`eval_split`]'s device-side reductions.
pub fn eval_logits(
    rt: &Runtime,
    eval_name: &str,
    params: &[Tensor],
    ds: &Dataset,
    ids: &[u32],
    builder: &BlockBuilder,
    rng: &mut Pcg64,
) -> Result<Vec<f32>> {
    let meta = rt.meta(eval_name)?.clone();
    let c = meta.dims.c;
    let mut full_builder = builder.clone();
    full_builder.fanout = Fanout::Full;
    full_builder.sample_ratio = 1.0;
    let mut dev = rt.upload_params(eval_name, params)?;
    let mut arena = BlockArena::new();
    let mut logits = Vec::with_capacity(ids.len() * c);
    for chunk in ids.chunks(meta.dims.b) {
        let blk = full_builder.build_into(&mut arena, chunk, &ds.graph, ds, rng);
        let out = rt.eval_step_device(&mut dev, blk)?;
        logits.extend_from_slice(&out[..chunk.len() * c]);
    }
    Ok(logits)
}

/// The metric-selection rule, in one place: proteins-style datasets report
/// ROC-AUC (paper Table 2), everything else micro-F1. Both [`score`] and
/// [`eval_split`]'s fast-path gate consult this single predicate.
pub fn scored_by_auc(ds: &Dataset) -> bool {
    ds.name.starts_with("proteins")
}

/// Score = ROC-AUC for multilabel-AUC datasets (proteins), micro-F1 otherwise.
pub fn score(ds: &Dataset, logits: &[f32], c: usize, ids: &[u32]) -> f64 {
    if scored_by_auc(ds) {
        metrics::roc_auc(logits, c, &ds.labels, ids)
    } else {
        metrics::micro_f1(logits, c, &ds.labels, ids)
    }
}

/// Evaluate `params` on `ids` without downloading logits: every chunk is
/// reduced device-side by [`Runtime::eval_scores_device`] to per-row
/// predictions + losses, and this function only folds those `O(b)` values.
/// Returns `(score, mean_loss)` **bit-identical** to
/// `score(eval_logits(..))` / `metrics::mean_loss(eval_logits(..))`: the
/// reductions use the same formulas and the same id-order f64 accumulation.
/// ROC-AUC datasets (and `c > 64`) fall back to the full-logits path;
/// there, `need_score: false` (loss-only callers) skips the rank-statistic
/// sort entirely and returns NaN for the score.
#[allow(clippy::too_many_arguments)]
pub fn eval_split(
    rt: &Runtime,
    eval_name: &str,
    params: &[Tensor],
    ds: &Dataset,
    ids: &[u32],
    builder: &BlockBuilder,
    rng: &mut Pcg64,
    need_score: bool,
) -> Result<(f64, f64)> {
    let meta = rt.meta(eval_name)?.clone();
    let c = meta.dims.c;
    if scored_by_auc(ds) || c > 64 {
        let logits = eval_logits(rt, eval_name, params, ds, ids, builder, rng)?;
        let split_score = if need_score {
            score(ds, &logits, c, ids)
        } else {
            f64::NAN
        };
        return Ok((split_score, metrics::mean_loss(&logits, c, &ds.labels, ids)));
    }
    let mut full_builder = builder.clone();
    full_builder.fanout = Fanout::Full;
    full_builder.sample_ratio = 1.0;
    let mut dev = rt.upload_params(eval_name, params)?;
    let mut arena = BlockArena::new();
    let mut correct = 0usize;
    let mut f1 = metrics::MicroF1::default();
    let mut loss_total = 0f64;
    for chunk in ids.chunks(meta.dims.b) {
        let blk = full_builder.build_into(&mut arena, chunk, &ds.graph, ds, rng);
        let s = rt.eval_scores_device(&mut dev, blk)?;
        for (i, &v) in chunk.iter().enumerate() {
            loss_total += s.loss[i];
            match &ds.labels {
                Labels::MultiClass(y) => {
                    if s.pred[i] == y[v as usize] as u32 {
                        correct += 1;
                    }
                }
                Labels::MultiLabel { data, c: dc } => {
                    for j in 0..c {
                        let pred = ((s.pos_bits[i] >> j) & 1) == 1;
                        let truth = data[v as usize * dc + j] > 0.5;
                        f1.add(pred, truth);
                    }
                }
            }
        }
    }
    let n = ids.len();
    let split_score = if n == 0 {
        0.0
    } else {
        match &ds.labels {
            Labels::MultiClass(_) => correct as f64 / n as f64,
            Labels::MultiLabel { .. } => f1.value(),
        }
    };
    let mean_loss = if n == 0 { 0.0 } else { loss_total / n as f64 };
    Ok((split_score, mean_loss))
}

/// Everything both engines need, derived from `(cfg, ds, rt)` with one RNG
/// stream discipline. Centralizing this is what makes the cluster engine's
/// sync mode bit-compatible with the sequential driver: there is a single
/// place that draws the partition/init/eval/correction streams, in a fixed
/// order.
pub(crate) struct RunSetup {
    pub train_name: String,
    pub server_train_name: String,
    pub eval_name: String,
    pub dims: Dims,
    pub assignment: Vec<u32>,
    pub cut_ratio: f64,
    pub parts: Vec<PartInfo>,
    /// one per-worker state, all starting from the same global init (their
    /// optimizer state stays local across rounds, like FedAvg+Adam)
    pub workers: Vec<ModelState>,
    pub global_params: Vec<Tensor>,
    /// server correction state (its optimizer state persists across rounds)
    pub server_state: ModelState,
    pub local_builder: BlockBuilder,
    pub corr_builder: BlockBuilder,
    pub param_bytes: u64,
    pub eval_rng: Pcg64,
    pub corr_rng: Pcg64,
    pub net: NetModel,
}

/// Shared prologue: artifacts, partition, states, builders, RNG streams.
/// `pre_assignment` short-circuits the partitioner with an already-computed
/// assignment (sweep reuse); it must equal what this run's
/// `(seed, partitioner, parts)` would produce, and the partition RNG
/// stream is still burned so every downstream stream stays bit-identical.
pub(crate) fn setup_run(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    rt: &Runtime,
    pre_assignment: Option<&[u32]>,
) -> Result<RunSetup> {
    let mut root_rng = Pcg64::new(cfg.seed);

    // --- artifacts --------------------------------------------------------
    let train_name = Runtime::train_name(&cfg.arch, &cfg.optimizer, &cfg.dataset);
    let server_train_name =
        Runtime::train_name(&cfg.arch, &cfg.server_optimizer, &cfg.dataset);
    let eval_name = Runtime::eval_name(&cfg.arch, &cfg.dataset);
    let meta = rt.meta(&train_name)?.clone();
    let dims = meta.dims;
    if dims.d != ds.d {
        bail!(
            "dataset {} has d={} but artifact {} expects d={}",
            ds.name, ds.d, train_name, dims.d
        );
    }

    // --- partition ---------------------------------------------------------
    let assignment = if cfg.parts <= 1 {
        vec![0u32; ds.n()]
    } else if let Some(pre) = pre_assignment {
        let _ = root_rng.split(1); // burn the partition stream
        pre.to_vec()
    } else {
        let p = registry::build_partitioner(&cfg.partitioner)
            .map_err(|e| anyhow::anyhow!(e))?;
        p.partition(&ds.graph, cfg.parts, &mut root_rng.split(1))
    };
    let cut_ratio = ds.graph.cut_ratio(&assignment);
    let mut setup_rng = root_rng.split(2);
    let parts = build_parts(cfg, ds, &assignment, &mut setup_rng);

    // --- states ------------------------------------------------------------
    let mut init_rng = root_rng.split(3);
    let global_init = ModelState::init(&meta, &mut init_rng);
    let workers: Vec<ModelState> = (0..cfg.parts).map(|_| global_init.clone()).collect();
    let global_params: Vec<Tensor> = global_init.params.clone();
    let server_meta = rt.meta(&server_train_name)?.clone();
    let server_state = ModelState::init(&server_meta, &mut init_rng.split(9));

    // --- builders ----------------------------------------------------------
    let mut local_builder = BlockBuilder::new(
        dims.b,
        dims.f1,
        dims.f2,
        dims.d,
        dims.c,
        meta.multilabel(),
    );
    local_builder.sample_ratio = cfg.sample_ratio;
    let mut corr_builder = local_builder.clone();
    corr_builder.sample_ratio = 1.0;
    corr_builder.fanout = if cfg.correction_full_neighbors {
        Fanout::Full
    } else {
        Fanout::Sample
    };

    let param_bytes: u64 = global_params.iter().map(|t| t.size_bytes()).sum();
    let eval_rng = root_rng.split(4);
    let corr_rng = root_rng.split(5);
    let net = NetModel::parse(&cfg.net)
        .map_err(|e| anyhow::anyhow!(e))?
        .with_seed(cfg.seed);

    Ok(RunSetup {
        train_name,
        server_train_name,
        eval_name,
        dims,
        assignment,
        cut_ratio,
        parts,
        workers,
        global_params,
        server_state,
        local_builder,
        corr_builder,
        param_bytes,
        eval_rng,
        corr_rng,
        net,
    })
}

/// What one worker's local round produced (losses/bytes are engine-
/// independent; times are measured on whichever thread ran it).
pub(crate) struct WorkerRoundOut {
    pub loss_sum: f64,
    pub loss_n: usize,
    /// modeled link time for this worker's round (down + features + up)
    pub net_s: f64,
    /// measured elapsed, including any injected network sleeps
    pub elapsed_s: f64,
}

/// One worker's local round (Alg. 2 lines 5-10): receive the global params,
/// run `k` device-resident local steps, hand the params back. Runs
/// identically on the sequential driver's thread and on a cluster worker
/// thread — per-(run, worker, round) RNG streams keep it engine-independent.
/// `on_feature_bytes` fires once per mini-batch that touched remote
/// features (GGS accounting); the cluster engine forwards it as a
/// `RemoteFeatures` message.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_worker_round(
    rt: &Runtime,
    train_name: &str,
    cfg: &ExperimentConfig,
    ds: &Dataset,
    assignment: &[u32],
    info: &PartInfo,
    builder: &BlockBuilder,
    netm: &NetModel,
    param_bytes: u64,
    state: &mut ModelState,
    global: &[Tensor],
    round: usize,
    k: usize,
    arena: &mut BlockArena,
    scratch: &mut NodeScratch,
    mut on_feature_bytes: impl FnMut(u64),
) -> Result<WorkerRoundOut> {
    let t0 = std::time::Instant::now();
    let _span_round = crate::obs::span_round("worker.round", round as i64);
    let mut net_s = 0f64;

    // receive global params over the modeled link ("net.*" spans measure
    // the *injected sleep*, i.e. modeled time made wall — see obs/README)
    {
        let _s = crate::obs::span_round("net.down", round as i64);
        let t_down = netm.transfer_s(param_bytes, info.part, round as u64, net::LEG_DOWN);
        netm.sleep(t_down);
        net_s += t_down;
        if round == 1 && info.storage_bytes > 0 {
            // SubgraphApprox one-time feature storage rides the first download
            let t_store = netm.transfer_s(
                info.storage_bytes,
                info.part,
                round as u64,
                net::LEG_STORAGE,
            );
            netm.sleep(t_store);
            net_s += t_store;
        }
    }
    state.copy_params_from(global);

    let mut loss_sum = 0f64;
    let mut loss_n = 0usize;
    if !info.train_ids.is_empty() {
        let _s = crate::obs::span_round("worker.local_steps", round as i64);
        let mut rng = super::worker_rng(cfg.seed, info.part as usize, round);
        let mut batches = BatchIter::new(&info.train_ids, builder.b, &mut rng);
        // model + optimizer state stay device-resident across all K local
        // steps (Alg. 2 cadence); host tensors are touched again only at
        // the round boundary below
        let mut dev = rt.upload(train_name, state)?;
        for step in 0..k {
            if batches.remaining() == 0 {
                batches.reshuffle(&mut rng);
            }
            let batch = batches.next_batch().expect("train shard is non-empty");
            let blk = builder.build_into(arena, batch, &info.adj, ds, &mut rng);
            if cfg.algorithm.uses_global_view() {
                let fb = blk.remote_feature_bytes_with(scratch, assignment, info.part);
                let t_feat = netm.transfer_s(
                    fb,
                    info.part,
                    round as u64,
                    net::LEG_FEATURES + step as u64,
                );
                netm.sleep(t_feat);
                net_s += t_feat;
                on_feature_bytes(fb);
            }
            rt.train_step_device_queued(&mut dev, blk, cfg.lr)?;
        }
        rt.download_into(&dev, state)?;
        // the per-round (not per-step) loss readback
        for loss in dev.take_losses()? {
            loss_sum += loss as f64;
            loss_n += 1;
        }
    }

    // send params back over the modeled link
    {
        let _s = crate::obs::span_round("net.up", round as i64);
        let t_up = netm.transfer_s(param_bytes, info.part, round as u64, net::LEG_UP);
        netm.sleep(t_up);
        net_s += t_up;
    }

    Ok(WorkerRoundOut {
        loss_sum,
        loss_n,
        net_s,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

/// S server-correction steps (Alg. 2 lines 13-18) starting from `base`,
/// device-resident: one upload, S steps, one download. Leaves the corrected
/// parameters in `server_state.params`; the caller decides whether they
/// replace the global params (sync) or become a delta (pipelined).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_correction_steps(
    rt: &Runtime,
    server_train_name: &str,
    cfg: &ExperimentConfig,
    ds: &Dataset,
    assignment: &[u32],
    b: usize,
    server_state: &mut ModelState,
    base: &[Tensor],
    corr_builder: &BlockBuilder,
    corr_arena: &mut BlockArena,
    corr_rng: &mut Pcg64,
) -> Result<()> {
    server_state.copy_params_from(base);
    let mut dev = rt.upload(server_train_name, server_state)?;
    for _ in 0..cfg.correction_steps {
        let batch = correction_batch(cfg.correction_batch, ds, assignment, b, corr_rng);
        let blk = corr_builder.build_into(corr_arena, &batch, &ds.graph, ds, corr_rng);
        rt.train_step_device_queued(&mut dev, blk, cfg.server_lr)?;
    }
    rt.download_into(&dev, server_state)?;
    dev.take_losses()?; // drain: correction losses are not reported
    Ok(())
}

/// Forward training-monitor alerts into the run's event stream. A no-op
/// list while the telemetry monitors are off, so the sync-mode event-parity
/// contract (which runs with monitors off) is untouched.
pub(crate) fn emit_alerts(ctx: &mut RunCtx<'_>, alerts: Vec<crate::obs::monitor::Alert>) {
    for a in alerts {
        ctx.emit(Event::MonitorAlert {
            round: a.round,
            monitor: a.monitor,
            message: a.message,
            value: a.value,
        });
    }
}

/// Server-side round epilogue shared by every engine's sync-style path:
/// run the correction steps (when the algorithm has them) on the freshly
/// averaged `global_params`, then the cadenced evaluation. Keeping this in
/// one place is part of the bit-parity contract between the sequential
/// driver and the cluster engine's sync mode — including the event
/// sequence: `CorrectionApplied` then (on eval rounds) `EvalCompleted`.
/// Returns `(val_score, global_loss)` (NaN on non-eval rounds).
#[allow(clippy::too_many_arguments)]
pub(crate) fn server_round_epilogue(
    rt: &Runtime,
    cfg: &ExperimentConfig,
    ds: &Dataset,
    assignment: &[u32],
    dims: Dims,
    server_train_name: &str,
    eval_name: &str,
    local_builder: &BlockBuilder,
    corr_builder: &BlockBuilder,
    server_state: &mut ModelState,
    global_params: &mut Vec<Tensor>,
    corr_arena: &mut BlockArena,
    corr_rng: &mut Pcg64,
    eval_rng: &mut Pcg64,
    round: usize,
    phases: &mut PhaseTimes,
    ctx: &mut RunCtx<'_>,
) -> Result<(f64, f64)> {
    if cfg.algorithm.corrects() && cfg.correction_steps > 0 {
        // Correction-efficacy probe (telemetry monitors only): global
        // train-sample loss before vs. after the correction, plus the
        // correction's parameter-delta norm. Every RNG it touches is a
        // *clone* of `eval_rng`'s pre-correction state — the same clone
        // twice, so both evals score the same node sample — which keeps
        // every training-visible stream bit-identical with monitors on.
        // The two extra evals are the monitors' documented cost.
        let probe = crate::obs::monitor::enabled();
        let mut probe_rng = eval_rng.clone();
        let mut probe_sample: Vec<u32> = Vec::new();
        let mut loss_before = f64::NAN;
        let mut params_before: Vec<Vec<f32>> = Vec::new();
        if probe {
            probe_sample =
                if cfg.eval_max_nodes > 0 && ds.splits.train.len() > cfg.eval_max_nodes {
                    probe_rng.sample_without_replacement(&ds.splits.train, cfg.eval_max_nodes)
                } else {
                    ds.splits.train.clone()
                };
            let mut r = probe_rng.clone();
            loss_before = eval_split(
                rt,
                eval_name,
                global_params,
                ds,
                &probe_sample,
                local_builder,
                &mut r,
                false,
            )?
            .1;
            params_before = global_params.iter().map(|t| t.data.clone()).collect();
        }
        let t_corr = std::time::Instant::now();
        {
            let _s = crate::obs::span_round("server.correction", round as i64);
            run_correction_steps(
                rt,
                server_train_name,
                cfg,
                ds,
                assignment,
                dims.b,
                server_state,
                global_params,
                corr_builder,
                corr_arena,
                corr_rng,
            )?;
            Tensor::copy_all(global_params, &server_state.params);
        }
        phases.corr_s = t_corr.elapsed().as_secs_f64();
        ctx.emit(Event::CorrectionApplied {
            round,
            steps: cfg.correction_steps,
        });
        if probe {
            let mut r = probe_rng;
            let loss_after = eval_split(
                rt,
                eval_name,
                global_params,
                ds,
                &probe_sample,
                local_builder,
                &mut r,
                false,
            )?
            .1;
            let mut d2 = 0f64;
            for (t, before) in global_params.iter().zip(&params_before) {
                for (a, b) in t.data.iter().zip(before) {
                    let d = (*a - *b) as f64;
                    d2 += d * d;
                }
            }
            emit_alerts(
                ctx,
                crate::obs::monitor::observe_correction(round, loss_before, loss_after, d2.sqrt()),
            );
        }
    }
    eval_if_due(
        rt,
        eval_name,
        global_params,
        ds,
        cfg,
        local_builder,
        eval_rng,
        round,
        phases,
        ctx,
    )
}

/// The eval-cadence rule in one place: evaluate on `eval_every` rounds and
/// on the final round (emitting `EvalCompleted`), otherwise report NaNs.
/// The eval span and `phases.eval_s` are tagged with the round that
/// *triggered* the eval, so under `eval_every > 1` its cost is attributed
/// to this round's record — never smeared into the rounds after it
/// (asserted by the event-parity test in `tests/obs.rs`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_if_due(
    rt: &Runtime,
    eval_name: &str,
    global_params: &[Tensor],
    ds: &Dataset,
    cfg: &ExperimentConfig,
    builder: &BlockBuilder,
    eval_rng: &mut Pcg64,
    round: usize,
    phases: &mut PhaseTimes,
    ctx: &mut RunCtx<'_>,
) -> Result<(f64, f64)> {
    if round % cfg.eval_every == 0 || round == cfg.rounds {
        let t_eval = std::time::Instant::now();
        let (val_score, global_loss) = {
            let _s = crate::obs::span_round("server.eval", round as i64);
            eval_round(rt, eval_name, global_params, ds, cfg, builder, eval_rng)?
        };
        phases.eval_s = t_eval.elapsed().as_secs_f64();
        ctx.emit(Event::EvalCompleted {
            round,
            val_score,
            global_loss,
        });
        Ok((val_score, global_loss))
    } else {
        Ok((f64::NAN, f64::NAN))
    }
}

/// Round-boundary evaluation of the global model: (val_score, global_loss)
/// on seeded samples of the val / train splits.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_round(
    rt: &Runtime,
    eval_name: &str,
    global_params: &[Tensor],
    ds: &Dataset,
    cfg: &ExperimentConfig,
    builder: &BlockBuilder,
    eval_rng: &mut Pcg64,
) -> Result<(f64, f64)> {
    let val_ids: Vec<u32> = if cfg.eval_max_nodes > 0 && ds.splits.val.len() > cfg.eval_max_nodes
    {
        eval_rng.sample_without_replacement(&ds.splits.val, cfg.eval_max_nodes)
    } else {
        ds.splits.val.clone()
    };
    let (val_score, _) = eval_split(
        rt,
        eval_name,
        global_params,
        ds,
        &val_ids,
        builder,
        eval_rng,
        true,
    )?;

    let train_sample: Vec<u32> =
        if cfg.eval_max_nodes > 0 && ds.splits.train.len() > cfg.eval_max_nodes {
            eval_rng.sample_without_replacement(&ds.splits.train, cfg.eval_max_nodes)
        } else {
            ds.splits.train.clone()
        };
    let (_, global_loss) = eval_split(
        rt,
        eval_name,
        global_params,
        ds,
        &train_sample,
        builder,
        eval_rng,
        false, // loss-only: skip the score (AUC fallback sorts are wasted)
    )?;
    Ok((val_score, global_loss))
}

/// Final test-split score of the run's global model.
#[allow(clippy::too_many_arguments)]
pub(crate) fn final_test_score(
    rt: &Runtime,
    eval_name: &str,
    global_params: &[Tensor],
    ds: &Dataset,
    cfg: &ExperimentConfig,
    builder: &BlockBuilder,
    eval_rng: &mut Pcg64,
) -> Result<f64> {
    let test_ids: Vec<u32> =
        if cfg.eval_max_nodes > 0 && ds.splits.test.len() > cfg.eval_max_nodes * 2 {
            eval_rng.sample_without_replacement(&ds.splits.test, cfg.eval_max_nodes * 2)
        } else {
            ds.splits.test.clone()
        };
    if test_ids.is_empty() {
        return Ok(f64::NAN);
    }
    let (test_score, _) = eval_split(
        rt,
        eval_name,
        global_params,
        ds,
        &test_ids,
        builder,
        eval_rng,
        true,
    )?;
    Ok(test_score)
}

/// Last non-NaN validation score + avg bytes/round over `records`.
pub(crate) fn summarize(records: &[RoundRecord]) -> (f64, f64) {
    let final_val = records
        .iter()
        .rev()
        .find(|r| !r.val_score.is_nan())
        .map(|r| r.val_score)
        .unwrap_or(f64::NAN);
    let total_rounds = records.len().max(1) as f64;
    let avg_round_bytes =
        records.iter().map(|r| r.comm.total()).sum::<u64>() as f64 / total_rounds;
    (final_val, avg_round_bytes)
}

/// Total optimizer steps the schedule implies for this config.
pub(crate) fn planned_total_steps(cfg: &ExperimentConfig) -> usize {
    if cfg.algorithm == Algorithm::FullSync {
        cfg.rounds
    } else {
        cfg.schedule.total_steps(cfg.rounds)
    }
}

/// Shared run epilogue for every engine: final test score + summary stats,
/// assembled into the `RunResult`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_run(
    rt: &Runtime,
    eval_name: &str,
    global_params: &[Tensor],
    ds: &Dataset,
    cfg: &ExperimentConfig,
    builder: &BlockBuilder,
    eval_rng: &mut Pcg64,
    cut_ratio: f64,
    records: Vec<RoundRecord>,
    engine: Engine,
    max_staleness: Option<u64>,
) -> Result<RunResult> {
    let final_test =
        final_test_score(rt, eval_name, global_params, ds, cfg, builder, eval_rng)?;
    let (final_val, avg_round_bytes) = summarize(&records);
    let total_drops = records.iter().map(|r| r.drops).sum();
    let total_respawns = records.iter().map(|r| r.respawns).sum();
    // report the wire the run actually rode (kills and other options are
    // not identity; the kind name is) — sequential runs are in-process by
    // construction
    let transport = match engine {
        Engine::Sequential => "inprocess".to_string(),
        Engine::Cluster => crate::transport::TransportSpec::parse(&cfg.transport)
            .map(|t| t.kind.name().to_string())
            .unwrap_or_else(|_| cfg.transport.clone()),
    };
    Ok(RunResult {
        algorithm: cfg.algorithm,
        dataset: cfg.dataset.clone(),
        arch: cfg.arch.clone(),
        transport,
        parts: cfg.parts,
        engine: engine.name(),
        records,
        final_val,
        final_test,
        cut_ratio,
        avg_round_bytes,
        total_steps: planned_total_steps(cfg),
        max_staleness,
        total_drops,
        total_respawns,
    })
}

/// Run one complete distributed-training experiment, dispatching to the
/// engine named in `cfg.engine` (see the module docs).
///
/// This is the legacy run-to-completion entry point, kept as a thin
/// wrapper over the session machinery: no events are observed and no
/// early-stop is possible. Use `api::ExperimentBuilder` → `launch` →
/// `Run::stream` for the streaming interface.
pub fn run_experiment(cfg: &ExperimentConfig, ds: &Dataset, rt: &Runtime) -> Result<RunResult> {
    let control = RunControl::default();
    let mut sink = |_: Event| {};
    let mut ctx = RunCtx {
        sink: &mut sink,
        stop: &control,
        publish: None,
    };
    run_with_ctx(cfg, ds, rt, None, &mut ctx)
}

/// Engine dispatch with full session plumbing: the optional pre-computed
/// partition (sweep reuse) and the event/stop context.
pub(crate) fn run_with_ctx(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    rt: &Runtime,
    pre_assignment: Option<&[u32]>,
    ctx: &mut RunCtx<'_>,
) -> Result<RunResult> {
    match cfg.engine {
        Engine::Sequential => {
            if cfg.round_mode != RoundMode::Sync {
                bail!(
                    "round_mode {} requires the cluster engine — the sequential \
                     driver is always sync; rerun with --engine cluster",
                    cfg.round_mode.name()
                );
            }
            run_sequential(cfg, ds, rt, pre_assignment, ctx)
        }
        Engine::Cluster => crate::cluster::run_cluster(cfg, ds, rt, pre_assignment, ctx),
    }
}

/// The legacy single-thread engine: workers run one after another on the
/// caller's `Runtime` (the only option under PJRT), with the parallel round
/// time back-computed as `max_p(worker time)`.
fn run_sequential(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    rt: &Runtime,
    pre_assignment: Option<&[u32]>,
    ctx: &mut RunCtx<'_>,
) -> Result<RunResult> {
    let RunSetup {
        train_name,
        server_train_name,
        eval_name,
        dims,
        assignment,
        cut_ratio,
        parts,
        mut workers,
        mut global_params,
        mut server_state,
        local_builder,
        corr_builder,
        param_bytes,
        mut eval_rng,
        mut corr_rng,
        net: netm,
    } = setup_run(cfg, ds, rt, pre_assignment)?;
    if netm.has_faults() || cfg.round_timeout > 0.0 || cfg.quorum > 0 {
        bail!(
            "fault injection (drop=/crash=) and quorum rounds (round_timeout, \
             quorum) require the cluster engine; rerun with --engine cluster"
        );
    }
    let is_fullsync = cfg.algorithm == Algorithm::FullSync;
    // workers run serially on this thread, so the kernel pool may use the
    // whole host (0 = auto); results are bit-identical at any setting
    rt.set_kernel_threads(cfg.kernel_threads);

    let mut records: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);
    // one-time storage bytes ride round 1's comm, so the cumulative counter
    // starts at zero (counting them here too would double-book them)
    let mut cum_bytes: u64 = 0;

    // --- resume: overwrite round-loop state from a checkpoint ---------------
    // `setup_run` above already burned the setup-time RNG streams in fresh-run
    // order, so only loop-carried state needs restoring; the remaining rounds
    // then replay bit-for-bit (asserted by tests/cluster.rs).
    let mut start_round = 1usize;
    if !cfg.resume.is_empty() {
        let ck = Checkpoint::load(std::path::Path::new(&cfg.resume))?;
        ck.check_compatible(cfg)?;
        if !ck.dead.is_empty() {
            bail!(
                "checkpoint has dead workers {:?} — resuming a faulted run \
                 requires the cluster engine",
                ck.dead
            );
        }
        global_params = ck.global_params;
        server_state = ck.server_state;
        workers = ck.workers;
        eval_rng = Pcg64::from_raw_state(ck.eval_rng.0, ck.eval_rng.1);
        corr_rng = Pcg64::from_raw_state(ck.corr_rng.0, ck.corr_rng.1);
        cum_bytes = ck.cum_bytes;
        start_round = ck.round + 1;
    }

    // reusable hot-path buffers: block arenas (local + correction shapes)
    // and the remote-feature dedup scratch — no per-batch allocation
    let mut arena = BlockArena::new();
    let mut corr_arena = BlockArena::new();
    let mut node_scratch = NodeScratch::new();

    // --- round loop ---------------------------------------------------------
    for round in start_round..=cfg.rounds {
        if ctx.stopped() {
            break; // RunControl::stop(): end at the round boundary
        }
        let t_round = std::time::Instant::now();
        let _span_round = crate::obs::span_round("round", round as i64);
        let k = if is_fullsync {
            1
        } else {
            cfg.schedule.steps_for_round(round)
        };
        ctx.emit(Event::RoundStarted {
            round,
            local_steps: k,
        });
        let mut comm = CommStats::default();
        if round == 1 {
            comm.feature_bytes += parts.iter().map(|p| p.storage_bytes).sum::<u64>();
        }
        let mut worker_time = 0f64;
        let mut net_time = 0f64;
        let mut local_loss_sum = 0f64;
        let mut local_loss_n = 0usize;

        // ---- local training (simulated-parallel) --------------------------
        for (p, info) in parts.iter().enumerate() {
            comm.down_bytes += param_bytes;
            let out = run_worker_round(
                rt,
                &train_name,
                cfg,
                ds,
                &assignment,
                info,
                &local_builder,
                &netm,
                param_bytes,
                &mut workers[p],
                &global_params,
                round,
                k,
                &mut arena,
                &mut node_scratch,
                |fb| comm.feature_bytes += fb,
            )?;
            comm.up_bytes += param_bytes;
            local_loss_sum += out.loss_sum;
            local_loss_n += out.loss_n;
            worker_time = worker_time.max(out.elapsed_s);
            net_time = net_time.max(out.net_s);
            ctx.emit(Event::WorkerRoundCompleted {
                round,
                part: info.part,
                compute_s: out.elapsed_s,
                net_s: out.net_s,
            });
        }

        // cross-worker parameter divergence (Thm 4.3/4.4's residual
        // quantity), read from the states the server already holds —
        // monitors only, never part of training
        if crate::obs::monitor::enabled() {
            let views: Vec<Vec<&[f32]>> = workers
                .iter()
                .map(|w| w.params.iter().map(|t| t.data.as_slice()).collect())
                .collect();
            let alerts = crate::obs::monitor::observe_divergence(round, &views);
            emit_alerts(ctx, alerts);
        }

        // ---- server: average + correct + eval -----------------------------
        let t_server = std::time::Instant::now();
        let mut phases = PhaseTimes::default();
        {
            let _s = crate::obs::span_round("server.average", round as i64);
            let refs: Vec<&ModelState> = workers.iter().collect();
            ModelState::average_params_into(&mut global_params, &refs);
        }
        phases.avg_s = t_server.elapsed().as_secs_f64();
        let (val_score, global_loss) = server_round_epilogue(
            rt,
            cfg,
            ds,
            &assignment,
            dims,
            &server_train_name,
            &eval_name,
            &local_builder,
            &corr_builder,
            &mut server_state,
            &mut global_params,
            &mut corr_arena,
            &mut corr_rng,
            &mut eval_rng,
            round,
            &mut phases,
            ctx,
        )?;
        let server_time = t_server.elapsed().as_secs_f64();

        cum_bytes += comm.total();
        records.push(RoundRecord {
            round,
            local_steps: k,
            local_loss: if local_loss_n > 0 {
                local_loss_sum / local_loss_n as f64
            } else {
                f64::NAN
            },
            global_loss,
            val_score,
            comm,
            cum_bytes,
            worker_time_s: worker_time,
            server_time_s: server_time,
            net_time_s: net_time,
            wall_time_s: t_round.elapsed().as_secs_f64(),
            phases,
            drops: 0,
            respawns: 0,
            quorum: parts.len(),
            wire_bytes_down: 0,
            wire_bytes_up: 0,
        });
        // round boundary: hand the (corrected) global model to any live
        // serving hub (no-op unless the run was launched with publish_to)
        ctx.publish_params(round, &global_params);
        ctx.emit(Event::RoundCompleted(
            records.last().expect("just pushed").clone(),
        ));
        if cfg.checkpoint_every > 0 && round % cfg.checkpoint_every == 0 {
            let ck = Checkpoint::capture(
                cfg,
                round,
                cum_bytes,
                &global_params,
                &server_state,
                &workers,
                &eval_rng,
                &corr_rng,
                &[],
            );
            let path = ck.save(std::path::Path::new(&cfg.checkpoint_dir))?;
            ctx.emit(Event::CheckpointSaved {
                round,
                path: path.display().to_string(),
            });
        }
    }

    finish_run(
        rt,
        &eval_name,
        &global_params,
        ds,
        cfg,
        &local_builder,
        &mut eval_rng,
        cut_ratio,
        records,
        Engine::Sequential,
        None,
    )
}

/// Convenience: load the dataset named in `cfg` (registry lookup; unknown
/// names report the available set).
pub fn load_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    registry::load_dataset(&cfg.dataset, cfg.seed).map_err(|e| anyhow::anyhow!(e))
}

/// Label-distribution skew across parts: mean total-variation distance
/// between each part's label histogram and the global histogram — a direct
/// observable for the κ_X heterogeneity of §4.1.
pub fn label_skew(ds: &Dataset, assignment: &[u32], parts: usize) -> f64 {
    let c = ds.c();
    let hist = |ids: &dyn Fn(u32) -> bool| -> Vec<f64> {
        let mut h = vec![0f64; c];
        let mut n = 0f64;
        match &ds.labels {
            Labels::MultiClass(y) => {
                for v in 0..ds.n() as u32 {
                    if ids(v) {
                        h[y[v as usize] as usize] += 1.0;
                        n += 1.0;
                    }
                }
            }
            Labels::MultiLabel { data, c: dc } => {
                for v in 0..ds.n() as u32 {
                    if ids(v) {
                        for j in 0..*dc {
                            h[j] += data[v as usize * dc + j] as f64;
                        }
                        n += 1.0;
                    }
                }
            }
        }
        if n > 0.0 {
            for x in h.iter_mut() {
                *x /= n;
            }
        }
        h
    };
    let global = hist(&|_| true);
    let mut tv_sum = 0f64;
    for p in 0..parts as u32 {
        let local = hist(&|v| assignment[v as usize] == p);
        let tv: f64 = global
            .iter()
            .zip(&local)
            .map(|(g, l)| (g - l).abs())
            .sum::<f64>()
            / 2.0;
        tv_sum += tv;
    }
    tv_sum / parts as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_skew_detects_community_partitions() {
        let ds = generators::by_name("tiny", 0).unwrap();
        // partition by label (maximum skew) vs round-robin (no skew)
        let by_label: Vec<u32> = match &ds.labels {
            Labels::MultiClass(y) => y.iter().map(|&l| (l % 4) as u32).collect(),
            _ => unreachable!(),
        };
        let round_robin: Vec<u32> = (0..ds.n() as u32).map(|v| v % 4).collect();
        let skew_label = label_skew(&ds, &by_label, 4);
        let skew_rr = label_skew(&ds, &round_robin, 4);
        assert!(
            skew_label > 3.0 * skew_rr.max(0.01),
            "label {skew_label} vs rr {skew_rr}"
        );
    }

    #[test]
    fn correction_batch_uniform_is_from_train() {
        let ds = generators::by_name("tiny", 1).unwrap();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let mut rng = Pcg64::new(1);
        let train: std::collections::HashSet<u32> = ds.splits.train.iter().copied().collect();
        let b = correction_batch(CorrectionBatch::Uniform, &ds, &assignment, 16, &mut rng);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|v| train.contains(v)));
    }

    #[test]
    fn correction_batch_max_cut_prefers_cut_nodes() {
        let ds = generators::by_name("tiny", 2).unwrap();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let mut rng = Pcg64::new(2);
        let b = correction_batch(CorrectionBatch::MaxCutEdges, &ds, &assignment, 16, &mut rng);
        // alternating assignment cuts nearly every edge: all batch nodes
        // should touch a cut edge
        let n_cut = b
            .iter()
            .filter(|&&v| {
                ds.graph
                    .neighbors(v)
                    .iter()
                    .any(|&u| assignment[u as usize] != assignment[v as usize])
            })
            .count();
        assert!(n_cut >= 14, "only {n_cut}/16 touch cut edges");
    }

    #[test]
    fn build_parts_views_respect_algorithm() {
        let ds = generators::by_name("tiny", 3).unwrap();
        let assignment: Vec<u32> = (0..ds.n() as u32).map(|v| v % 2).collect();
        let mut rng = Pcg64::new(3);
        let mut mk = |alg: Algorithm| {
            let mut cfg = ExperimentConfig::default();
            cfg.parts = 2;
            cfg.algorithm = alg;
            build_parts(&cfg, &ds, &assignment, &mut rng)
        };
        // induced views drop cut edges
        let local = mk(Algorithm::PsgdPa);
        let mut induced_edges = 0usize;
        for v in 0..ds.n() as u32 {
            induced_edges += local[0].adj.neighbors(v).len();
        }
        let global = mk(Algorithm::Ggs);
        let mut global_edges = 0usize;
        for v in 0..ds.n() as u32 {
            global_edges += global[0].adj.neighbors(v).len();
        }
        assert!(induced_edges < global_edges);
        // approx view sits in between and reports storage bytes
        let approx = mk(Algorithm::SubgraphApprox);
        assert!(approx[0].storage_bytes > 0);
        let mut approx_edges = 0usize;
        for v in 0..ds.n() as u32 {
            approx_edges += approx[0].adj.neighbors(v).len();
        }
        assert!(approx_edges > induced_edges);
        assert!(approx_edges < global_edges);
    }
}
