//! Modeled network: turns the byte counters the coordinator already tracks
//! into per-transfer wall-clock, deterministically.
//!
//! Every message between the parameter server and worker `p` crosses a
//! point-to-point link with a latency + bandwidth cost, optional
//! multiplicative jitter, and an optional straggler distribution (with
//! probability `straggle_p` a transfer takes `straggle_mult`× longer —
//! the tail-latency events the distributed-GNN surveys identify as the
//! dominant systems effect).
//!
//! Two uses:
//!
//! - **modeling** — [`NetModel::transfer_s`] is a pure function of
//!   `(bytes, link, round, leg)`: the jitter/straggler draw comes from a
//!   PRNG seeded by those coordinates, *not* from thread timing, so the
//!   sequential driver and the threaded cluster engine compute bit-identical
//!   modeled times for the same run.
//! - **injection** — with `sleep_scale > 0`, [`NetModel::sleep`] turns the
//!   modeled time into a real `thread::sleep`, so the *measured* wall-clock
//!   of an engine shows overlap (cluster workers sleep concurrently) vs
//!   serialization (the sequential driver sleeps one worker at a time).
//!   The default (`sleep_scale = 0`) never sleeps, keeping tests and the
//!   paper-repro figures timing-neutral.
//!
//! Specs are parsed from strings: a preset (`ideal` | `lan` | `wan`)
//! optionally followed by `key=value` overrides, comma-separated —
//! e.g. `"lan,scale=1"` or `"lat=2e-2,bw=1.25e8,jitter=0.1,scale=1"`.
//!
//! **Fault injection** rides the same spec grammar so every failure mode is
//! reproducible from a string: `drop=0.02` gives each message leg an
//! independent 2% chance of being lost (a pure, seeded draw per
//! `(link, round, leg)` — like the jitter stream, but salted differently so
//! drop and jitter decisions are independent), and `crash=p@r` kills worker
//! `p` at the start of round `r` (repeatable for multiple crashes:
//! `crash=1@3,crash=2@5`). The engine decides what a lost message or dead
//! worker *means* (quorum averaging, respawn); the model only answers
//! "was this message dropped?" / "does this worker crash here?".

use crate::util::Pcg64;

/// Leg tags decorrelate the jitter draws of the transfers inside one
/// (link, round): params down, params up, one-time storage, and the
/// per-step remote-feature fetches.
pub const LEG_DOWN: u64 = 0;
pub const LEG_UP: u64 = 1;
pub const LEG_STORAGE: u64 = 2;
/// feature fetch for local step `i` uses leg `LEG_FEATURES + i`
pub const LEG_FEATURES: u64 = 16;

/// A symmetric point-to-point link model between the server and each worker.
#[derive(Clone, Debug, PartialEq)]
pub struct NetModel {
    /// per-message one-way latency (seconds)
    pub latency_s: f64,
    /// link bandwidth (bytes/second); `f64::INFINITY` = unmetered
    pub bytes_per_s: f64,
    /// multiplicative jitter amplitude: transfer time is scaled by a
    /// uniform factor in `[1 - jitter, 1 + jitter]`
    pub jitter: f64,
    /// probability that a transfer straggles
    pub straggle_p: f64,
    /// multiplier applied to a straggling transfer
    pub straggle_mult: f64,
    /// real-sleep factor for [`NetModel::sleep`] (0 = model only)
    pub sleep_scale: f64,
    /// probability that any one message leg is lost (`drop=p`)
    pub drop_p: f64,
    /// crash schedule: worker `p` dies at the start of round `r` (`crash=p@r`)
    pub crashes: Vec<(u32, u64)>,
    /// decorrelates the jitter stream between runs (set from the run seed)
    pub seed: u64,
}

impl NetModel {
    /// Zero-cost network: every transfer is instantaneous.
    pub fn ideal() -> NetModel {
        NetModel {
            latency_s: 0.0,
            bytes_per_s: f64::INFINITY,
            jitter: 0.0,
            straggle_p: 0.0,
            straggle_mult: 1.0,
            sleep_scale: 0.0,
            drop_p: 0.0,
            crashes: Vec::new(),
            seed: 0,
        }
    }

    /// Datacenter LAN: 0.5 ms latency, 10 Gb/s, light jitter.
    pub fn lan() -> NetModel {
        NetModel {
            latency_s: 5e-4,
            bytes_per_s: 1.25e9,
            jitter: 0.05,
            ..NetModel::ideal()
        }
    }

    /// Cross-site WAN: 20 ms latency, 1 Gb/s, jitter + 2% 4x stragglers.
    pub fn wan() -> NetModel {
        NetModel {
            latency_s: 2e-2,
            bytes_per_s: 1.25e8,
            jitter: 0.1,
            straggle_p: 0.02,
            straggle_mult: 4.0,
            ..NetModel::ideal()
        }
    }

    /// Parse a spec string: `preset[,key=value]*` (see module docs). A
    /// preset is only legal as the first token — later it would silently
    /// overwrite every override that preceded it, so that is an error.
    pub fn parse(spec: &str) -> Result<NetModel, String> {
        let mut net = NetModel::ideal();
        for (i, tok) in spec.split(',').map(str::trim).enumerate() {
            if tok.is_empty() {
                continue;
            }
            match tok {
                "ideal" | "lan" | "wan" if i > 0 => {
                    return Err(format!(
                        "net spec {spec:?}: preset {tok:?} must come first \
                         (it would discard the preceding overrides)"
                    ));
                }
                "ideal" => net = NetModel::ideal(),
                "lan" => net = NetModel::lan(),
                "wan" => net = NetModel::wan(),
                _ => {
                    let (k, v) = tok
                        .split_once('=')
                        .ok_or_else(|| format!("net spec token {tok:?} is not a preset (ideal|lan|wan) or key=value"))?;
                    if k == "crash" {
                        // crash=p@r is not numeric — handle before the parse
                        let (p, r) = v.split_once('@').ok_or_else(|| {
                            format!("net spec crash={v:?}: expected crash=<worker>@<round>")
                        })?;
                        let part = p.parse::<u32>().map_err(|_| {
                            format!("net spec crash={v:?}: worker {p:?} is not an integer")
                        })?;
                        let round = r.parse::<u64>().map_err(|_| {
                            format!("net spec crash={v:?}: round {r:?} is not an integer")
                        })?;
                        if round == 0 {
                            return Err(format!(
                                "net spec crash={v:?}: rounds are 1-based (round >= 1)"
                            ));
                        }
                        net.crashes.push((part, round));
                        continue;
                    }
                    let num = v
                        .parse::<f64>()
                        .map_err(|_| format!("net spec {k}={v:?}: not a number"))?;
                    match k {
                        "lat" => net.latency_s = num,
                        "bw" => net.bytes_per_s = num,
                        "jitter" => net.jitter = num,
                        "straggle" => net.straggle_p = num,
                        "straggle_mult" => net.straggle_mult = num,
                        "scale" => net.sleep_scale = num,
                        "drop" => net.drop_p = num,
                        other => return Err(format!("unknown net spec key {other:?}")),
                    }
                }
            }
        }
        // NaN compares false everywhere, so spell the valid ranges positively
        let lat_ok = net.latency_s.is_finite() && net.latency_s >= 0.0;
        let bw_ok = net.bytes_per_s > 0.0 && !net.bytes_per_s.is_nan(); // inf = unmetered
        if !lat_ok || !bw_ok || !(0.0..=1.0).contains(&net.jitter) {
            return Err(format!(
                "net spec {spec:?}: need finite lat >= 0, bw > 0, 0 <= jitter <= 1"
            ));
        }
        let mult_ok = net.straggle_mult.is_finite() && net.straggle_mult >= 1.0;
        let scale_ok = net.sleep_scale.is_finite() && net.sleep_scale >= 0.0;
        if !(0.0..=1.0).contains(&net.straggle_p) || !mult_ok || !scale_ok {
            return Err(format!(
                "net spec {spec:?}: need 0 <= straggle <= 1, finite straggle_mult >= 1, \
                 finite scale >= 0"
            ));
        }
        if !(0.0..=1.0).contains(&net.drop_p) {
            return Err(format!("net spec {spec:?}: need 0 <= drop <= 1"));
        }
        Ok(net)
    }

    /// Bind the model to a run seed (jitter stream decorrelation).
    pub fn with_seed(mut self, seed: u64) -> NetModel {
        self.seed = seed;
        self
    }

    /// No latency and unmetered bandwidth: all transfers cost 0.
    pub fn is_ideal(&self) -> bool {
        self.latency_s == 0.0 && self.bytes_per_s.is_infinite()
    }

    /// Modeled seconds to move `bytes` over worker `link`'s connection in
    /// `round`, transfer `leg`. Pure in its arguments (see module docs), so
    /// both engines agree bit-for-bit.
    pub fn transfer_s(&self, bytes: u64, link: u32, round: u64, leg: u64) -> f64 {
        if self.is_ideal() || bytes == 0 {
            return 0.0;
        }
        let base = self.latency_s + bytes as f64 / self.bytes_per_s;
        if self.jitter == 0.0 && self.straggle_p == 0.0 {
            return base;
        }
        let mut rng = Pcg64::new(
            self.seed
                ^ (link as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ round.wrapping_mul(0xc2b2_ae3d_27d4_eb4f)
                ^ leg.wrapping_mul(0x1656_67b1_9e37_79f9),
        );
        let mut t = base * (1.0 + self.jitter * (2.0 * rng.f64() - 1.0));
        if self.straggle_p > 0.0 && rng.bernoulli(self.straggle_p) {
            t *= self.straggle_mult;
        }
        t.max(0.0)
    }

    /// Any failure mode configured? Engines without fault-tolerant
    /// collection (sequential, async) reject such specs up front.
    pub fn has_faults(&self) -> bool {
        self.drop_p > 0.0 || !self.crashes.is_empty()
    }

    /// Was the message on worker `link`'s connection in `round`, transfer
    /// `leg`, lost? Pure in its arguments like [`NetModel::transfer_s`] —
    /// the draw is seeded from the coordinates with a salt distinct from
    /// the jitter stream, so enabling drops never perturbs modeled times.
    pub fn dropped(&self, link: u32, round: u64, leg: u64) -> bool {
        if self.drop_p <= 0.0 {
            return false;
        }
        let mut rng = Pcg64::new(
            self.seed
                ^ 0xd1b5_4a32_d192_ed03
                ^ (link as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ round.wrapping_mul(0xc2b2_ae3d_27d4_eb4f)
                ^ leg.wrapping_mul(0x1656_67b1_9e37_79f9),
        );
        rng.bernoulli(self.drop_p)
    }

    /// Does worker `link` crash at the start of `round` per the schedule?
    pub fn crashed(&self, link: u32, round: u64) -> bool {
        self.crashes.iter().any(|&(p, r)| p == link && r == round)
    }

    /// Inject `modeled_s` as real wall-clock, scaled by `sleep_scale`
    /// (no-op at the default scale of 0).
    pub fn sleep(&self, modeled_s: f64) {
        if self.sleep_scale > 0.0 && modeled_s > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                modeled_s * self.sleep_scale,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_costs_nothing() {
        let net = NetModel::ideal();
        assert!(net.is_ideal());
        assert_eq!(net.transfer_s(1 << 30, 3, 7, LEG_DOWN), 0.0);
    }

    #[test]
    fn preset_after_overrides_is_rejected() {
        // `scale=1,wan` used to silently discard the scale override
        assert!(NetModel::parse("scale=1,wan").is_err());
        assert!(NetModel::parse("jitter=0.2,lan").is_err());
        let net = NetModel::parse("wan,scale=1").unwrap();
        assert!(net.sleep_scale > 0.0);
    }

    #[test]
    fn transfer_math_without_jitter() {
        let net = NetModel::parse("lat=1e-3,bw=1e6").unwrap();
        // 1 ms latency + 500_000 bytes at 1 MB/s = 0.501 s
        let t = net.transfer_s(500_000, 0, 1, LEG_UP);
        assert!((t - 0.501).abs() < 1e-12, "t={t}");
        // zero-byte transfers send no message
        assert_eq!(net.transfer_s(0, 0, 1, LEG_UP), 0.0);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let net = NetModel::parse("lat=1e-2,bw=1e9,jitter=0.1").unwrap().with_seed(5);
        let base = 1e-2 + 1000.0 / 1e9;
        for link in 0..4 {
            for round in 1..10u64 {
                let a = net.transfer_s(1000, link, round, LEG_DOWN);
                let b = net.transfer_s(1000, link, round, LEG_DOWN);
                assert_eq!(a.to_bits(), b.to_bits(), "not deterministic");
                assert!(
                    (base * 0.9 - 1e-15..=base * 1.1 + 1e-15).contains(&a),
                    "a={a}"
                );
            }
        }
        // different legs draw different jitter (almost surely)
        let a = net.transfer_s(1000, 0, 1, LEG_DOWN);
        let b = net.transfer_s(1000, 0, 1, LEG_UP);
        assert_ne!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn stragglers_appear_at_roughly_their_rate() {
        let net = NetModel::parse("lat=1e-3,bw=1e9,straggle=0.2,straggle_mult=10")
            .unwrap()
            .with_seed(11);
        let base = 1e-3 + 100.0 / 1e9;
        let n = 2000;
        let slow = (0..n)
            .filter(|&r| net.transfer_s(100, 0, r as u64, LEG_DOWN) > base * 5.0)
            .count();
        let rate = slow as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.05, "straggle rate {rate}");
    }

    #[test]
    fn presets_and_overrides_parse() {
        assert_eq!(NetModel::parse("ideal").unwrap(), NetModel::ideal());
        assert_eq!(NetModel::parse("lan").unwrap(), NetModel::lan());
        let n = NetModel::parse("wan,scale=1").unwrap();
        assert_eq!(n.sleep_scale, 1.0);
        assert_eq!(n.latency_s, NetModel::wan().latency_s);
        assert!(NetModel::parse("dsl").is_err());
        assert!(NetModel::parse("lat=abc").is_err());
        assert!(NetModel::parse("lan,jitter=2").is_err());
        assert!(NetModel::parse("bw=0").is_err());
        // non-finite / negative knobs are rejected (inf bw = unmetered is ok)
        assert!(NetModel::parse("lat=inf").is_err());
        assert!(NetModel::parse("lat=nan").is_err());
        assert!(NetModel::parse("bw=nan").is_err());
        assert!(NetModel::parse("lan,scale=-1").is_err());
        assert!(NetModel::parse("lan,scale=inf").is_err());
        assert!(NetModel::parse("lan,straggle=0.1,straggle_mult=nan").is_err());
        assert!(NetModel::parse("bw=inf").is_ok());
    }

    #[test]
    fn fault_spec_parses_and_validates() {
        let net = NetModel::parse("lan,drop=0.05,crash=1@3,crash=2@5").unwrap();
        assert_eq!(net.drop_p, 0.05);
        assert_eq!(net.crashes, vec![(1, 3), (2, 5)]);
        assert!(net.has_faults());
        assert!(net.crashed(1, 3) && net.crashed(2, 5));
        assert!(!net.crashed(1, 4) && !net.crashed(0, 3));
        assert!(!NetModel::parse("lan").unwrap().has_faults());
        assert!(NetModel::parse("drop=1.5").is_err());
        assert!(NetModel::parse("drop=-0.1").is_err());
        assert!(NetModel::parse("drop=nan").is_err());
        assert!(NetModel::parse("crash=1").is_err());
        assert!(NetModel::parse("crash=a@3").is_err());
        assert!(NetModel::parse("crash=1@x").is_err());
        assert!(NetModel::parse("crash=1@0").is_err()); // rounds are 1-based
    }

    #[test]
    fn drop_draws_are_deterministic_and_at_rate() {
        let net = NetModel::parse("lan,drop=0.1").unwrap().with_seed(3);
        let n = 4000u64;
        let hits = (0..n).filter(|&r| net.dropped(0, r, LEG_UP)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.1).abs() < 0.03, "drop rate {rate}");
        for r in 0..32u64 {
            assert_eq!(net.dropped(1, r, LEG_DOWN), net.dropped(1, r, LEG_DOWN));
        }
        // no drops configured -> never drops
        let clean = NetModel::lan().with_seed(3);
        assert!((0..256).all(|r| !clean.dropped(0, r, LEG_UP)));
    }

    #[test]
    fn drop_draws_do_not_perturb_modeled_times() {
        // same spec with and without drop must model identical transfer times
        let a = NetModel::parse("wan").unwrap().with_seed(9);
        let b = NetModel::parse("wan,drop=0.5").unwrap().with_seed(9);
        for r in 1..64u64 {
            for leg in [LEG_DOWN, LEG_UP, LEG_STORAGE] {
                let ta = a.transfer_s(100_000, 2, r, leg);
                let tb = b.transfer_s(100_000, 2, r, leg);
                assert_eq!(ta.to_bits(), tb.to_bits());
            }
        }
    }
}
