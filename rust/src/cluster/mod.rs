//! Cluster execution engine: real multi-threaded workers + a parameter
//! server, exchanging typed messages through a modeled network.
//!
//! The sequential driver ([`crate::coordinator::driver`]) runs every worker
//! on one thread and *back-computes* the parallel round time as
//! `max_p(worker compute)`. That reproduces the paper's byte/round figures
//! but cannot show the systems effects a real deployment lives or dies by:
//! compute/communication overlap, stragglers, and server-correction
//! pipelining. This module is the execution substrate for those.
//!
//! ## Execution model
//!
//! ```text
//!   server (caller thread, shared Runtime `rt`)
//!     │  Down::Round { round, k, params }          ... one mpsc pair per worker
//!     ▼
//!   worker p (OS thread, own native Runtime + BlockArena + ModelState)
//!     │  Up::Features { bytes }                    ... per GGS mini-batch
//!     │  Up::Round(ParamsUp { params, losses.. })  ... once per local round
//!     ▼
//!   server: average → correct → eval → RoundRecord
//! ```
//!
//! Each worker thread owns a *private* `Runtime` (the native backend;
//! `Runtime` is deliberately not `Send`, and the PJRT client cannot leave
//! its thread — PJRT runs only under the legacy sequential engine). Worker
//! state — model + optimizer tensors, the block arena, the sampling
//! scratch — lives on the worker thread for the whole run, exactly like a
//! real cluster node; only parameter vectors and byte counters cross the
//! channels.
//!
//! ## Accounting model
//!
//! Byte counters are identical to the sequential driver's (`CommStats`).
//! Time is reported three ways per [`crate::coordinator::RoundRecord`]:
//!
//! - `worker_time_s` — measured: slowest worker's local round (compute +
//!   any injected network sleeps);
//! - `net_time_s` — modeled: the slowest worker's link time this round,
//!   from [`NetModel::transfer_s`], a pure function of (bytes, link,
//!   round, leg) so the sequential and cluster engines agree bit-for-bit;
//! - `wall_time_s` — measured: the whole round end-to-end on the server.
//!
//! ## Round modes
//!
//! - [`RoundMode::Sync`] — Algorithm 1/2 exactly as the sequential driver
//!   runs them. Same seeds, same RNG streams, same accumulation order ⇒
//!   the per-round losses and bytes reproduce the sequential engine
//!   *bit-for-bit* (asserted by `tests/cluster.rs`); only the measured
//!   wall-clock changes.
//! - [`RoundMode::AsyncStaleness`] — bounded-staleness parameter averaging:
//!   each worker pulls/pushes at its own pace; the server folds each push
//!   into a running average (weight `1/P`) and defers a worker's next pull
//!   while it is more than `tau` rounds ahead of the slowest
//!   ([`StalenessGate`]). One `RoundRecord` is emitted per `P` pushes.
//! - [`RoundMode::PipelinedCorrection`] — the server-correction steps of
//!   Alg. 2 run on a dedicated thread *overlapped* with the next local
//!   epoch: round `r` corrects the broadcast params `θ_r` while workers
//!   train on them, then applies the correction as a delta on top of the
//!   fresh average (`θ_{r+1} = mean_p(θ_p) + (correct(θ_r) − θ_r)`). The
//!   correction leaves the critical path at the cost of applying it one
//!   average "late" — the classic pipelining trade.
//!
//! The front-end [`crate::coordinator::driver::run_experiment`] dispatches
//! on [`crate::config::ExperimentConfig::engine`]; both engines share the
//! same setup, worker-round, correction, and eval code paths (see
//! `coordinator::driver`), and emit the same `RoundRecord`/`RunResult`
//! schema, so every figure, bench, and test runs on either.

//! ## Fault model (see `cluster/README.md` for the full contract)
//!
//! `NetModel` specs can inject reproducible failures: `drop=p` loses each
//! message leg with probability `p` (a pure seeded draw per
//! `(link, round, leg)`, salted independently of the jitter stream), and
//! `crash=p@r` kills worker `p` at the start of round `r`. The sync engine
//! tolerates them with **quorum rounds** (`round_timeout` + `quorum`:
//! average whatever K-of-P params made it, re-admit late ones next round
//! under the [`StalenessGate`] bound), **worker respawn** (a dead worker is
//! relaunched on a fresh thread seeded from the current global params —
//! the paper's "local model = averaged global model" round entry), and
//! **round-boundary checkpoints** ([`checkpoint`]) from which `--resume`
//! replays the remaining rounds bit-for-bit. With no faults configured the
//! collection path degenerates to the legacy all-P fold, keeping sync mode
//! bit-identical to the sequential driver.

pub mod checkpoint;
pub mod engine;
pub mod net;

pub(crate) use engine::run_cluster;
pub use net::NetModel;

/// Which execution substrate runs the round loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// legacy single-thread driver (works on every backend, incl. PJRT)
    Sequential,
    /// one OS thread per worker + parameter-server loop (native backend)
    Cluster,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Some(Engine::Sequential),
            "cluster" | "threaded" => Some(Engine::Cluster),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Sequential => "sequential",
            Engine::Cluster => "cluster",
        }
    }
}

/// Synchronization discipline of the cluster engine's round loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundMode {
    /// lock-step rounds (Alg. 1/2 as written; bit-compatible with the
    /// sequential driver)
    Sync,
    /// bounded-staleness asynchronous averaging: a worker may run at most
    /// `tau` rounds ahead of the slowest worker
    AsyncStaleness { tau: usize },
    /// server correction overlapped with the next local epoch
    PipelinedCorrection,
}

impl RoundMode {
    /// Parse `"sync"`, `"async"` / `"async:<tau>"`, `"pipelined"`.
    pub fn parse(s: &str) -> Option<RoundMode> {
        let s = s.to_ascii_lowercase().replace('_', "-");
        match s.as_str() {
            "sync" => Some(RoundMode::Sync),
            "pipelined" | "pipelined-correction" => Some(RoundMode::PipelinedCorrection),
            "async" => Some(RoundMode::AsyncStaleness { tau: 1 }),
            _ => {
                let tau = s.strip_prefix("async:")?.parse::<usize>().ok()?;
                Some(RoundMode::AsyncStaleness { tau })
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            RoundMode::Sync => "sync".to_string(),
            RoundMode::AsyncStaleness { tau } => format!("async:{tau}"),
            RoundMode::PipelinedCorrection => "pipelined".to_string(),
        }
    }
}

/// Bounded-staleness admission control for [`RoundMode::AsyncStaleness`]:
/// tracks how many local rounds each worker has completed and admits a
/// worker's next round only while it is at most `tau` rounds ahead of the
/// slowest worker. The slowest worker is always admissible (staleness 0),
/// so the gate cannot deadlock.
#[derive(Clone, Debug)]
pub struct StalenessGate {
    tau: usize,
    done: Vec<usize>,
}

impl StalenessGate {
    pub fn new(parts: usize, tau: usize) -> StalenessGate {
        StalenessGate {
            tau,
            done: vec![0; parts],
        }
    }

    /// Resume constructor: every worker has already completed the rounds a
    /// checkpoint barrier recorded (the gate counts absolute rounds, so
    /// admission math keeps working across a resume).
    pub fn from_done(done: Vec<usize>, tau: usize) -> StalenessGate {
        StalenessGate { tau, done }
    }

    /// Record that worker `p` completed (pushed) one more round.
    pub fn push(&mut self, p: usize) {
        self.done[p] += 1;
    }

    /// Rounds completed by worker `p`.
    pub fn done(&self, p: usize) -> usize {
        self.done[p]
    }

    /// Rounds completed by the slowest worker.
    pub fn min_done(&self) -> usize {
        self.done.iter().copied().min().unwrap_or(0)
    }

    /// How far ahead of the slowest worker `p` currently is.
    pub fn staleness(&self, p: usize) -> usize {
        self.done[p] - self.min_done()
    }

    /// May worker `p` start its next round now?
    pub fn may_start(&self, p: usize) -> bool {
        self.staleness(p) <= self.tau
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_and_round_mode_parse() {
        assert_eq!(Engine::parse("cluster"), Some(Engine::Cluster));
        assert_eq!(Engine::parse("SEQ"), Some(Engine::Sequential));
        assert_eq!(Engine::parse("gpu"), None);
        assert_eq!(RoundMode::parse("sync"), Some(RoundMode::Sync));
        assert_eq!(
            RoundMode::parse("async:3"),
            Some(RoundMode::AsyncStaleness { tau: 3 })
        );
        assert_eq!(
            RoundMode::parse("async"),
            Some(RoundMode::AsyncStaleness { tau: 1 })
        );
        assert_eq!(
            RoundMode::parse("pipelined"),
            Some(RoundMode::PipelinedCorrection)
        );
        assert_eq!(RoundMode::parse("async:x"), None);
        assert_eq!(RoundMode::parse("turbo"), None);
        assert_eq!(RoundMode::AsyncStaleness { tau: 2 }.name(), "async:2");
    }

    #[test]
    fn staleness_gate_enforces_bound() {
        let mut g = StalenessGate::new(3, 1);
        // everyone at round 0: all admissible
        assert!(g.may_start(0) && g.may_start(1) && g.may_start(2));
        // worker 0 races ahead by one round: still within tau = 1
        g.push(0);
        assert_eq!(g.staleness(0), 1);
        assert!(g.may_start(0));
        // two rounds ahead: blocked until the slowest catches up
        g.push(0);
        assert_eq!(g.staleness(0), 2);
        assert!(!g.may_start(0));
        assert!(g.may_start(1), "slowest is never blocked");
        g.push(1);
        assert!(!g.may_start(0), "min unchanged while worker 2 lags");
        g.push(2);
        assert_eq!(g.min_done(), 1);
        assert!(g.may_start(0), "released once the bound holds again");
    }

    #[test]
    fn staleness_gate_resumes_from_absolute_counts() {
        let mut g = StalenessGate::from_done(vec![6, 6, 6], 1);
        assert_eq!(g.min_done(), 6);
        assert!(g.may_start(0) && g.may_start(1) && g.may_start(2));
        g.push(0);
        assert_eq!(g.done(0), 7);
        g.push(0);
        assert!(!g.may_start(0), "tau bound holds across the resume base");
    }

    #[test]
    fn staleness_gate_tau_zero_is_lockstep() {
        let mut g = StalenessGate::new(2, 0);
        g.push(0);
        assert!(!g.may_start(0));
        g.push(1);
        assert!(g.may_start(0) && g.may_start(1));
    }
}
