//! The threaded parameter-server engine (see the [`crate::cluster`] module
//! docs for the execution/accounting model).
//!
//! Layout: [`run_cluster`] validates the backend and dispatches on the
//! round mode; `run_rounds` covers sync + pipelined-correction (lock-step
//! rounds, correction inline vs. on a dedicated overlapped thread);
//! `run_async` implements bounded-staleness averaging. All numeric work
//! goes through the same `coordinator::driver` helpers the sequential
//! engine uses, so sync mode is bit-compatible with it by construction.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::checkpoint::Checkpoint;
use super::net::{LEG_DOWN, LEG_UP};
use super::{Engine, RoundMode, StalenessGate};
use crate::api::session::{Event, RunCtx};
use crate::config::ExperimentConfig;
use crate::coordinator::driver::{self, RoundRecord, RunResult, RunSetup};
use crate::coordinator::{Algorithm, CommStats};
use crate::graph::Dataset;
use crate::runtime::{ModelState, Runtime, Tensor};
use crate::sampler::{BlockArena, BlockBuilder};
use crate::transport::{worker_send_error, Down, ParamsUp, Transport, Up, WorkerHost};
use crate::util::{Json, Pcg64};

/// How long the server waits on the shared `Up` channel (per message)
/// before writing off the still-outstanding workers as dead. Only applies
/// under fault tolerance; the fault-free path blocks indefinitely, exactly
/// like the legacy engine.
const LIVENESS_TIMEOUT: Duration = Duration::from_secs(60);

/// Result of one overlapped correction: the parameter delta
/// `correct(θ_r) − θ_r` plus the measured correction time.
type CorrReply = std::result::Result<(Vec<Tensor>, f64), String>;

/// Pipelined-correction thread body: for each base-params snapshot the
/// server sends, run the S correction steps on a private runtime and send
/// back the correction *delta* (applied by the server on top of the fresh
/// average). The server's correction optimizer state persists here across
/// rounds, as in sync mode.
#[allow(clippy::too_many_arguments)]
fn correction_main(
    req: Receiver<Vec<Tensor>>,
    res: Sender<CorrReply>,
    dir: PathBuf,
    server_train_name: String,
    cfg: &ExperimentConfig,
    ds: &Dataset,
    assignment: &[u32],
    b: usize,
    mut state: ModelState,
    builder: BlockBuilder,
    mut rng: Pcg64,
    kernel_threads: usize,
) {
    let rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = res.send(Err(format!("{e:#}")));
            return;
        }
    };
    // the correction overlaps the workers' local epoch: budget it like one
    // more worker so the host stays un-oversubscribed
    rt.set_kernel_threads(kernel_threads);
    let mut arena = BlockArena::new();
    while let Ok(base) = req.recv() {
        let t0 = Instant::now();
        match driver::run_correction_steps(
            &rt,
            &server_train_name,
            cfg,
            ds,
            assignment,
            b,
            &mut state,
            &base,
            &builder,
            &mut arena,
            &mut rng,
        ) {
            Ok(()) => {
                let delta: Vec<Tensor> = state
                    .params
                    .iter()
                    .zip(&base)
                    .map(|(c, b0)| Tensor {
                        shape: c.shape.clone(),
                        data: c
                            .data
                            .iter()
                            .zip(&b0.data)
                            .map(|(cv, bv)| cv - bv)
                            .collect(),
                    })
                    .collect();
                if res.send(Ok((delta, t0.elapsed().as_secs_f64()))).is_err() {
                    break;
                }
            }
            Err(e) => {
                let _ = res.send(Err(format!("{e:#}")));
                break;
            }
        }
    }
}

/// Kernel-pool lanes per compute thread: the explicit `kernel_threads`
/// setting, or `host cores / concurrent` (min 1), where `concurrent` is the
/// number of simultaneously-computing threads — `P` workers, plus the
/// overlapped correction thread in pipelined mode — so the lanes never
/// oversubscribe the host.
fn worker_kernel_threads(cfg: &ExperimentConfig, concurrent: usize) -> usize {
    if cfg.kernel_threads > 0 {
        cfg.kernel_threads
    } else {
        (crate::runtime::pool::host_threads() / concurrent.max(1)).max(1)
    }
}

// ---------------------------------------------------------------------------
// engine front door
// ---------------------------------------------------------------------------

/// Run one experiment on the threaded cluster engine. Requires the native
/// backend (each worker thread builds its own `Runtime`; the PJRT client
/// cannot leave its thread — use the sequential engine there).
pub(crate) fn run_cluster(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    rt: &Runtime,
    pre_assignment: Option<&[u32]>,
    ctx: &mut RunCtx<'_>,
) -> Result<RunResult> {
    if rt.backend_name() != "native" {
        bail!(
            "engine=cluster needs the native backend (the PJRT client is not \
             Send); use --engine=sequential with PJRT artifacts"
        );
    }
    if cfg.parts == 0 || cfg.rounds == 0 {
        bail!("engine=cluster needs parts >= 1 and rounds >= 1");
    }
    // In lock-step modes the server's averaging/eval runs while workers are
    // idle, so its pool may use the whole host. Async mode overlaps server
    // eval with worker compute — budget the server like one more concurrent
    // worker there (explicit kernel_threads always wins).
    rt.set_kernel_threads(match cfg.round_mode {
        RoundMode::AsyncStaleness { .. } => worker_kernel_threads(cfg, cfg.parts + 1),
        _ => cfg.kernel_threads,
    });
    let setup = driver::setup_run(cfg, ds, rt, pre_assignment)?;
    // the transport outlives the round loop: bridge threads borrow it from
    // inside the engine's thread scope, and `finish` reaps worker processes
    // after the scope has joined (workers exit on Shutdown / socket EOF)
    let transport = Transport::new(cfg, &setup)?;
    let res = match cfg.round_mode {
        RoundMode::Sync => run_rounds(cfg, ds, rt, setup, false, &transport, ctx),
        RoundMode::PipelinedCorrection => run_rounds(cfg, ds, rt, setup, true, &transport, ctx),
        RoundMode::AsyncStaleness { tau } => run_async(cfg, ds, rt, setup, tau, &transport, ctx),
    };
    transport.finish();
    res
}

/// Lock-step rounds: sync mode (correction inline on the server thread,
/// bit-compatible with the sequential driver) or pipelined mode (correction
/// overlapped on its own thread, applied as a delta).
fn run_rounds(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    rt: &Runtime,
    setup: RunSetup,
    pipelined: bool,
    transport: &Transport,
    ctx: &mut RunCtx<'_>,
) -> Result<RunResult> {
    let RunSetup {
        train_name,
        server_train_name,
        eval_name,
        dims,
        assignment,
        cut_ratio,
        parts,
        mut workers,
        mut global_params,
        mut server_state,
        local_builder,
        corr_builder,
        param_bytes,
        mut eval_rng,
        mut corr_rng,
        net: netm,
    } = setup;
    let ft = netm.has_faults()
        || cfg.round_timeout > 0.0
        || cfg.quorum > 0
        || transport.has_faults();
    if pipelined && (ft || cfg.checkpoint_every > 0 || !cfg.resume.is_empty()) {
        bail!(
            "fault tolerance and checkpoint/resume run under round_mode=sync \
             only — pipelined mode overlaps the correction with the next \
             local epoch, so there is no round boundary to cut at"
        );
    }
    if cfg.quorum > parts.len() {
        bail!("quorum {} exceeds parts {}", cfg.quorum, parts.len());
    }
    let dir = rt.artifacts_dir().to_path_buf();
    let is_fullsync = cfg.algorithm == Algorithm::FullSync;
    let do_correct = cfg.algorithm.corrects() && cfg.correction_steps > 0;
    let pipe_corr = pipelined && do_correct;
    let storage_sum: u64 = parts.iter().map(|p| p.storage_bytes).sum();
    let parts_n = parts.len();
    // pipelined mode computes on P workers + the correction thread at once;
    // budget the kernel lanes over all of them
    let lanes = worker_kernel_threads(cfg, parts_n + usize::from(pipe_corr));

    // respawn template: a restarted worker re-enters the round from the
    // current global params with zeroed optimizer moments — the paper's
    // round entry ("local model := averaged global model") for a node that
    // lost its local state
    let fresh_opt: Vec<Tensor> = workers
        .first()
        .map(|w| {
            w.opt
                .iter()
                .map(|t| Tensor {
                    shape: t.shape.clone(),
                    data: vec![0.0; t.data.len()],
                })
                .collect()
        })
        .unwrap_or_default();

    // --- resume: overwrite loop-carried state from a checkpoint -------------
    // `setup_run` above already burned the setup-time RNG streams in
    // fresh-run order, so only the loop state needs restoring; the remaining
    // rounds then replay bit-for-bit (asserted by tests/cluster.rs).
    let mut alive: Vec<bool> = vec![true; parts_n];
    let mut start_round = 1usize;
    let mut resume_cum_bytes = 0u64;
    if !cfg.resume.is_empty() {
        let ck = Checkpoint::load(std::path::Path::new(&cfg.resume))?;
        ck.check_compatible(cfg)?;
        if ck.extra.is_some() {
            bail!(
                "this checkpoint was written by the async engine (it carries \
                 async barrier state); resume it under round_mode=async"
            );
        }
        global_params = ck.global_params;
        server_state = ck.server_state;
        workers = ck.workers;
        eval_rng = Pcg64::from_raw_state(ck.eval_rng.0, ck.eval_rng.1);
        corr_rng = Pcg64::from_raw_state(ck.corr_rng.0, ck.corr_rng.1);
        resume_cum_bytes = ck.cum_bytes;
        start_round = ck.round + 1;
        for &p in &ck.dead {
            alive[p as usize] = false;
        }
    }

    // run-owned data every spawn (and respawn) borrows, for either transport
    let host = WorkerHost {
        cfg,
        ds,
        assignment: &assignment,
        netm: &netm,
        dir: dir.clone(),
        train_name: train_name.clone(),
        builder: local_builder.clone(),
        param_bytes,
    };
    std::thread::scope(|s| -> Result<RunResult> {
        let (up_tx, up_rx) = channel::<Up>();
        let mut down_txs: Vec<Sender<Down>> = parts
            .iter()
            .zip(workers)
            .map(|(info, state)| transport.spawn_worker(s, &host, info, state, &up_tx, lanes))
            .collect();
        // under fault tolerance the server keeps an `Up` sender so respawned
        // workers get fresh clones; without it the dropped sender keeps total
        // worker death observable as a channel disconnect (legacy behavior)
        let up_hold = if ft {
            Some(up_tx)
        } else {
            drop(up_tx);
            None
        };

        // sync mode corrects inline and keeps these; pipelined mode moves
        // them onto the correction thread
        let mut inline_server_state = Some(server_state);
        let mut inline_corr_rng = Some(corr_rng);
        let (creq_tx, creq_rx) = channel::<Vec<Tensor>>();
        let (cres_tx, cres_rx) = channel::<CorrReply>();
        if pipe_corr {
            let st = inline_server_state.take().expect("taken once");
            let crng = inline_corr_rng.take().expect("taken once");
            let res = cres_tx.clone();
            let cdir = dir.clone();
            let cname = server_train_name.clone();
            let cb = corr_builder.clone();
            let assign: &[u32] = &assignment;
            let b = dims.b;
            s.spawn(move || {
                correction_main(
                    creq_rx, res, cdir, cname, cfg, ds, assign, b, st, cb, crng, lanes,
                )
            });
        }
        drop(cres_tx);

        let mut records: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);
        // storage bytes ride round 1's comm (see the sequential driver)
        let mut cum_bytes: u64 = resume_cum_bytes;
        // measured wire-byte baseline for per-round deltas (always zero on
        // the in-process transport)
        let (mut wire_up_prev, mut wire_down_prev) = transport.wire_totals();
        let mut corr_arena = BlockArena::new();
        // uploads that missed their round (up-leg drop → retransmit, or past
        // the `round_timeout` deadline), held for the next round's average —
        // the staleness-1 bound the async engine's `StalenessGate` enforces
        let mut held: Vec<Option<ParamsUp>> = (0..parts_n).map(|_| None).collect();

        for round in start_round..=cfg.rounds {
            if ctx.stopped() {
                break; // RunControl::stop(): end at the round boundary
            }
            let t_round = Instant::now();
            let _span_round = crate::obs::span_round("round", round as i64);
            let k = if is_fullsync {
                1
            } else {
                cfg.schedule.steps_for_round(round)
            };
            ctx.emit(Event::RoundStarted {
                round,
                local_steps: k,
            });
            let mut comm = CommStats::default();
            if round == 1 {
                comm.feature_bytes += storage_sum;
            }

            // ---- supervise: respawn workers that died last round ----------
            let mut respawns_r = 0u32;
            if ft && cfg.respawn {
                for p in 0..parts_n {
                    if alive[p] {
                        continue;
                    }
                    let state = ModelState {
                        params: global_params.clone(),
                        opt: fresh_opt.clone(),
                    };
                    // replacing the sender drops the old one, so a worker
                    // that is merely wedged (rather than exited) unblocks
                    // and dies with the channel (remotely: its bridge closes
                    // the socket, and the old process exits on EOF)
                    down_txs[p] = transport.spawn_worker(
                        s,
                        &host,
                        &parts[p],
                        state,
                        up_hold.as_ref().expect("ft keeps the up sender"),
                        lanes,
                    );
                    alive[p] = true;
                    respawns_r += 1;
                    ctx.emit(Event::WorkerRestarted {
                        round,
                        part: parts[p].part,
                    });
                }
            }

            // ---- broadcast ParamsDown (and the correction snapshot) -------
            let span_bcast = crate::obs::span_round("round.broadcast", round as i64);
            let mut drops_r: u64 = 0;
            let mut expected: Vec<bool> = vec![false; parts_n];
            for (p, tx) in down_txs.iter().enumerate() {
                if !alive[p] {
                    continue; // dead with respawn off: out for the run
                }
                let crashes_now = netm.crashed(parts[p].part, round as u64);
                if netm.dropped(parts[p].part, round as u64, LEG_DOWN) {
                    // broadcast lost: p sits this round out (and still dies
                    // here if its crash was scheduled now)
                    drops_r += 1;
                    alive[p] = !crashes_now;
                    continue;
                }
                if tx
                    .send(Down::Round {
                        round,
                        k,
                        params: global_params.clone(),
                    })
                    .is_err()
                {
                    if ft {
                        alive[p] = false; // died unannounced; respawn next round
                        continue;
                    }
                    return Err(worker_send_error(&up_rx, "a worker thread terminated early"));
                }
                comm.down_bytes += param_bytes;
                if crashes_now {
                    // the worker checks the same schedule and exits on
                    // receipt without replying; don't wait for it
                    alive[p] = false;
                } else {
                    expected[p] = true;
                }
            }
            if pipe_corr {
                // correct θ_r concurrently with the local epoch on θ_r
                creq_tx
                    .send(global_params.clone())
                    .map_err(|_| anyhow!("correction thread terminated early"))?;
            }

            drop(span_bcast);

            // ---- collect ParamsUp + RemoteFeatures ------------------------
            let span_collect = crate::obs::span_round("round.collect", round as i64);
            let mut ups: Vec<Option<ParamsUp>> = (0..parts_n).map(|_| None).collect();
            let mut late_next: Vec<Option<ParamsUp>> = (0..parts_n).map(|_| None).collect();
            let mut need: usize = expected.iter().filter(|e| **e).count();
            while need > 0 {
                let msg = if ft {
                    match up_rx.recv_timeout(LIVENESS_TIMEOUT) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => {
                            // liveness guard: whoever is still outstanding
                            // is wedged or gone; write them off and let the
                            // supervisor respawn them next round
                            for (p, e) in expected.iter_mut().enumerate() {
                                if *e {
                                    alive[p] = false;
                                    *e = false;
                                }
                            }
                            need = 0;
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => {
                            bail!("all worker threads disconnected mid-round")
                        }
                    }
                } else {
                    match up_rx.recv() {
                        Ok(m) => m,
                        Err(_) => bail!("all worker threads disconnected mid-round"),
                    }
                };
                match msg {
                    Up::Features { bytes } => comm.feature_bytes += bytes,
                    Up::Snapshot { .. } => {
                        // stale reply from a timed-out checkpoint snapshot;
                        // a protocol bug on the fault-free path
                        if !ft {
                            bail!("unexpected snapshot reply mid-round");
                        }
                    }
                    Up::Failed { part, err } => {
                        if !ft {
                            bail!("worker {part} failed: {err}");
                        }
                        let p = part as usize;
                        alive[p] = false;
                        if expected[p] {
                            expected[p] = false;
                            need -= 1;
                        }
                    }
                    Up::Round(u) => {
                        if u.round != round {
                            bail!(
                                "worker {} answered round {} during round {round}",
                                u.part,
                                u.round
                            );
                        }
                        let p = u.part as usize;
                        if expected[p] {
                            expected[p] = false;
                            need -= 1;
                        }
                        let lost = netm.dropped(u.part, round as u64, LEG_UP);
                        if lost {
                            drops_r += 1;
                        }
                        if lost || (cfg.round_timeout > 0.0 && u.net_s > cfg.round_timeout) {
                            // upload lost (it retransmits) or past the round
                            // deadline: hold for the next round's average
                            late_next[p] = Some(u);
                        } else {
                            ups[p] = Some(u);
                        }
                    }
                }
            }

            drop(span_collect);

            // ---- integrate: last round's late arrivals + this round's
            // on-time uploads (a fresh upload supersedes a stale held one,
            // which is then discarded as a drop) ----------------------------
            let mut contributors: Vec<Option<ParamsUp>> =
                (0..parts_n).map(|_| None).collect();
            for p in 0..parts_n {
                match (ups[p].take(), held[p].take()) {
                    (Some(u), stale) => {
                        if stale.is_some() {
                            drops_r += 1;
                        }
                        comm.up_bytes += param_bytes;
                        contributors[p] = Some(u);
                    }
                    (None, Some(u)) => {
                        comm.up_bytes += param_bytes;
                        contributors[p] = Some(u);
                    }
                    (None, None) => {}
                }
            }
            // quorum backfill: if fewer than K contributors made the
            // deadline, admit the late uploads with the smallest modeled
            // arrival time (tie: part id) until K is met or none remain
            if cfg.quorum > 0 {
                let mut have = contributors.iter().filter(|c| c.is_some()).count();
                let mut order: Vec<usize> = (0..parts_n)
                    .filter(|&p| contributors[p].is_none() && late_next[p].is_some())
                    .collect();
                order.sort_by(|&a, &b| {
                    let na = late_next[a].as_ref().expect("filtered").net_s;
                    let nb = late_next[b].as_ref().expect("filtered").net_s;
                    na.partial_cmp(&nb)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                for p in order {
                    if have >= cfg.quorum {
                        break;
                    }
                    comm.up_bytes += param_bytes;
                    contributors[p] = late_next[p].take();
                    have += 1;
                }
            }
            // fold per-worker stats in part order (float sums must not
            // depend on message arrival order — bit parity with sequential;
            // worker events are emitted in the same part order so the
            // sync-mode event stream matches the sequential engine's)
            let mut worker_time = 0f64;
            let mut net_time = 0f64;
            let mut loss_sum = 0f64;
            let mut loss_n = 0usize;
            for u in contributors.iter().flatten() {
                worker_time = worker_time.max(u.elapsed_s);
                net_time = net_time.max(u.net_s);
                loss_sum += u.loss_sum;
                loss_n += u.loss_n;
                ctx.emit(Event::WorkerRoundCompleted {
                    round: u.round,
                    part: u.part,
                    compute_s: u.elapsed_s,
                    net_s: u.net_s,
                });
            }
            let quorum_r = contributors.iter().filter(|c| c.is_some()).count();

            // training monitors (telemetry plane only): cross-worker
            // parameter divergence (Thm 4.3/4.4's residual quantity),
            // straggler skew, and heartbeat liveness — all read from the
            // uploads and timestamps the server already holds, never from
            // the training path
            if crate::obs::monitor::enabled() {
                let views: Vec<Vec<&[f32]>> = contributors
                    .iter()
                    .flatten()
                    .map(|u| u.params.iter().map(|t| t.data.as_slice()).collect())
                    .collect();
                let mut alerts = crate::obs::monitor::observe_divergence(round, &views);
                let times: Vec<(u32, f64)> = contributors
                    .iter()
                    .flatten()
                    .map(|u| (u.part, u.elapsed_s))
                    .collect();
                alerts.extend(crate::obs::monitor::observe_round_times(round, &times));
                alerts.extend(crate::obs::monitor::check_heartbeats(
                    round,
                    cfg.heartbeat_ms as f64 / 1000.0,
                ));
                driver::emit_alerts(ctx, alerts);
            }

            // ---- server: average (+ correct) + eval -----------------------
            let t_server = Instant::now();
            let mut phases = driver::PhaseTimes::default();
            {
                let _s = crate::obs::span_round("server.average", round as i64);
                let states: Vec<ModelState> = contributors
                    .into_iter()
                    .flatten()
                    .map(|u| ModelState {
                        params: u.params,
                        opt: Vec::new(),
                    })
                    .collect();
                if !states.is_empty() {
                    // uniform mean over whoever contributed; with zero
                    // contributors the global model carries over unchanged
                    let refs: Vec<&ModelState> = states.iter().collect();
                    ModelState::average_params_into(&mut global_params, &refs);
                }
            }
            phases.avg_s = t_server.elapsed().as_secs_f64();

            let (val_score, global_loss) = if pipe_corr {
                // the correction of θ_r overlapped the local epoch; apply
                // its delta on top of the fresh average
                let t_corr = Instant::now();
                {
                    let _s = crate::obs::span_round("server.correction", round as i64);
                    match cres_rx.recv() {
                        Ok(Ok((delta, _corr_s))) => {
                            for (g, d) in global_params.iter_mut().zip(&delta) {
                                for (gv, dv) in g.data.iter_mut().zip(&d.data) {
                                    *gv += dv;
                                }
                            }
                        }
                        Ok(Err(msg)) => bail!("server correction failed: {msg}"),
                        Err(_) => bail!("correction thread disconnected mid-round"),
                    }
                }
                phases.corr_s = t_corr.elapsed().as_secs_f64();
                ctx.emit(Event::CorrectionApplied {
                    round,
                    steps: cfg.correction_steps,
                });
                driver::eval_if_due(
                    rt,
                    &eval_name,
                    &global_params,
                    ds,
                    cfg,
                    &local_builder,
                    &mut eval_rng,
                    round,
                    &mut phases,
                    ctx,
                )?
            } else {
                // sync path: the exact epilogue the sequential driver runs
                driver::server_round_epilogue(
                    rt,
                    cfg,
                    ds,
                    &assignment,
                    dims,
                    &server_train_name,
                    &eval_name,
                    &local_builder,
                    &corr_builder,
                    inline_server_state.as_mut().expect("sync keeps state"),
                    &mut global_params,
                    &mut corr_arena,
                    inline_corr_rng.as_mut().expect("sync keeps rng"),
                    &mut eval_rng,
                    round,
                    &mut phases,
                    ctx,
                )?
            };
            let server_time = t_server.elapsed().as_secs_f64();

            // a checkpoint is a barrier: held-late uploads cannot outlive it
            // (the on-disk state must fully determine the remaining rounds),
            // and nothing is carried past the final round either way
            let ckpt_due = cfg.checkpoint_every > 0 && round % cfg.checkpoint_every == 0;
            if ckpt_due || round == cfg.rounds {
                for l in late_next.iter_mut() {
                    if l.take().is_some() {
                        drops_r += 1;
                    }
                }
            }
            held = late_next;

            cum_bytes += comm.total();
            let (wu, wd) = transport.wire_totals();
            let (wire_bytes_up, wire_bytes_down) =
                (wu - wire_up_prev, wd - wire_down_prev);
            (wire_up_prev, wire_down_prev) = (wu, wd);
            records.push(RoundRecord {
                round,
                local_steps: k,
                local_loss: if loss_n > 0 {
                    loss_sum / loss_n as f64
                } else {
                    f64::NAN
                },
                global_loss,
                val_score,
                comm,
                cum_bytes,
                worker_time_s: worker_time,
                server_time_s: server_time,
                net_time_s: net_time,
                wall_time_s: t_round.elapsed().as_secs_f64(),
                phases,
                drops: drops_r,
                respawns: respawns_r,
                quorum: quorum_r,
                wire_bytes_up,
                wire_bytes_down,
            });
            // round boundary: publish the (corrected) global model for any
            // live serving hub while the next round keeps training
            ctx.publish_params(round, &global_params);
            ctx.emit(Event::RoundCompleted(
                records.last().expect("just pushed").clone(),
            ));

            // ---- round-boundary checkpoint --------------------------------
            if ckpt_due {
                // covers the snapshot barrier (gather) plus the save I/O;
                // the save itself also records a "checkpoint.save" span
                let _s = crate::obs::span_round("checkpoint.round_barrier", round as i64);
                // gather full worker states (params + optimizer moments:
                // worker Adam state persists across rounds); dead workers
                // are recorded as such and stored as their respawn template
                let mut snaps: Vec<Option<ModelState>> =
                    (0..parts_n).map(|_| None).collect();
                let mut want = 0usize;
                for (p, tx) in down_txs.iter().enumerate() {
                    if !alive[p] {
                        continue;
                    }
                    if tx.send(Down::Snapshot).is_ok() {
                        want += 1;
                    } else if ft {
                        alive[p] = false;
                    } else {
                        return Err(worker_send_error(
                            &up_rx,
                            "a worker thread terminated early",
                        ));
                    }
                }
                while want > 0 {
                    let msg = if ft {
                        match up_rx.recv_timeout(LIVENESS_TIMEOUT) {
                            Ok(m) => m,
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => {
                                bail!("all worker threads disconnected at a checkpoint")
                            }
                        }
                    } else {
                        match up_rx.recv() {
                            Ok(m) => m,
                            Err(_) => {
                                bail!("all worker threads disconnected at a checkpoint")
                            }
                        }
                    };
                    match msg {
                        Up::Snapshot { part, state } => {
                            snaps[part as usize] = Some(*state);
                            want -= 1;
                        }
                        Up::Failed { part, err } => {
                            if !ft {
                                bail!("worker {part} failed: {err}");
                            }
                            alive[part as usize] = false;
                            want -= 1;
                        }
                        Up::Features { .. } | Up::Round(_) => {
                            bail!("unexpected worker message during a checkpoint snapshot")
                        }
                    }
                }
                // liveness-timeout stragglers count as dead like the rest
                for (p, snap) in snaps.iter().enumerate() {
                    if snap.is_none() {
                        alive[p] = false;
                    }
                }
                let worker_states: Vec<ModelState> = snaps
                    .into_iter()
                    .map(|snap| {
                        snap.unwrap_or_else(|| ModelState {
                            params: global_params.clone(),
                            opt: fresh_opt.clone(),
                        })
                    })
                    .collect();
                let dead: Vec<u32> =
                    (0..parts_n as u32).filter(|&p| !alive[p as usize]).collect();
                let ck = Checkpoint::capture(
                    cfg,
                    round,
                    cum_bytes,
                    &global_params,
                    inline_server_state.as_ref().expect("sync keeps state"),
                    &worker_states,
                    &eval_rng,
                    inline_corr_rng.as_ref().expect("sync keeps rng"),
                    &dead,
                );
                let path = ck.save(std::path::Path::new(&cfg.checkpoint_dir))?;
                ctx.emit(Event::CheckpointSaved {
                    round,
                    path: path.display().to_string(),
                });
            }
        }

        for (p, tx) in down_txs.iter().enumerate() {
            if tx.send(Down::Shutdown).is_err() && alive[p] {
                // a worker we believed alive is gone: surface the root cause
                // instead of silently swallowing the failed send
                return Err(worker_send_error(
                    &up_rx,
                    &format!("worker {p} exited before shutdown"),
                ));
            }
        }
        driver::finish_run(
            rt,
            &eval_name,
            &global_params,
            ds,
            cfg,
            &local_builder,
            &mut eval_rng,
            cut_ratio,
            records,
            Engine::Cluster,
            None,
        )
    })
}

/// Bounded-staleness asynchronous averaging: workers pull/push at their own
/// pace, the server folds each push into a running average with weight
/// `1/P`, and [`StalenessGate`] defers a worker's next pull while it is
/// more than `tau` rounds ahead of the slowest. One `RoundRecord` is
/// emitted per `P` pushes (the correction + eval cadence).
fn run_async(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    rt: &Runtime,
    setup: RunSetup,
    tau: usize,
    transport: &Transport,
    ctx: &mut RunCtx<'_>,
) -> Result<RunResult> {
    let RunSetup {
        train_name,
        server_train_name,
        eval_name,
        dims,
        assignment,
        cut_ratio,
        parts,
        mut workers,
        mut global_params,
        mut server_state,
        local_builder,
        corr_builder,
        param_bytes,
        mut eval_rng,
        mut corr_rng,
        net: netm,
    } = setup;
    if netm.has_faults() || cfg.round_timeout > 0.0 || cfg.quorum > 0 || transport.has_faults()
    {
        bail!(
            "fault injection and quorum rounds require round_mode=sync — the \
             async engine already tolerates pacing differences through its \
             staleness gate"
        );
    }
    let dir = rt.artifacts_dir().to_path_buf();
    let is_fullsync = cfg.algorithm == Algorithm::FullSync;
    let storage_sum: u64 = parts.iter().map(|p| p.storage_bytes).sum();
    let parts_n = parts.len();
    let k_for = |round: usize| {
        if is_fullsync {
            1
        } else {
            cfg.schedule.steps_for_round(round)
        }
    };
    // the server's eval overlaps worker compute in async mode: both sides
    // are budgeted as parts + 1 concurrent compute threads
    let lanes = worker_kernel_threads(cfg, parts_n + 1);

    // --- resume: a checkpoint written by either engine is a clean barrier
    // (every worker at round `base`, nothing in flight), which is exactly
    // this engine's state right after a completed window — so restoring the
    // loop-carried state and counting rounds from `base` replays the rest.
    // The admission cap below guarantees the async engine only ever *writes*
    // checkpoints at such barriers.
    let mut base = 0usize;
    let mut resume_cum_bytes = 0u64;
    let mut max_staleness = 0u64;
    if !cfg.resume.is_empty() {
        let ck = Checkpoint::load(std::path::Path::new(&cfg.resume))?;
        ck.check_compatible(cfg)?;
        if !ck.dead.is_empty() {
            bail!(
                "this checkpoint records dead workers and the async engine \
                 has no respawn path; resume it under round_mode=sync"
            );
        }
        global_params = ck.global_params;
        server_state = ck.server_state;
        workers = ck.workers;
        eval_rng = Pcg64::from_raw_state(ck.eval_rng.0, ck.eval_rng.1);
        corr_rng = Pcg64::from_raw_state(ck.corr_rng.0, ck.corr_rng.1);
        resume_cum_bytes = ck.cum_bytes;
        base = ck.round;
        // a sync-written checkpoint has no extra; staleness restarts at 0
        if let Some(ms) = ck
            .extra
            .as_ref()
            .and_then(|x| x.get("max_staleness"))
            .and_then(|v| v.as_f64())
        {
            max_staleness = ms as u64;
        }
    }

    // run-owned data every spawn borrows, for either transport
    let host = WorkerHost {
        cfg,
        ds,
        assignment: &assignment,
        netm: &netm,
        dir: dir.clone(),
        train_name: train_name.clone(),
        builder: local_builder.clone(),
        param_bytes,
    };
    std::thread::scope(|s| -> Result<RunResult> {
        let (up_tx, up_rx) = channel::<Up>();
        let down_txs: Vec<Sender<Down>> = parts
            .iter()
            .zip(workers)
            .map(|(info, state)| transport.spawn_worker(s, &host, info, state, &up_tx, lanes))
            .collect();
        drop(up_tx);

        // every worker stands at the `base` barrier (absolute round counts,
        // so schedule lookups and the tau bound work across a resume)
        let mut gate = StalenessGate::from_done(vec![base; parts_n], tau);
        // workers already sent Shutdown when they finished their rounds (a
        // second send at teardown would trip over the closed channel)
        let mut shut = vec![false; parts_n];
        let mut waiting: Vec<usize> = Vec::new();
        let mut records: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);
        // storage bytes ride the first window's comm (see sequential driver)
        let mut cum_bytes: u64 = resume_cum_bytes;
        let (mut wire_up_prev, mut wire_down_prev) = transport.wire_totals();
        let mut corr_arena = BlockArena::new();

        // window accumulators (one window = P pushes = one RoundRecord)
        let mut comm = CommStats::default();
        if base == 0 {
            comm.feature_bytes += storage_sum;
        }
        let mut loss_sum = 0f64;
        let mut loss_n = 0usize;
        let mut k_sum = 0usize;
        let mut worker_time = 0f64;
        let mut net_time = 0f64;
        // per-push averaging folds happen throughout the window; accumulate
        // them so server_time_s keeps its "averaging + correction + eval"
        // meaning from the sync engines
        let mut fold_time = 0f64;
        let mut pushes = 0usize;
        let mut t_window = Instant::now();

        // everyone starts the round after the barrier (staleness 0)
        ctx.emit(Event::RoundStarted {
            round: base + 1,
            local_steps: k_for(base + 1),
        });
        for tx in &down_txs {
            if tx
                .send(Down::Round {
                    round: base + 1,
                    k: k_for(base + 1),
                    params: global_params.clone(),
                })
                .is_err()
            {
                return Err(worker_send_error(
                    &up_rx,
                    "a worker thread terminated before the run",
                ));
            }
            comm.down_bytes += param_bytes;
        }

        while base + records.len() < cfg.rounds {
            match up_rx.recv() {
                Err(_) => bail!("all worker threads disconnected mid-run"),
                Ok(Up::Features { bytes }) => comm.feature_bytes += bytes,
                Ok(Up::Snapshot { .. }) => bail!("unexpected snapshot reply in async mode"),
                Ok(Up::Failed { part, err }) => bail!("worker {part} failed: {err}"),
                Ok(Up::Round(u)) => {
                    let p = u.part as usize;
                    comm.up_bytes += param_bytes;
                    loss_sum += u.loss_sum;
                    loss_n += u.loss_n;
                    k_sum += k_for(u.round);
                    worker_time = worker_time.max(u.elapsed_s);
                    net_time = net_time.max(u.net_s);
                    // async mode streams worker completions as they arrive
                    ctx.emit(Event::WorkerRoundCompleted {
                        round: u.round,
                        part: u.part,
                        compute_s: u.elapsed_s,
                        net_s: u.net_s,
                    });
                    // fold the push into the running average (weight 1/P)
                    let t_fold = Instant::now();
                    {
                        let _s = crate::obs::span_round(
                            "server.average",
                            (base + records.len() + 1) as i64,
                        );
                        let alpha = 1.0 / parts_n as f32;
                        for (g, w) in global_params.iter_mut().zip(&u.params) {
                            for (gv, &wv) in g.data.iter_mut().zip(&w.data) {
                                *gv += alpha * (wv - *gv);
                            }
                        }
                    }
                    fold_time += t_fold.elapsed().as_secs_f64();
                    gate.push(p);
                    waiting.push(p);
                    pushes += 1;

                    if pushes == parts_n {
                        pushes = 0;
                        let round = base + records.len() + 1;
                        let t_server = Instant::now();
                        // the per-push folds above are this window's
                        // averaging cost
                        let mut phases = driver::PhaseTimes {
                            avg_s: fold_time,
                            ..Default::default()
                        };
                        let (val_score, global_loss) = driver::server_round_epilogue(
                            rt,
                            cfg,
                            ds,
                            &assignment,
                            dims,
                            &server_train_name,
                            &eval_name,
                            &local_builder,
                            &corr_builder,
                            &mut server_state,
                            &mut global_params,
                            &mut corr_arena,
                            &mut corr_rng,
                            &mut eval_rng,
                            round,
                            &mut phases,
                            ctx,
                        )?;
                        cum_bytes += comm.total();
                        let (wu, wd) = transport.wire_totals();
                        let (wire_bytes_up, wire_bytes_down) =
                            (wu - wire_up_prev, wd - wire_down_prev);
                        (wire_up_prev, wire_down_prev) = (wu, wd);
                        records.push(RoundRecord {
                            round,
                            // mean steps actually granted to this window's
                            // pushes (workers drift across schedule rounds
                            // under tau > 0), rounded to nearest
                            local_steps: (k_sum as f64 / parts_n as f64).round()
                                as usize,
                            local_loss: if loss_n > 0 {
                                loss_sum / loss_n as f64
                            } else {
                                f64::NAN
                            },
                            global_loss,
                            val_score,
                            comm,
                            cum_bytes,
                            worker_time_s: worker_time,
                            server_time_s: fold_time + t_server.elapsed().as_secs_f64(),
                            net_time_s: net_time,
                            wall_time_s: t_window.elapsed().as_secs_f64(),
                            phases,
                            drops: 0,
                            respawns: 0,
                            quorum: parts_n,
                            wire_bytes_up,
                            wire_bytes_down,
                        });
                        // window boundary: publish for any live serving hub
                        ctx.publish_params(round, &global_params);
                        ctx.emit(Event::RoundCompleted(
                            records.last().expect("just pushed").clone(),
                        ));
                        comm = CommStats::default();
                        loss_sum = 0.0;
                        loss_n = 0;
                        k_sum = 0;
                        worker_time = 0.0;
                        net_time = 0.0;
                        fold_time = 0.0;
                        t_window = Instant::now();

                        // ---- checkpoint barrier ---------------------------
                        // the admission cap below stalls every worker at the
                        // boundary, so when this window completes all P
                        // workers are idle at `round` with nothing in flight
                        // — the same clean barrier the sync engine cuts at
                        let ckpt_due = cfg.checkpoint_every > 0
                            && round % cfg.checkpoint_every == 0
                            && round < cfg.rounds;
                        if ckpt_due {
                            let _s = crate::obs::span_round(
                                "checkpoint.round_barrier",
                                round as i64,
                            );
                            for tx in &down_txs {
                                if tx.send(Down::Snapshot).is_err() {
                                    return Err(worker_send_error(
                                        &up_rx,
                                        "a worker exited before the checkpoint barrier",
                                    ));
                                }
                            }
                            let mut snaps: Vec<Option<ModelState>> =
                                (0..parts_n).map(|_| None).collect();
                            let mut want = parts_n;
                            while want > 0 {
                                match up_rx.recv() {
                                    Ok(Up::Snapshot { part, state }) => {
                                        snaps[part as usize] = Some(*state);
                                        want -= 1;
                                    }
                                    Ok(Up::Failed { part, err }) => {
                                        bail!("worker {part} failed: {err}")
                                    }
                                    Ok(Up::Features { .. }) | Ok(Up::Round(_)) => bail!(
                                        "unexpected worker message during a \
                                         checkpoint snapshot"
                                    ),
                                    Err(_) => bail!(
                                        "all worker threads disconnected at a checkpoint"
                                    ),
                                }
                            }
                            let worker_states: Vec<ModelState> = snaps
                                .into_iter()
                                .map(|s| s.expect("all P gathered"))
                                .collect();
                            let mut ck = Checkpoint::capture(
                                cfg,
                                round,
                                cum_bytes,
                                &global_params,
                                &server_state,
                                &worker_states,
                                &eval_rng,
                                &corr_rng,
                                &[],
                            );
                            // marks the checkpoint as async-written (the sync
                            // engine refuses it) and carries the running
                            // staleness high-water mark across the resume
                            ck.extra = Some(Json::obj(vec![
                                ("mode", Json::str("async")),
                                ("max_staleness", Json::num(max_staleness as f64)),
                            ]));
                            let path =
                                ck.save(std::path::Path::new(&cfg.checkpoint_dir))?;
                            ctx.emit(Event::CheckpointSaved {
                                round,
                                path: path.display().to_string(),
                            });
                        }

                        if ctx.stopped() {
                            break; // end the run at this window boundary
                        }
                        if base + records.len() < cfg.rounds {
                            let next = base + records.len() + 1;
                            ctx.emit(Event::RoundStarted {
                                round: next,
                                local_steps: k_for(next),
                            });
                        }
                    }

                    // admit waiting workers within the staleness bound, and
                    // stall everyone at the next checkpoint boundary so the
                    // window completing it is a clean barrier
                    let cap = if cfg.checkpoint_every > 0 {
                        ((base + records.len()) / cfg.checkpoint_every + 1)
                            * cfg.checkpoint_every
                    } else {
                        usize::MAX
                    };
                    let mut i = 0;
                    while i < waiting.len() {
                        let q = waiting[i];
                        if gate.done(q) >= cfg.rounds || base + records.len() >= cfg.rounds
                        {
                            if down_txs[q].send(Down::Shutdown).is_err() {
                                return Err(worker_send_error(
                                    &up_rx,
                                    &format!("worker {q} exited before shutdown"),
                                ));
                            }
                            shut[q] = true;
                            waiting.swap_remove(i);
                        } else if gate.may_start(q) && gate.done(q) < cap {
                            max_staleness = max_staleness.max(gate.staleness(q) as u64);
                            crate::obs::gauge("cluster.staleness_hwm")
                                .set(max_staleness as f64);
                            let next = gate.done(q) + 1;
                            if down_txs[q]
                                .send(Down::Round {
                                    round: next,
                                    k: k_for(next),
                                    params: global_params.clone(),
                                })
                                .is_err()
                            {
                                return Err(worker_send_error(
                                    &up_rx,
                                    &format!("worker {q} terminated early"),
                                ));
                            }
                            comm.down_bytes += param_bytes;
                            waiting.swap_remove(i);
                        } else {
                            i += 1;
                        }
                    }
                }
            }
        }

        for (q, tx) in down_txs.iter().enumerate() {
            if !shut[q] && tx.send(Down::Shutdown).is_err() {
                // a worker died without us noticing: surface the root cause
                // instead of silently swallowing the failed send
                return Err(worker_send_error(
                    &up_rx,
                    &format!("worker {q} exited before shutdown"),
                ));
            }
        }
        driver::finish_run(
            rt,
            &eval_name,
            &global_params,
            ds,
            cfg,
            &local_builder,
            &mut eval_rng,
            cut_ratio,
            records,
            Engine::Cluster,
            Some(max_staleness),
        )
    })
}
