//! Round-boundary checkpoints: everything needed to resume a sync run
//! bit-identically — the global params, the server correction state, every
//! worker's local state (params *and* optimizer moments: worker Adam state
//! persists across rounds, FedAvg-style, so dropping it would fork the
//! stream), the sequentially-consumed RNG streams (`eval_rng`, `corr_rng`),
//! and the cumulative byte counter.
//!
//! On-disk format (`<dir>/round_<r>/`):
//!
//! - `meta.json` — round, counters, RNG raw states (hex strings: `Json`
//!   numbers are f64 and cannot hold a `u128` exactly), a shape manifest
//!   for every tensor group, and a config digest used to reject resuming
//!   under a different experiment.
//! - `tensors.bin` — every tensor's `f32` data concatenated little-endian
//!   in manifest order: global params, server params, server opt, then per
//!   worker params + opt. Bytes round-trip exactly, so a resumed run
//!   replays the remaining rounds bit-for-bit.
//!
//! Only data derived *inside* the round loop is stored. Setup-time products
//! (partition assignment, block builders, worker RNGs — which are stateless
//! per `(seed, part, round)`) are re-derived by running `setup_run` again
//! on resume, which also burns the setup RNG streams in the exact order a
//! fresh run would.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::runtime::{ModelState, Tensor};
use crate::util::{Json, Pcg64};

/// Format version, bumped on any layout change.
const VERSION: f64 = 1.0;

/// Config fields a checkpoint must agree on to be resumable: anything that
/// changes the numerical stream of the remaining rounds.
#[derive(Clone, Debug, PartialEq)]
pub struct Digest {
    pub dataset: String,
    pub arch: String,
    pub algorithm: String,
    pub optimizer: String,
    pub server_optimizer: String,
    pub partitioner: String,
    pub parts: usize,
    pub seed: u64,
    pub net: String,
}

impl Digest {
    pub fn of(cfg: &ExperimentConfig) -> Digest {
        Digest {
            dataset: cfg.dataset.clone(),
            arch: cfg.arch.clone(),
            algorithm: cfg.algorithm.name().to_string(),
            optimizer: cfg.optimizer.clone(),
            server_optimizer: cfg.server_optimizer.clone(),
            partitioner: cfg.partitioner.clone(),
            parts: cfg.parts,
            seed: cfg.seed,
            net: cfg.net.clone(),
        }
    }

    pub(crate) fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(&self.dataset)),
            ("arch", Json::str(&self.arch)),
            ("algorithm", Json::str(&self.algorithm)),
            ("optimizer", Json::str(&self.optimizer)),
            ("server_optimizer", Json::str(&self.server_optimizer)),
            ("partitioner", Json::str(&self.partitioner)),
            ("parts", Json::num(self.parts as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("net", Json::str(&self.net)),
        ])
    }

    pub(crate) fn from_json(j: &Json) -> Result<Digest> {
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or_else(|| anyhow!("checkpoint digest: missing/invalid {k}"))
        };
        let n = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("checkpoint digest: missing/invalid {k}"))
        };
        Ok(Digest {
            dataset: s("dataset")?,
            arch: s("arch")?,
            algorithm: s("algorithm")?,
            optimizer: s("optimizer")?,
            server_optimizer: s("server_optimizer")?,
            partitioner: s("partitioner")?,
            parts: n("parts")?,
            seed: n("seed")? as u64,
            net: s("net")?,
        })
    }
}

/// One resumable snapshot of a sync run at a round boundary.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// the round this state is the *result of* (resume starts at `round+1`)
    pub round: usize,
    pub cum_bytes: u64,
    pub global_params: Vec<Tensor>,
    pub server_state: ModelState,
    /// per-worker local states in part order
    pub workers: Vec<ModelState>,
    /// raw `(state, inc)` of the sequentially-consumed eval stream
    pub eval_rng: (u128, u128),
    /// raw `(state, inc)` of the correction-batch stream
    pub corr_rng: (u128, u128),
    /// parts whose worker was dead at the checkpoint boundary (crashed or
    /// failed, not yet respawned); their stored state is the respawn
    /// template (current global params + fresh optimizer). The cluster
    /// engine re-marks them dead on resume so `respawn=false` runs stay
    /// faithful; always empty for sequential-engine checkpoints.
    pub dead: Vec<u32>,
    pub digest: Digest,
    /// engine-specific extras (e.g. the async engine marks its barrier
    /// checkpoints and carries its running `max_staleness`); `None` for
    /// sync/sequential checkpoints, and older checkpoints load as `None`
    pub extra: Option<Json>,
}

fn hex_u128(x: u128) -> Json {
    Json::str(format!("{x:x}"))
}

fn parse_hex_u128(j: Option<&Json>, what: &str) -> Result<u128> {
    let s = j
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("checkpoint meta: missing/invalid {what}"))?;
    u128::from_str_radix(s, 16).with_context(|| format!("checkpoint meta: bad hex in {what}"))
}

fn shapes_json(tensors: &[Tensor]) -> Json {
    Json::arr(
        tensors
            .iter()
            .map(|t| Json::arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()))
            .collect(),
    )
}

fn shapes_from_json(j: Option<&Json>, what: &str) -> Result<Vec<Vec<usize>>> {
    let arr = j
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("checkpoint meta: missing/invalid {what}"))?;
    arr.iter()
        .map(|s| {
            s.as_array()
                .ok_or_else(|| anyhow!("checkpoint meta: bad shape in {what}"))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| anyhow!("checkpoint meta: bad dim in {what}"))
                })
                .collect()
        })
        .collect()
}

/// Append every tensor's `f32` data little-endian (shared with the wire
/// protocol in `transport/wire.rs`, so frames and checkpoints round-trip
/// parameters through the identical byte layout).
pub(crate) fn push_tensors(buf: &mut Vec<u8>, tensors: &[Tensor]) {
    for t in tensors {
        for &x in &t.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Consume the next tensors from `bytes` per `shapes`, advancing `off`.
pub(crate) fn take_tensors(
    bytes: &[u8],
    off: &mut usize,
    shapes: &[Vec<usize>],
) -> Result<Vec<Tensor>> {
    let mut out = Vec::with_capacity(shapes.len());
    for shape in shapes {
        let numel: usize = shape.iter().product();
        let need = numel * 4;
        if *off + need > bytes.len() {
            bail!("checkpoint tensors.bin truncated (need {need} bytes at offset {off})");
        }
        let data: Vec<f32> = bytes[*off..*off + need]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        *off += need;
        out.push(Tensor {
            shape: shape.clone(),
            data,
        });
    }
    Ok(out)
}

/// `<dir>/round_<r>`
pub fn round_dir(dir: &Path, round: usize) -> PathBuf {
    dir.join(format!("round_{round}"))
}

impl Checkpoint {
    /// Capture the round-boundary state. RNGs are cloned out via their raw
    /// state, so the live streams are unaffected.
    #[allow(clippy::too_many_arguments)]
    pub fn capture(
        cfg: &ExperimentConfig,
        round: usize,
        cum_bytes: u64,
        global_params: &[Tensor],
        server_state: &ModelState,
        workers: &[ModelState],
        eval_rng: &Pcg64,
        corr_rng: &Pcg64,
        dead: &[u32],
    ) -> Checkpoint {
        Checkpoint {
            round,
            cum_bytes,
            global_params: global_params.to_vec(),
            server_state: server_state.clone(),
            workers: workers.to_vec(),
            eval_rng: eval_rng.raw_state(),
            corr_rng: corr_rng.raw_state(),
            dead: dead.to_vec(),
            digest: Digest::of(cfg),
            extra: None,
        }
    }

    /// Write `<dir>/round_<round>/{meta.json,tensors.bin}`; returns the
    /// round directory. `tensors.bin` lands before `meta.json`, so a
    /// directory with a readable meta is always complete.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        let _s = crate::obs::span_round("checkpoint.save", self.round as i64);
        let rd = round_dir(dir, self.round);
        std::fs::create_dir_all(&rd)
            .with_context(|| format!("creating checkpoint dir {}", rd.display()))?;

        let mut bin = Vec::new();
        push_tensors(&mut bin, &self.global_params);
        push_tensors(&mut bin, &self.server_state.params);
        push_tensors(&mut bin, &self.server_state.opt);
        for w in &self.workers {
            push_tensors(&mut bin, &w.params);
            push_tensors(&mut bin, &w.opt);
        }
        let bin_path = rd.join("tensors.bin");
        let mut f = std::fs::File::create(&bin_path)
            .with_context(|| format!("creating {}", bin_path.display()))?;
        f.write_all(&bin)
            .with_context(|| format!("writing {}", bin_path.display()))?;

        // all workers share one shape manifest (they start from one init)
        let w0 = self
            .workers
            .first()
            .ok_or_else(|| anyhow!("checkpoint with zero workers"))?;
        let meta = Json::obj(vec![
            ("version", Json::num(VERSION)),
            ("round", Json::num(self.round as f64)),
            ("cum_bytes", hex_u128(self.cum_bytes as u128)),
            (
                "eval_rng",
                Json::arr(vec![hex_u128(self.eval_rng.0), hex_u128(self.eval_rng.1)]),
            ),
            (
                "corr_rng",
                Json::arr(vec![hex_u128(self.corr_rng.0), hex_u128(self.corr_rng.1)]),
            ),
            ("digest", self.digest.to_json()),
            ("global_shapes", shapes_json(&self.global_params)),
            ("server_param_shapes", shapes_json(&self.server_state.params)),
            ("server_opt_shapes", shapes_json(&self.server_state.opt)),
            ("worker_param_shapes", shapes_json(&w0.params)),
            ("worker_opt_shapes", shapes_json(&w0.opt)),
            ("workers", Json::num(self.workers.len() as f64)),
            (
                "dead",
                Json::arr(self.dead.iter().map(|&p| Json::num(p as f64)).collect()),
            ),
        ]);
        let meta = match &self.extra {
            Some(x) => {
                let mut pairs: Vec<(&str, Json)> = meta
                    .as_object()
                    .expect("meta is an object")
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect();
                pairs.push(("extra", x.clone()));
                Json::obj(pairs)
            }
            None => meta,
        };
        let meta_path = rd.join("meta.json");
        let meta_text = meta.to_string_pretty();
        std::fs::write(&meta_path, &meta_text)
            .with_context(|| format!("writing {}", meta_path.display()))?;
        crate::obs::counter("checkpoint.saves").inc();
        crate::obs::counter("checkpoint.bytes_written")
            .add((bin.len() + meta_text.len()) as u64);
        Ok(rd)
    }

    /// Load from `path`: either a `round_<r>` directory itself, or a parent
    /// checkpoint directory (the highest complete round wins).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let _s = crate::obs::span("checkpoint.load");
        let rd = resolve_round_dir(path)?;
        let meta_path = rd.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let meta = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e:?}", meta_path.display()))?;
        let version = meta.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        if version != VERSION {
            bail!(
                "checkpoint {}: format version {version} (this build reads {VERSION})",
                rd.display()
            );
        }
        let round = meta
            .get("round")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("checkpoint meta: missing round"))?;
        let cum_bytes = parse_hex_u128(meta.get("cum_bytes"), "cum_bytes")? as u64;
        let rng_pair = |k: &str| -> Result<(u128, u128)> {
            let arr = meta
                .get(k)
                .and_then(Json::as_array)
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow!("checkpoint meta: missing/invalid {k}"))?;
            Ok((
                parse_hex_u128(Some(&arr[0]), k)?,
                parse_hex_u128(Some(&arr[1]), k)?,
            ))
        };
        let eval_rng = rng_pair("eval_rng")?;
        let corr_rng = rng_pair("corr_rng")?;
        let digest = Digest::from_json(
            meta.get("digest")
                .ok_or_else(|| anyhow!("checkpoint meta: missing digest"))?,
        )?;
        let global_shapes = shapes_from_json(meta.get("global_shapes"), "global_shapes")?;
        let server_param_shapes =
            shapes_from_json(meta.get("server_param_shapes"), "server_param_shapes")?;
        let server_opt_shapes =
            shapes_from_json(meta.get("server_opt_shapes"), "server_opt_shapes")?;
        let worker_param_shapes =
            shapes_from_json(meta.get("worker_param_shapes"), "worker_param_shapes")?;
        let worker_opt_shapes =
            shapes_from_json(meta.get("worker_opt_shapes"), "worker_opt_shapes")?;
        let n_workers = meta
            .get("workers")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("checkpoint meta: missing workers"))?;
        let dead: Vec<u32> = meta
            .get("dead")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow!("checkpoint meta: missing dead"))?
            .iter()
            .map(|p| {
                p.as_usize()
                    .map(|p| p as u32)
                    .ok_or_else(|| anyhow!("checkpoint meta: bad part id in dead"))
            })
            .collect::<Result<_>>()?;

        let bin_path = rd.join("tensors.bin");
        let bytes = std::fs::read(&bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        let mut off = 0usize;
        let global_params = take_tensors(&bytes, &mut off, &global_shapes)?;
        let server_state = ModelState {
            params: take_tensors(&bytes, &mut off, &server_param_shapes)?,
            opt: take_tensors(&bytes, &mut off, &server_opt_shapes)?,
        };
        let mut workers = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            workers.push(ModelState {
                params: take_tensors(&bytes, &mut off, &worker_param_shapes)?,
                opt: take_tensors(&bytes, &mut off, &worker_opt_shapes)?,
            });
        }
        if off != bytes.len() {
            bail!(
                "checkpoint {}: tensors.bin has {} trailing bytes",
                rd.display(),
                bytes.len() - off
            );
        }
        crate::obs::counter("checkpoint.loads").inc();
        crate::obs::counter("checkpoint.bytes_read").add((bytes.len() + text.len()) as u64);
        Ok(Checkpoint {
            round,
            cum_bytes,
            global_params,
            server_state,
            workers,
            eval_rng,
            corr_rng,
            dead,
            digest,
            extra: meta.get("extra").cloned(),
        })
    }

    /// Refuse to resume under a config that would fork the numerical
    /// stream of the remaining rounds.
    pub fn check_compatible(&self, cfg: &ExperimentConfig) -> Result<()> {
        let now = Digest::of(cfg);
        if self.digest != now {
            bail!(
                "checkpoint was written by a different experiment:\n  saved: {:?}\n  now:   {now:?}",
                self.digest
            );
        }
        if self.workers.len() != cfg.parts {
            bail!(
                "checkpoint has {} worker states but parts={}",
                self.workers.len(),
                cfg.parts
            );
        }
        if self.round >= cfg.rounds {
            bail!(
                "checkpoint is at round {} but the run only has {} rounds — nothing to resume",
                self.round,
                cfg.rounds
            );
        }
        Ok(())
    }
}

/// `path` is either a round dir (has `meta.json`) or a parent holding
/// `round_<r>` subdirectories — pick the highest complete round.
fn resolve_round_dir(path: &Path) -> Result<PathBuf> {
    if path.join("meta.json").is_file() {
        return Ok(path.to_path_buf());
    }
    let entries = std::fs::read_dir(path)
        .with_context(|| format!("reading checkpoint dir {}", path.display()))?;
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(r) = name
            .to_str()
            .and_then(|n| n.strip_prefix("round_"))
            .and_then(|n| n.parse::<usize>().ok())
        else {
            continue;
        };
        if !entry.path().join("meta.json").is_file() {
            continue; // partial write: tensors.bin lands first
        }
        if best.as_ref().map(|(br, _)| r > *br).unwrap_or(true) {
            best = Some((r, entry.path()));
        }
    }
    best.map(|(_, p)| p).ok_or_else(|| {
        anyhow!(
            "{}: not a checkpoint (no meta.json, no round_<r> subdirectory with one)",
            path.display()
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let numel: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..numel).map(|_| rng.f32() * 2.0 - 1.0).collect(),
        }
    }

    fn state(seed: u64) -> ModelState {
        ModelState {
            params: vec![tensor(&[4, 3], seed), tensor(&[3], seed + 1)],
            opt: vec![tensor(&[4, 3], seed + 2), tensor(&[4, 3], seed + 3)],
        }
    }

    fn sample_checkpoint(round: usize) -> Checkpoint {
        let cfg = ExperimentConfig::default();
        let mut eval_rng = Pcg64::new(4);
        let mut corr_rng = Pcg64::new(5);
        eval_rng.next_u64(); // mid-stream states must round-trip
        corr_rng.next_u64();
        corr_rng.next_u64();
        Checkpoint::capture(
            &cfg,
            round,
            123_456_789,
            &[tensor(&[4, 3], 1), tensor(&[3], 2)],
            &state(10),
            &(0..cfg.parts).map(|p| state(20 + p as u64)).collect::<Vec<_>>(),
            &eval_rng,
            &corr_rng,
            &[1],
        )
    }

    fn assert_states_eq(a: &ModelState, b: &ModelState) {
        assert_eq!(a.params.len(), b.params.len());
        for (x, y) in a.params.iter().zip(&b.params).chain(a.opt.iter().zip(&b.opt)) {
            assert_eq!(x.shape, y.shape);
            let same = x
                .data
                .iter()
                .zip(&y.data)
                .all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(same, "tensor bits diverged through save/load");
        }
    }

    #[test]
    fn save_load_roundtrip_is_bit_exact() {
        let dir = std::env::temp_dir().join(format!("llcg_ckpt_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ck = sample_checkpoint(3);
        let rd = ck.save(&dir).unwrap();
        assert!(rd.ends_with("round_3"));

        // load via the round dir and via the parent (same result)
        for path in [rd.clone(), dir.clone()] {
            let got = Checkpoint::load(&path).unwrap();
            assert_eq!(got.round, 3);
            assert_eq!(got.cum_bytes, ck.cum_bytes);
            assert_eq!(got.eval_rng, ck.eval_rng);
            assert_eq!(got.corr_rng, ck.corr_rng);
            assert_eq!(got.dead, vec![1]);
            assert_eq!(got.digest, ck.digest);
            assert_eq!(got.workers.len(), ck.workers.len());
            for (a, b) in got.global_params.iter().zip(&ck.global_params) {
                assert_eq!(a.shape, b.shape);
                assert!(a.data.iter().zip(&b.data).all(|(p, q)| p.to_bits() == q.to_bits()));
            }
            assert_states_eq(&got.server_state, &ck.server_state);
            for (a, b) in got.workers.iter().zip(&ck.workers) {
                assert_states_eq(a, b);
            }
            // restored RNGs continue the stream exactly
            let mut live = Pcg64::new(4);
            live.next_u64();
            let mut restored = Pcg64::from_raw_state(got.eval_rng.0, got.eval_rng.1);
            for _ in 0..16 {
                assert_eq!(live.next_u64(), restored.next_u64());
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parent_dir_resolves_to_latest_round() {
        let dir = std::env::temp_dir().join(format!("llcg_ckpt_latest_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        sample_checkpoint(2).save(&dir).unwrap();
        sample_checkpoint(7).save(&dir).unwrap();
        sample_checkpoint(4).save(&dir).unwrap();
        // a partial round (no meta.json) is skipped
        std::fs::create_dir_all(round_dir(&dir, 9)).unwrap();
        std::fs::write(round_dir(&dir, 9).join("tensors.bin"), b"partial").unwrap();
        let got = Checkpoint::load(&dir).unwrap();
        assert_eq!(got.round, 7);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compatibility_check_rejects_config_drift() {
        let ck = sample_checkpoint(3);
        let mut cfg = ExperimentConfig::default();
        cfg.rounds = 10;
        ck.check_compatible(&cfg).unwrap();
        let mut other = cfg.clone();
        other.seed = 99;
        assert!(ck.check_compatible(&other).is_err());
        let mut other = cfg.clone();
        other.arch = "sage".into();
        assert!(ck.check_compatible(&other).is_err());
        let mut other = cfg.clone();
        other.rounds = 3; // checkpoint already at the last round
        assert!(ck.check_compatible(&other).is_err());
    }

    #[test]
    fn load_rejects_non_checkpoints() {
        let dir = std::env::temp_dir().join(format!("llcg_ckpt_empty_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Checkpoint::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("not a checkpoint"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
