//! `llcg` — CLI for the LLCG distributed GNN training framework.
//!
//! Subcommands:
//!   run [--config file.json] [--key=value ...]   one distributed run
//!       `llcg run --help` prints the full config-key table (generated
//!       from the single-source schema in `api::keys`)
//!   sweep --sweep key=v1,v2[,...] [...]          config-grid sweep: the first
//!                                                --sweep axis spans the grid,
//!                                                further --sweep axes cross it;
//!                                                all other flags form the base
//!                                                config; prints one summary row
//!                                                per point
//!   serve [--requests N] [--clients K] [...]     train (publishing per-round
//!                                                snapshots), then load-test the
//!                                                micro-batching inference server
//!   infer [--nodes 1,2,3 | --split val]          train, then score nodes through
//!                                                the cached inference engine
//!   worker --connect ADDR --rank P               cluster worker process; spawned
//!                                                by the server when
//!                                                transport=tcp|uds (internal)
//!   datasets                                     registry listing + Table-2 stats
//!   partition --dataset D --parts P              partitioner comparison
//!   repro-<exp>                                  regenerate a paper table/figure
//!                                                (fig2, fig4, table1, fig5,
//!                                                 fig6, fig78, fig9, fig10,
//!                                                 fig11, theory, fig1)
//!
//! Hand-rolled flag parsing (offline environment has no clap; DESIGN.md
//! §Substitutions). Flags are `--key value` or `--key=value`.
//!
//! `run` streams its output through the session API: the per-round table is
//! printed as `Event`s arrive, not after the run completes.

use anyhow::{bail, Result};

use llcg::api::{keys, registry, Event, ExperimentBuilder, Sweep, TablePrinter};
use llcg::util::Json;
use llcg::config::ExperimentConfig;
use llcg::coordinator::driver;
use llcg::experiments;
use llcg::graph::Labels;
use llcg::partition;
use llcg::runtime::{KernelCtx, Runtime};
use llcg::serve::{
    run_load, InferenceEngine, LoadMode, LoadSpec, ServeConfig, Server, SnapshotHub,
};
use llcg::util::Pcg64;

fn parse_flags(args: &[String]) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.push((k.to_string(), v.to_string()));
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.push((stripped.to_string(), args[i + 1].clone()));
                i += 1;
            } else {
                out.push((stripped.to_string(), "true".to_string()));
            }
        } else {
            bail!("unexpected positional argument {a:?}");
        }
        i += 1;
    }
    Ok(out)
}

/// Fold `--config` + `--key=value` flags into a config. `skip` names the
/// subcommand's own structural flags (e.g. `sweep`); anything else unknown
/// still fails loudly through the key schema — `llcg run --sweep ...` is an
/// error, not a silently ignored axis.
fn build_config(flags: &[(String, String)], skip: &[&str]) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    for (k, v) in flags {
        if k == "config" {
            cfg = ExperimentConfig::from_file(v).map_err(|e| anyhow::anyhow!(e))?;
        }
    }
    for (k, v) in flags {
        if k == "config" || k == "out" || skip.contains(&k.as_str()) {
            continue;
        }
        cfg.apply_override(k, v).map_err(|e| anyhow::anyhow!(e))?;
    }
    Ok(cfg)
}

fn run_help() {
    println!(
        "usage: llcg run [--config file.json] [--key=value ...] [--out result.json]\n\
         \n\
         Observability (structural flags, not config keys):\n\
         \x20 --trace trace.json       span tracing on; write a Chrome/Perfetto\n\
         \x20                          trace at the end of the run\n\
         \x20 --log-json events.jsonl  stream every run event as one JSON line,\n\
         \x20                          plus end-of-run span summaries + metrics\n\
         \x20 --metrics                print the metrics table after the run\n\
         \x20 --listen 127.0.0.1:9184  live telemetry plane: serve /metrics\n\
         \x20                          (Prometheus), /health, /run, /series over\n\
         \x20                          HTTP while the run is alive, and turn the\n\
         \x20                          training monitors on (port 0 picks a free\n\
         \x20                          port; the bound address is printed). With\n\
         \x20                          --out, the sampled time series is embedded\n\
         \x20                          in the result JSON as \"series\"\n\
         \n\
         Config keys (generated from the api::keys schema; every key works\n\
         both as a JSON field and as a --key=value override):\n\
         {}",
        keys::help_table()
    );
}

/// Pull the obs flags (`--trace <path>`, `--log-json <path>`, `--metrics`,
/// `--listen <addr>`) out of a flag list: run-structural, like `--out` —
/// not config keys.
struct ObsFlags {
    trace: Option<String>,
    log_json: Option<String>,
    metrics: bool,
    listen: Option<String>,
}

const OBS_FLAG_NAMES: &[&str] = &["trace", "log-json", "metrics", "listen"];

impl ObsFlags {
    fn parse(flags: &[(String, String)]) -> ObsFlags {
        let find = |name: &str| {
            flags
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v.clone())
        };
        ObsFlags {
            trace: find("trace"),
            log_json: find("log-json"),
            metrics: find("metrics").is_some_and(|v| v != "false"),
            listen: find("listen"),
        }
    }

    /// Enable tracing and open the event log; call before the run starts.
    fn begin(&self) -> Result<Option<llcg::obs::JsonlLog>> {
        if self.trace.is_some() {
            llcg::obs::set_enabled(true);
        }
        Ok(match &self.log_json {
            Some(p) => {
                let mut log = llcg::obs::JsonlLog::create(std::path::Path::new(p))?;
                // first line of every log file: who wrote it, for which
                // config (schema v4 run-metadata header)
                log.write_header()?;
                Some(log)
            }
            None => None,
        })
    }

    /// Write the trace file, span summaries, metrics dump, and `--metrics`
    /// table; call after the run finishes.
    fn finish(&self, mut log: Option<llcg::obs::JsonlLog>) -> Result<()> {
        if self.trace.is_some() || log.is_some() {
            llcg::obs::set_enabled(false);
            let spans = llcg::obs::take_spans();
            if let Some(path) = &self.trace {
                let p = std::path::Path::new(path);
                if let Some(dir) = p.parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)?;
                    }
                }
                // worker processes that flushed spans over the transport get
                // their own named Perfetto track; with none the trace stays
                // the plain single-process shape
                let remote = llcg::transport::take_remote_spans();
                let (json, n) = if remote.is_empty() {
                    (llcg::obs::chrome_trace_json(&spans), spans.len())
                } else {
                    let n = spans.len() + remote.iter().map(|(_, s)| s.len()).sum::<usize>();
                    let mut tracks = vec![("server".to_string(), spans.clone())];
                    tracks.extend(remote);
                    (llcg::obs::chrome_trace_json_multi(&tracks), n)
                };
                std::fs::write(p, json.to_string_pretty())?;
                eprintln!("trace: wrote {n} spans to {path}");
            }
            if let Some(log) = log.as_mut() {
                log.write_span_summaries(&llcg::obs::summarize(&spans))?;
                log.write_metrics()?;
                log.flush()?;
                eprintln!("log-json: wrote {} lines to {}", log.lines(), log.path().display());
            }
        }
        if self.metrics {
            print!("{}", llcg::obs::metrics_table());
        }
        Ok(())
    }
}

/// The live telemetry plane behind `--listen <addr>`: the HTTP exposition
/// server (`/metrics` `/health` `/run` `/series`), the rolling registry
/// sampler, and the training monitors. Exists only while the flag is
/// given — without it there is no socket, no thread, and the monitor hook
/// sites cost one relaxed atomic load each.
struct Telemetry {
    exporter: llcg::obs::Exporter,
    sampler: Option<llcg::obs::Sampler>,
    ring: llcg::obs::SeriesRing,
    health: llcg::obs::RunHealth,
    /// workers seen since the last round boundary (feeds `live_workers`)
    round_workers: usize,
}

impl Telemetry {
    fn start(addr: &str, engine: &str, parts: usize, rounds: usize) -> Result<Telemetry> {
        let exporter = llcg::obs::Exporter::bind(addr)
            .map_err(|e| anyhow::anyhow!("--listen {addr}: {e}"))?;
        let sampler = llcg::obs::Sampler::start(
            llcg::obs::timeseries::DEFAULT_INTERVAL_MS,
            llcg::obs::timeseries::DEFAULT_CAPACITY,
        );
        let ring = sampler.ring();
        exporter.attach_series(ring.clone());
        llcg::obs::monitor::reset();
        llcg::obs::monitor::set_enabled(true);
        let health = llcg::obs::RunHealth::new(engine, parts, rounds);
        exporter.set_health(health.clone());
        // port 0 resolves here; scrapers parse this line for the address
        eprintln!(
            "listen: telemetry on http://{} (/metrics /health /run /series)",
            exporter.addr()
        );
        Ok(Telemetry {
            exporter,
            sampler: Some(sampler),
            ring,
            health,
            round_workers: 0,
        })
    }

    /// Mirror one run event into the `/run` tail and `/health` snapshot.
    fn on_event(&mut self, ev: &Event) {
        match ev {
            Event::RoundStarted { .. } => {
                self.health.state = "running".into();
                self.round_workers = 0;
            }
            Event::WorkerRoundCompleted { .. } => self.round_workers += 1,
            Event::RoundCompleted(r) => {
                self.health.last_round = r.round;
                if self.round_workers > 0 {
                    self.health.live_workers = self.round_workers;
                }
                self.health.staleness_hwm =
                    llcg::obs::gauge("cluster.staleness_hwm").get() as u64;
            }
            Event::MonitorAlert { .. } => self.health.alerts += 1,
            _ => {}
        }
        self.exporter.push_event(ev.to_json());
        self.exporter.set_health(self.health.clone());
    }

    fn set_state(&mut self, state: &str) {
        self.health.state = state.into();
        self.exporter.set_health(self.health.clone());
    }

    /// Stop the sampler (one final sample), publish the terminal health
    /// state, and return the ring for the `--out` dump. The exporter keeps
    /// serving until this struct drops, so late scrapes still land.
    fn finish(&mut self, state: &str) -> llcg::obs::SeriesRing {
        if let Some(s) = self.sampler.take() {
            s.stop();
        }
        llcg::obs::monitor::set_enabled(false);
        self.set_state(state);
        self.ring.clone()
    }
}

fn cmd_run(flags: &[(String, String)]) -> Result<()> {
    if flags.iter().any(|(k, _)| k == "help") {
        run_help();
        return Ok(());
    }
    let cfg = build_config(flags, OBS_FLAG_NAMES)?;
    let obs_flags = ObsFlags::parse(flags);
    let (rt, _adir) = Runtime::load_or_native(&cfg.artifacts_dir)?;
    let exp = ExperimentBuilder::from_config(cfg).build()?;
    let cfg = exp.config();
    eprintln!(
        "run: {} on {} ({} parts, {} rounds, arch={}, opt={}, backend={}, \
         engine={}, mode={}, net={}, transport={})",
        cfg.algorithm.name(),
        cfg.dataset,
        cfg.parts,
        cfg.rounds,
        cfg.arch,
        cfg.optimizer,
        rt.backend_name(),
        cfg.engine.name(),
        cfg.round_mode.name(),
        cfg.net,
        cfg.transport
    );

    // stream the run: one table row per completed round, as it happens
    llcg::obs::set_config_digest(&keys::config_fingerprint(cfg));
    let mut telemetry = match &obs_flags.listen {
        Some(addr) => Some(Telemetry::start(addr, cfg.engine.name(), cfg.parts, cfg.rounds)?),
        None => None,
    };
    let mut printer = TablePrinter::new();
    let mut event_log = obs_flags.begin()?;
    let result = exp.launch(&rt).stream(|ev| {
        if let Some(t) = telemetry.as_mut() {
            t.on_event(ev);
        }
        if let Some(log) = event_log.as_mut() {
            // best-effort: a full disk must not kill the training run
            let _ = log.write(ev.to_json());
        }
        printer.on_event(ev)
    })?;
    obs_flags.finish(event_log)?;
    let series = telemetry.as_mut().map(|t| t.finish("finished"));

    println!(
        "final: val={:.4} test={:.4} cut_ratio={:.3} avg_round_MB={:.3}",
        result.final_val,
        result.final_test,
        result.cut_ratio,
        result.avg_round_mb()
    );
    let wall: f64 = result.records.iter().map(|r| r.wall_time_s).sum();
    let net: f64 = result.records.iter().map(|r| r.net_time_s).sum();
    println!(
        "time: measured wall {:.3}s, modeled net {:.3}s (engine={})",
        wall, net, result.engine
    );
    if let Some(s) = result.max_staleness {
        println!("staleness: max observed {s}");
    }
    for (k, v) in flags {
        if k == "out" {
            std::fs::create_dir_all(
                std::path::Path::new(v).parent().unwrap_or(std::path::Path::new(".")),
            )?;
            let mut out = result.to_json();
            // --listen + --out: embed the sampled registry time series so
            // the live `/series` view survives the run as a plot source
            if let (Some(ring), Json::Object(m)) = (&series, &mut out) {
                m.insert("series".into(), ring.to_json());
            }
            std::fs::write(v, out.to_string_pretty())?;
            eprintln!("wrote {v}");
        }
    }
    Ok(())
}

/// `llcg sweep --sweep key=v1,v2[,...] [--sweep key2=...] [base flags]` —
/// the ROADMAP axis grammar straight to `Sweep::over`/`cross`, with one
/// summary row per point. Dataset + partition are loaded once and shared
/// across points (the sweep layer's caches).
fn cmd_sweep(flags: &[(String, String)]) -> Result<()> {
    let mut axes: Vec<(String, Vec<String>)> = Vec::new();
    for (k, v) in flags {
        if k != "sweep" {
            continue;
        }
        let (axis, values) = v.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("--sweep wants key=v1,v2,... (got {v:?})")
        })?;
        let values: Vec<String> = values.split(',').map(str::to_string).collect();
        if axis.is_empty() || values.iter().any(String::is_empty) {
            bail!("--sweep wants key=v1,v2,... (got {v:?})");
        }
        axes.push((axis.to_string(), values));
    }
    if axes.is_empty() {
        bail!(
            "usage: llcg sweep --sweep key=v1,v2[,...] [--sweep key2=...] \
             [--config file.json] [--key=value ...] [--out results.json]"
        );
    }
    let base = build_config(flags, &["sweep"])?;
    let mut sweep = Sweep::over(&base, &axes[0].0, &axes[0].1);
    for (axis, values) in &axes[1..] {
        sweep = sweep.cross(axis, values);
    }
    // validate every point's config up front so a typo fails fast
    for i in 0..sweep.len() {
        sweep.config(i).map_err(|e| anyhow::anyhow!("point {i}: {e:#}"))?;
    }
    let (rt, adir) = Runtime::load_or_native(&base.artifacts_dir)?;
    eprintln!(
        "sweep: {} points on {} (backend={}, artifacts: {adir})",
        sweep.len(),
        base.dataset,
        rt.backend_name()
    );
    println!(
        "{:<36} {:>9} {:>9} {:>12} {:>9}",
        "point", "final_val", "final_test", "avg_round_MB", "wall_s"
    );
    let results = sweep.run(&rt, |i, _exp, res| {
        let label: Vec<String> = sweep
            .patch(i)
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        let wall: f64 = res.records.iter().map(|r| r.wall_time_s).sum();
        println!(
            "{:<36} {:>9.4} {:>9.4} {:>12.3} {:>9.3}",
            label.join(" "),
            res.final_val,
            res.final_test,
            res.avg_round_mb(),
            wall
        );
    })?;
    for (k, v) in flags {
        if k == "out" {
            std::fs::create_dir_all(
                std::path::Path::new(v).parent().unwrap_or(std::path::Path::new(".")),
            )?;
            let j = Json::arr(results.iter().map(|r| r.to_json()).collect());
            std::fs::write(v, j.to_string_pretty())?;
            eprintln!("wrote {v}");
        }
    }
    Ok(())
}

/// `llcg serve [config flags] [--requests N] [--clients K] [--mode
/// closed|open] [--rate RPS]` — train (publishing a serving snapshot every
/// round), start the micro-batching inference server over the final hub
/// state, and drive it with the deterministic load generator.
fn cmd_serve(flags: &[(String, String)]) -> Result<()> {
    let cfg = build_config(
        flags,
        &["requests", "clients", "mode", "rate", "trace", "log-json", "metrics", "listen"],
    )?;
    let obs_flags = ObsFlags::parse(flags);
    let mut requests = 2000usize;
    let mut clients = 4usize;
    let mut mode = "closed".to_string();
    let mut rate = 2000.0f64;
    for (k, v) in flags {
        match k.as_str() {
            "requests" => requests = v.parse()?,
            "clients" => clients = v.parse()?,
            "mode" => mode = v.clone(),
            "rate" => rate = v.parse()?,
            _ => {}
        }
    }
    let load_mode = match mode.as_str() {
        "closed" => LoadMode::Closed,
        "open" => LoadMode::Open { rate_rps: rate },
        other => bail!("--mode wants closed|open (got {other:?})"),
    };

    let (rt, _adir) = Runtime::load_or_native(&cfg.artifacts_dir)?;
    let exp = ExperimentBuilder::from_config(cfg).build()?;
    let cfg = exp.config();
    let hub = SnapshotHub::new();
    eprintln!(
        "serve: training {} on {} ({} parts, {} rounds, engine={}) with per-round \
         snapshot publication",
        cfg.algorithm.name(),
        cfg.dataset,
        cfg.parts,
        cfg.rounds,
        cfg.engine.name()
    );
    llcg::obs::set_config_digest(&keys::config_fingerprint(cfg));
    let mut telemetry = match &obs_flags.listen {
        Some(addr) => Some(Telemetry::start(addr, cfg.engine.name(), cfg.parts, cfg.rounds)?),
        None => None,
    };
    let mut printer = TablePrinter::new();
    let mut event_log = obs_flags.begin()?;
    let result = exp
        .launch(&rt)
        .publish_to(hub.clone())?
        .stream(|ev| {
            if let Some(t) = telemetry.as_mut() {
                t.on_event(ev);
            }
            if let Some(log) = event_log.as_mut() {
                let _ = log.write(ev.to_json());
            }
            printer.on_event(ev)
        })?;
    if let Some(t) = telemetry.as_mut() {
        // training is done; /metrics and /health stay up through the
        // load-test so the serve-path histograms are scrapeable live
        t.set_state("serving");
    }
    eprintln!(
        "trained: final val={:.4} test={:.4}; snapshots published: {}",
        result.final_val,
        result.final_test,
        hub.version()
    );

    let ds = exp.dataset().clone();
    let scfg = ServeConfig::from_experiment(exp.config());
    let server = Server::start(hub, ds.clone(), scfg)?;
    let nodes: Vec<u32> = (0..ds.n() as u32).collect();
    let spec = LoadSpec {
        mode: load_mode,
        clients,
        requests,
        seed: exp.config().seed,
    };
    eprintln!(
        "serving: batch<= {}, flush {}us, {} kernel lanes, queue {}{}; load: {mode} x{clients} clients",
        scfg.max_batch,
        scfg.flush_us,
        scfg.threads,
        scfg.queue,
        if scfg.shed { " (shedding)" } else { "" }
    );
    let client = server.client();
    let report = run_load(&client, &nodes, &spec);
    println!("{report}");
    let stats = server.stats();
    println!(
        "server: {} requests in {} batches (mean batch {:.1}, max {}), {} snapshot swaps, \
         {} rejected, {} shed",
        stats.requests,
        stats.batches,
        stats.mean_batch(),
        stats.max_batch,
        stats.swaps,
        stats.rejected,
        stats.shed
    );
    drop(client);
    server.shutdown();
    // finish after shutdown so the dispatcher's serve.* spans and latency
    // histograms make it into the trace / metrics table
    obs_flags.finish(event_log)?;
    if let Some(t) = telemetry.as_mut() {
        t.finish("finished");
    }
    Ok(())
}

/// `llcg infer [config flags] [--nodes 1,2,3 | --split val --limit N]` —
/// train, snapshot the final model, and score nodes through the cached
/// inference engine (bit-identical to the eval path).
fn cmd_infer(flags: &[(String, String)]) -> Result<()> {
    let cfg = build_config(flags, &["nodes", "split", "limit"])?;
    let mut explicit_nodes: Option<Vec<u32>> = None;
    let mut split = "val".to_string();
    let mut limit = 16usize;
    for (k, v) in flags {
        match k.as_str() {
            "nodes" => {
                explicit_nodes = Some(
                    v.split(',')
                        .map(|s| s.trim().parse::<u32>())
                        .collect::<std::result::Result<Vec<u32>, _>>()
                        .map_err(|e| anyhow::anyhow!("--nodes wants id,id,...: {e}"))?,
                );
            }
            "split" => split = v.clone(),
            "limit" => limit = v.parse()?,
            _ => {}
        }
    }
    let (rt, _adir) = Runtime::load_or_native(&cfg.artifacts_dir)?;
    let exp = ExperimentBuilder::from_config(cfg).build()?;
    let hub = SnapshotHub::new();
    eprintln!(
        "infer: training {} rounds of {} on {} first ...",
        exp.config().rounds,
        exp.config().arch,
        exp.config().dataset
    );
    let result = exp.launch(&rt).publish_to(hub.clone())?.finish()?;
    let snap = hub
        .current()
        .ok_or_else(|| anyhow::anyhow!("no snapshot published (rounds=0?)"))?;
    let ds = exp.dataset().clone();
    let nodes: Vec<u32> = match explicit_nodes {
        Some(n) => n,
        None => {
            let ids = match split.as_str() {
                "train" => &ds.splits.train,
                "val" => &ds.splits.val,
                "test" => &ds.splits.test,
                other => bail!("--split wants train|val|test (got {other:?})"),
            };
            ids.iter().copied().take(limit).collect()
        }
    };
    if nodes.is_empty() {
        bail!("no nodes to score (empty --nodes / split)");
    }
    let mut engine = InferenceEngine::new(
        snap,
        ds.clone(),
        KernelCtx::new(exp.config().serve_threads),
    )?;
    let c = engine.classes();
    eprintln!(
        "model: round {} snapshot (val={:.4}); cache: {} nodes, {:.2} MB, built in {:.3}s",
        engine.snapshot().round,
        result.final_val,
        engine.cache().nodes(),
        engine.cache().bytes() as f64 / 1e6,
        engine.cache().build_s
    );
    println!("{:>8} {:>6} {:>8} {:>12}", "node", "pred", "truth", "logit[pred]");
    let scores = engine.score_batch(&nodes)?.to_vec();
    for (i, &v) in nodes.iter().enumerate() {
        let row = &scores[i * c..(i + 1) * c];
        let pred = llcg::metrics::argmax(row);
        let truth = match &ds.labels {
            Labels::MultiClass(y) => y[v as usize].to_string(),
            Labels::MultiLabel { data, c: dc } => {
                let pos = (0..*dc).filter(|&j| data[v as usize * dc + j] > 0.5).count();
                format!("{pos}+")
            }
        };
        println!("{:>8} {:>6} {:>8} {:>12.4}", v, pred, truth, row[pred]);
    }
    Ok(())
}

/// `llcg worker --connect <addr> --rank <p> [config flags]` — a cluster
/// worker process. Not meant to be typed by hand: the server spawns these
/// itself when `transport=tcp|uds`, passing its exact config via
/// `api::keys::cli_args` so the handshake's config-digest check passes.
fn cmd_worker(flags: &[(String, String)]) -> Result<()> {
    let cfg = build_config(flags, &["connect", "rank"])?;
    let mut connect = None;
    let mut rank = None;
    for (k, v) in flags {
        match k.as_str() {
            "connect" => connect = Some(v.clone()),
            "rank" => rank = Some(v.parse::<u32>()?),
            _ => {}
        }
    }
    let connect = connect.ok_or_else(|| anyhow::anyhow!("worker requires --connect <addr>"))?;
    let rank = rank.ok_or_else(|| anyhow::anyhow!("worker requires --rank <p>"))?;
    llcg::transport::run_worker(&connect, rank, cfg)
}

fn cmd_datasets() -> Result<()> {
    println!("Registered datasets (synthetic; stats at seed 0):");
    for (name, doc) in registry::with(|r| r.dataset_docs()) {
        let ds = registry::load_dataset(&name, 0).map_err(|e| anyhow::anyhow!(e))?;
        println!("  {}", ds.stats());
        println!("      {doc}");
    }
    Ok(())
}

fn cmd_partition(flags: &[(String, String)]) -> Result<()> {
    let mut dataset = "reddit-s".to_string();
    let mut parts = 8usize;
    let mut seed = 0u64;
    for (k, v) in flags {
        match k.as_str() {
            "dataset" => dataset = v.clone(),
            "parts" => parts = v.parse()?,
            "seed" => seed = v.parse()?,
            _ => bail!("unknown flag --{k}"),
        }
    }
    let ds = registry::load_dataset(&dataset, seed).map_err(|e| anyhow::anyhow!(e))?;
    println!("{} | {} parts", ds.stats(), parts);
    println!(
        "{:<12} {:>9} {:>10} {:>10} {:>10} {:>9}",
        "method", "edge_cut", "cut_ratio", "imbalance", "label_skew", "time_s"
    );
    for name in registry::with(|r| r.partitioner_names()) {
        let p = registry::build_partitioner(&name).map_err(|e| anyhow::anyhow!(e))?;
        let mut rng = Pcg64::new(seed);
        let t0 = std::time::Instant::now();
        let a = p.partition(&ds.graph, parts, &mut rng);
        let dt = t0.elapsed().as_secs_f64();
        let q = partition::quality(&ds.graph, &a, parts);
        let skew = driver::label_skew(&ds, &a, parts);
        println!(
            "{:<12} {:>9} {:>10.4} {:>10.3} {:>10.3} {:>9.3}",
            name, q.edge_cut, q.cut_ratio, q.imbalance, skew, dt
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!(
            "usage: llcg <run|sweep|serve|infer|datasets|partition|repro-*> [--flags]\n\
             `llcg run --help` lists every config key\n\
             `llcg sweep --sweep key=v1,v2,...` runs a config grid\n\
             `llcg serve` trains then load-tests the inference server\n\
             `llcg infer --nodes 1,2,3` trains then scores nodes\n\
             repro commands: {}",
            experiments::REPRO_COMMANDS.join(", ")
        );
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "sweep" => cmd_sweep(&flags),
        "serve" => cmd_serve(&flags),
        "infer" => cmd_infer(&flags),
        "worker" => cmd_worker(&flags),
        "datasets" => cmd_datasets(),
        "partition" => cmd_partition(&flags),
        other => {
            if let Some(name) = other.strip_prefix("repro-") {
                experiments::run_repro(name, &flags)
            } else {
                bail!("unknown command {other:?}");
            }
        }
    }
}
