//! `llcg` — CLI for the LLCG distributed GNN training framework.
//!
//! Subcommands:
//!   run [--config file.json] [--key=value ...]   one distributed run
//!       engine flags: --engine sequential|cluster
//!                     --round-mode sync|async:<tau>|pipelined
//!                     --net ideal|lan|wan|lat=..,bw=..,jitter=..,scale=..
//!   datasets                                     Table-2-style stats
//!   partition --dataset D --parts P              partitioner comparison
//!   repro-<exp>                                  regenerate a paper table/figure
//!                                                (fig2, fig4, table1, fig5,
//!                                                 fig6, fig78, fig9, fig10,
//!                                                 fig11, theory, fig1)
//!
//! Hand-rolled flag parsing (offline environment has no clap; DESIGN.md
//! §Substitutions). Flags are `--key value` or `--key=value`.

use anyhow::{bail, Result};

use llcg::config::ExperimentConfig;
use llcg::coordinator::driver;
use llcg::experiments;
use llcg::graph::generators::{self, SynthConfig};
use llcg::partition;
use llcg::runtime::Runtime;
use llcg::util::Pcg64;

fn parse_flags(args: &[String]) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.push((k.to_string(), v.to_string()));
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.push((stripped.to_string(), args[i + 1].clone()));
                i += 1;
            } else {
                out.push((stripped.to_string(), "true".to_string()));
            }
        } else {
            bail!("unexpected positional argument {a:?}");
        }
        i += 1;
    }
    Ok(out)
}

fn build_config(flags: &[(String, String)]) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    for (k, v) in flags {
        if k == "config" {
            cfg = ExperimentConfig::from_file(v).map_err(|e| anyhow::anyhow!(e))?;
        }
    }
    for (k, v) in flags {
        if k == "config" || k == "out" {
            continue;
        }
        cfg.apply_override(k, v).map_err(|e| anyhow::anyhow!(e))?;
    }
    Ok(cfg)
}

fn cmd_run(flags: &[(String, String)]) -> Result<()> {
    let cfg = build_config(flags)?;
    let ds = driver::load_dataset(&cfg)?;
    let (rt, _adir) = Runtime::load_or_native(&cfg.artifacts_dir)?;
    eprintln!(
        "run: {} on {} ({} parts, {} rounds, arch={}, opt={}, backend={}, \
         engine={}, mode={}, net={})",
        cfg.algorithm.name(),
        cfg.dataset,
        cfg.parts,
        cfg.rounds,
        cfg.arch,
        cfg.optimizer,
        rt.backend_name(),
        cfg.engine.name(),
        cfg.round_mode.name(),
        cfg.net
    );
    let result = driver::run_experiment(&cfg, &ds, &rt)?;
    println!(
        "{:>5} {:>6} {:>10} {:>10} {:>9} {:>12}",
        "round", "steps", "loc_loss", "glob_loss", "val", "cum_MB"
    );
    for r in &result.records {
        println!(
            "{:>5} {:>6} {:>10.4} {:>10.4} {:>9.4} {:>12.3}",
            r.round,
            r.local_steps,
            r.local_loss,
            r.global_loss,
            r.val_score,
            r.cum_bytes as f64 / 1e6
        );
    }
    println!(
        "final: val={:.4} test={:.4} cut_ratio={:.3} avg_round_MB={:.3}",
        result.final_val,
        result.final_test,
        result.cut_ratio,
        result.avg_round_mb()
    );
    let wall: f64 = result.records.iter().map(|r| r.wall_time_s).sum();
    let net: f64 = result.records.iter().map(|r| r.net_time_s).sum();
    println!(
        "time: measured wall {:.3}s, modeled net {:.3}s (engine={})",
        wall, net, result.engine
    );
    if let Some(s) = result.max_staleness {
        println!("staleness: max observed {s}");
    }
    for (k, v) in flags {
        if k == "out" {
            std::fs::create_dir_all(
                std::path::Path::new(v).parent().unwrap_or(std::path::Path::new(".")),
            )?;
            std::fs::write(v, result.to_json().to_string_pretty())?;
            eprintln!("wrote {v}");
        }
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("Table 2 analogs (synthetic; seeds fixed at 0):");
    for name in SynthConfig::all_names() {
        let ds = generators::by_name(name, 0).unwrap();
        println!("  {}", ds.stats());
    }
    Ok(())
}

fn cmd_partition(flags: &[(String, String)]) -> Result<()> {
    let mut dataset = "reddit-s".to_string();
    let mut parts = 8usize;
    let mut seed = 0u64;
    for (k, v) in flags {
        match k.as_str() {
            "dataset" => dataset = v.clone(),
            "parts" => parts = v.parse()?,
            "seed" => seed = v.parse()?,
            _ => bail!("unknown flag --{k}"),
        }
    }
    let ds = generators::by_name(&dataset, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    println!("{} | {} parts", ds.stats(), parts);
    println!(
        "{:<12} {:>9} {:>10} {:>10} {:>10} {:>9}",
        "method", "edge_cut", "cut_ratio", "imbalance", "label_skew", "time_s"
    );
    for name in ["random", "hash", "bfs", "ldg", "metis"] {
        let p = partition::by_name(name).unwrap();
        let mut rng = Pcg64::new(seed);
        let t0 = std::time::Instant::now();
        let a = p.partition(&ds.graph, parts, &mut rng);
        let dt = t0.elapsed().as_secs_f64();
        let q = partition::quality(&ds.graph, &a, parts);
        let skew = driver::label_skew(&ds, &a, parts);
        println!(
            "{:<12} {:>9} {:>10.4} {:>10.3} {:>10.3} {:>9.3}",
            name, q.edge_cut, q.cut_ratio, q.imbalance, skew, dt
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!(
            "usage: llcg <run|datasets|partition|repro-*> [--flags]\n\
             repro commands: {}",
            experiments::REPRO_COMMANDS.join(", ")
        );
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "datasets" => cmd_datasets(),
        "partition" => cmd_partition(&flags),
        other => {
            if let Some(name) = other.strip_prefix("repro-") {
                experiments::run_repro(name, &flags)
            } else {
                bail!("unknown command {other:?}");
            }
        }
    }
}
