//! Graph partitioning — the METIS substitute (DESIGN.md §Substitutions).
//!
//! The paper splits input graphs with METIS before training. This module
//! provides:
//! - [`MultilevelPartitioner`] — the METIS-like default: heavy-edge-matching
//!   coarsening → greedy seeding on the coarsest graph → projected
//!   Kernighan–Lin/FM boundary refinement at every level;
//! - streaming/trivial baselines ([`LdgPartitioner`], [`BfsPartitioner`],
//!   [`RandomPartitioner`], [`HashPartitioner`]) used by ablations to vary
//!   the cut ratio (and hence κ).

pub mod multilevel;

pub use multilevel::MultilevelPartitioner;

use crate::graph::CsrGraph;
use crate::util::Pcg64;

/// A node→part assignment produced by a [`Partitioner`].
pub type Assignment = Vec<u32>;

/// Common interface: split `g` into `parts` balanced pieces.
pub trait Partitioner {
    fn partition(&self, g: &CsrGraph, parts: usize, rng: &mut Pcg64) -> Assignment;
    fn name(&self) -> &'static str;
}

/// Quality metrics of an assignment.
#[derive(Clone, Debug)]
pub struct PartitionQuality {
    pub parts: usize,
    pub edge_cut: usize,
    pub cut_ratio: f64,
    /// max part size / ideal part size
    pub imbalance: f64,
    pub sizes: Vec<usize>,
}

pub fn quality(g: &CsrGraph, assignment: &Assignment, parts: usize) -> PartitionQuality {
    let mut sizes = vec![0usize; parts];
    for &a in assignment {
        sizes[a as usize] += 1;
    }
    let ideal = g.n as f64 / parts as f64;
    let max = *sizes.iter().max().unwrap_or(&0);
    PartitionQuality {
        parts,
        edge_cut: g.edge_cut(assignment),
        cut_ratio: g.cut_ratio(assignment),
        imbalance: if ideal > 0.0 { max as f64 / ideal } else { 0.0 },
        sizes,
    }
}

/// Uniform random assignment — the worst-case cut baseline.
pub struct RandomPartitioner;

impl Partitioner for RandomPartitioner {
    fn partition(&self, g: &CsrGraph, parts: usize, rng: &mut Pcg64) -> Assignment {
        // balanced random: shuffle then deal round-robin
        let mut ids: Vec<u32> = (0..g.n as u32).collect();
        rng.shuffle(&mut ids);
        let mut out = vec![0u32; g.n];
        for (i, &v) in ids.iter().enumerate() {
            out[v as usize] = (i % parts) as u32;
        }
        out
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Deterministic id-hash assignment (what a naive system does).
pub struct HashPartitioner;

impl Partitioner for HashPartitioner {
    fn partition(&self, g: &CsrGraph, parts: usize, _rng: &mut Pcg64) -> Assignment {
        (0..g.n as u64)
            .map(|v| {
                let mut z = v.wrapping_add(0x9e3779b97f4a7c15);
                z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
                ((z ^ (z >> 31)) % parts as u64) as u32
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "hash"
    }
}

/// Balanced multi-source BFS region growing.
pub struct BfsPartitioner;

impl Partitioner for BfsPartitioner {
    fn partition(&self, g: &CsrGraph, parts: usize, rng: &mut Pcg64) -> Assignment {
        let cap = g.n.div_ceil(parts);
        let mut assign = vec![u32::MAX; g.n];
        let mut sizes = vec![0usize; parts];
        let mut queues: Vec<std::collections::VecDeque<u32>> =
            (0..parts).map(|_| Default::default()).collect();
        let mut order: Vec<u32> = (0..g.n as u32).collect();
        rng.shuffle(&mut order);
        let mut seeds = order.iter().copied();
        for (p, q) in queues.iter_mut().enumerate() {
            if let Some(s) = seeds.find(|&s| assign[s as usize] == u32::MAX) {
                assign[s as usize] = p as u32;
                sizes[p] += 1;
                q.push_back(s);
            }
        }
        let mut active = true;
        while active {
            active = false;
            for p in 0..parts {
                if sizes[p] >= cap {
                    continue;
                }
                if let Some(v) = queues[p].pop_front() {
                    active = true;
                    for &u in g.neighbors(v) {
                        if assign[u as usize] == u32::MAX && sizes[p] < cap {
                            assign[u as usize] = p as u32;
                            sizes[p] += 1;
                            queues[p].push_back(u);
                        }
                    }
                    // keep v queued if it still has unassigned neighbors
                    if g.neighbors(v).iter().any(|&u| assign[u as usize] == u32::MAX) {
                        queues[p].push_back(v);
                    }
                } else {
                    // restart from an unassigned seed (disconnected graphs)
                    if let Some(s) =
                        (0..g.n as u32).find(|&s| assign[s as usize] == u32::MAX)
                    {
                        assign[s as usize] = p as u32;
                        sizes[p] += 1;
                        queues[p].push_back(s);
                        active = true;
                    }
                }
            }
        }
        // sweep leftovers into the smallest parts
        for v in 0..g.n {
            if assign[v] == u32::MAX {
                let p = (0..parts).min_by_key(|&p| sizes[p]).unwrap();
                assign[v] = p as u32;
                sizes[p] += 1;
            }
        }
        assign
    }

    fn name(&self) -> &'static str {
        "bfs"
    }
}

/// Linear Deterministic Greedy streaming partitioner (Stanton & Kliot 2012):
/// each node goes to the part with the most already-assigned neighbors,
/// weighted by remaining capacity.
pub struct LdgPartitioner;

impl Partitioner for LdgPartitioner {
    fn partition(&self, g: &CsrGraph, parts: usize, rng: &mut Pcg64) -> Assignment {
        let cap = g.n.div_ceil(parts) + 1;
        let mut assign = vec![u32::MAX; g.n];
        let mut sizes = vec![0usize; parts];
        let mut order: Vec<u32> = (0..g.n as u32).collect();
        rng.shuffle(&mut order);
        let mut counts = vec![0f64; parts];
        for &v in &order {
            for c in counts.iter_mut() {
                *c = 0.0;
            }
            for &u in g.neighbors(v) {
                let a = assign[u as usize];
                if a != u32::MAX {
                    counts[a as usize] += 1.0;
                }
            }
            let mut best = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for p in 0..parts {
                if sizes[p] >= cap {
                    continue;
                }
                let penalty = 1.0 - sizes[p] as f64 / cap as f64;
                let score = counts[p] * penalty + 1e-9 * penalty;
                if score > best_score {
                    best_score = score;
                    best = p;
                }
            }
            assign[v as usize] = best as u32;
            sizes[best] += 1;
        }
        assign
    }

    fn name(&self) -> &'static str {
        "ldg"
    }
}

/// Look up a partitioner by config name.
pub fn by_name(name: &str) -> Option<Box<dyn Partitioner>> {
    match name {
        "random" => Some(Box::new(RandomPartitioner)),
        "hash" => Some(Box::new(HashPartitioner)),
        "bfs" => Some(Box::new(BfsPartitioner)),
        "ldg" => Some(Box::new(LdgPartitioner)),
        "metis" | "multilevel" => Some(Box::new(MultilevelPartitioner::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn check_valid(assign: &Assignment, n: usize, parts: usize) {
        assert_eq!(assign.len(), n);
        assert!(assign.iter().all(|&a| (a as usize) < parts));
        let mut sizes = vec![0usize; parts];
        for &a in assign {
            sizes[a as usize] += 1;
        }
        let ideal = n as f64 / parts as f64;
        for (p, &s) in sizes.iter().enumerate() {
            assert!(
                (s as f64) < 1.6 * ideal + 2.0,
                "part {p} oversized: {s} vs ideal {ideal}"
            );
            assert!(s > 0, "part {p} empty");
        }
    }

    #[test]
    fn all_partitioners_valid_on_sbm() {
        let ds = generators::by_name("tiny", 0).unwrap();
        let mut rng = Pcg64::new(1);
        for name in ["random", "hash", "bfs", "ldg", "metis"] {
            let p = by_name(name).unwrap();
            let a = p.partition(&ds.graph, 4, &mut rng);
            check_valid(&a, ds.n(), 4);
        }
    }

    #[test]
    fn ldg_beats_random_on_community_graph() {
        let ds = generators::by_name("tiny", 2).unwrap();
        let mut rng = Pcg64::new(3);
        let a_rand = RandomPartitioner.partition(&ds.graph, 4, &mut rng);
        let a_ldg = LdgPartitioner.partition(&ds.graph, 4, &mut rng);
        assert!(
            ds.graph.cut_ratio(&a_ldg) < ds.graph.cut_ratio(&a_rand),
            "ldg {} !< random {}",
            ds.graph.cut_ratio(&a_ldg),
            ds.graph.cut_ratio(&a_rand)
        );
    }

    #[test]
    fn single_part_has_no_cut() {
        let ds = generators::by_name("tiny", 4).unwrap();
        let mut rng = Pcg64::new(5);
        for name in ["random", "bfs", "ldg", "metis"] {
            let a = by_name(name).unwrap().partition(&ds.graph, 1, &mut rng);
            assert_eq!(ds.graph.edge_cut(&a), 0);
        }
    }

    #[test]
    fn quality_metrics() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let q = quality(&g, &vec![0, 0, 1, 1], 2);
        assert_eq!(q.edge_cut, 1);
        assert_eq!(q.sizes, vec![2, 2]);
        assert!((q.imbalance - 1.0).abs() < 1e-9);
    }
}
