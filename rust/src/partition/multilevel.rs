//! Multilevel k-way partitioner — the METIS substitute.
//!
//! Classic three-phase scheme (Karypis & Kumar):
//! 1. **Coarsen** — heavy-edge matching collapses matched pairs into
//!    super-nodes (edge weights accumulate) until the graph is small.
//! 2. **Initial partition** — weighted LDG-style greedy on the coarsest
//!    graph, respecting node weights.
//! 3. **Uncoarsen + refine** — project the assignment back level by level,
//!    running a bounded Kernighan–Lin/FM boundary-refinement pass at each
//!    level (positive-gain moves only, balance-constrained).
//!
//! Produces cut ratios within a small factor of METIS on SBM-style graphs
//! (measured in EXPERIMENTS.md §Partitioner) — sufficient because LLCG only
//! depends on the cut through κ, not on exact METIS behaviour.

use super::{Assignment, Partitioner};
use crate::graph::CsrGraph;
use crate::util::Pcg64;

/// Weighted graph used internally during coarsening.
struct WGraph {
    n: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    /// edge weights, parallel to `indices`
    eweights: Vec<u64>,
    /// node weights (number of original nodes collapsed)
    nweights: Vec<u64>,
}

impl WGraph {
    fn from_csr(g: &CsrGraph) -> WGraph {
        WGraph {
            n: g.n,
            indptr: g.indptr.clone(),
            indices: g.indices.clone(),
            eweights: vec![1; g.indices.len()],
            nweights: vec![1; g.n],
        }
    }

    fn neighbors(&self, v: u32) -> (&[u32], &[u64]) {
        let r = self.indptr[v as usize]..self.indptr[v as usize + 1];
        (&self.indices[r.clone()], &self.eweights[r])
    }
}

pub struct MultilevelPartitioner {
    /// stop coarsening when the graph has at most `coarsen_target * parts`
    /// super-nodes
    pub coarsen_target: usize,
    /// max refinement passes per level
    pub refine_passes: usize,
    /// allowed imbalance factor (max part weight / ideal)
    pub balance: f64,
}

impl Default for MultilevelPartitioner {
    fn default() -> Self {
        Self {
            coarsen_target: 30,
            refine_passes: 4,
            balance: 1.10,
        }
    }
}

impl Partitioner for MultilevelPartitioner {
    fn partition(&self, g: &CsrGraph, parts: usize, rng: &mut Pcg64) -> Assignment {
        if parts <= 1 {
            return vec![0; g.n];
        }
        let ml = self.multilevel(g, parts, rng);
        // On graphs with a dense random overlay (e.g. many cross-community
        // edges), heavy-edge matching can coarsen along noise edges and the
        // projected solution is poor. A streaming-LDG seed refined on the
        // fine graph is a strong fallback; keep whichever cuts less.
        let ldg = {
            let mut a = super::LdgPartitioner.partition(g, parts, rng);
            let wg = WGraph::from_csr(g);
            refine(&wg, &mut a, parts, self.refine_passes * 2, self.balance);
            a
        };
        if g.edge_cut(&ldg) < g.edge_cut(&ml) {
            ldg
        } else {
            ml
        }
    }

    fn name(&self) -> &'static str {
        "metis-like"
    }
}

impl MultilevelPartitioner {
    fn multilevel(&self, g: &CsrGraph, parts: usize, rng: &mut Pcg64) -> Assignment {
        // ---- coarsening ----------------------------------------------------
        let mut levels: Vec<(WGraph, Vec<u32>)> = Vec::new(); // (graph, map fine->coarse)
        let mut cur = WGraph::from_csr(g);
        while cur.n > self.coarsen_target * parts && levels.len() < 30 {
            let (coarse, map) = coarsen(&cur, rng);
            if coarse.n as f64 > cur.n as f64 * 0.95 {
                // matching stalled (e.g. star graphs) — stop
                levels.push((std::mem::replace(&mut cur, coarse), map));
                break;
            }
            levels.push((std::mem::replace(&mut cur, coarse), map));
        }

        // ---- initial partition on coarsest --------------------------------
        let mut assign = initial_partition(&cur, parts, self.balance, rng);
        refine(&cur, &mut assign, parts, self.refine_passes, self.balance);

        // ---- uncoarsen + refine -------------------------------------------
        while let Some((fine, map)) = levels.pop() {
            let mut fine_assign = vec![0u32; fine.n];
            for v in 0..fine.n {
                fine_assign[v] = assign[map[v] as usize];
            }
            assign = fine_assign;
            refine(&fine, &mut assign, parts, self.refine_passes, self.balance);
        }
        assign
    }
}

/// Heavy-edge matching: visit nodes in random order; match each unmatched
/// node with its heaviest unmatched neighbor; collapse pairs.
fn coarsen(g: &WGraph, rng: &mut Pcg64) -> (WGraph, Vec<u32>) {
    let n = g.n;
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![u32::MAX; n];
    for &v in &order {
        if mate[v as usize] != u32::MAX {
            continue;
        }
        let (ns, ws) = g.neighbors(v);
        let mut best = u32::MAX;
        let mut best_w = 0u64;
        for (&u, &w) in ns.iter().zip(ws) {
            if u != v && mate[u as usize] == u32::MAX && w >= best_w {
                best = u;
                best_w = w;
            }
        }
        if best != u32::MAX {
            mate[v as usize] = best;
            mate[best as usize] = v;
        } else {
            mate[v as usize] = v; // self-matched
        }
    }
    // assign coarse ids
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n as u32 {
        if map[v as usize] != u32::MAX {
            continue;
        }
        let m = mate[v as usize];
        map[v as usize] = next;
        if m != v && m != u32::MAX {
            map[m as usize] = next;
        }
        next += 1;
    }
    let cn = next as usize;
    // accumulate coarse adjacency
    let mut cweights = vec![0u64; cn];
    for v in 0..n {
        cweights[map[v] as usize] += g.nweights[v];
    }
    let mut adj: Vec<std::collections::HashMap<u32, u64>> =
        vec![Default::default(); cn];
    for v in 0..n as u32 {
        let cv = map[v as usize];
        let (ns, ws) = g.neighbors(v);
        for (&u, &w) in ns.iter().zip(ws) {
            let cu = map[u as usize];
            if cu != cv {
                *adj[cv as usize].entry(cu).or_insert(0) += w;
            }
        }
    }
    let mut indptr = Vec::with_capacity(cn + 1);
    indptr.push(0usize);
    let mut indices = Vec::new();
    let mut eweights = Vec::new();
    for a in adj.iter() {
        let mut items: Vec<(u32, u64)> = a.iter().map(|(&u, &w)| (u, w)).collect();
        items.sort_unstable();
        for (u, w) in items {
            indices.push(u);
            eweights.push(w);
        }
        indptr.push(indices.len());
    }
    (
        WGraph {
            n: cn,
            indptr,
            indices,
            eweights,
            nweights: cweights,
        },
        map,
    )
}

/// Weighted greedy seeding on the coarsest graph: BFS-flavoured LDG over
/// node weights.
fn initial_partition(g: &WGraph, parts: usize, balance: f64, rng: &mut Pcg64) -> Assignment {
    let total_w: u64 = g.nweights.iter().sum();
    let cap = ((total_w as f64 / parts as f64) * balance).ceil() as u64 + 1;
    let mut assign = vec![u32::MAX; g.n];
    let mut loads = vec![0u64; parts];
    let mut order: Vec<u32> = (0..g.n as u32).collect();
    // heaviest nodes first gives the greedy a better start
    order.sort_by_key(|&v| std::cmp::Reverse(g.nweights[v as usize]));
    // random tie-break jitter
    let chunk = (order.len() / 8).max(1);
    for w in order.chunks_mut(chunk) {
        rng.shuffle(w);
    }
    let mut gain = vec![0f64; parts];
    for &v in &order {
        for gm in gain.iter_mut() {
            *gm = 0.0;
        }
        let (ns, ws) = g.neighbors(v);
        for (&u, &w) in ns.iter().zip(ws) {
            let a = assign[u as usize];
            if a != u32::MAX {
                gain[a as usize] += w as f64;
            }
        }
        let mut best = usize::MAX;
        let mut best_score = f64::NEG_INFINITY;
        for p in 0..parts {
            if loads[p] + g.nweights[v as usize] > cap {
                continue;
            }
            let penalty = 1.0 - loads[p] as f64 / cap as f64;
            let score = gain[p] * penalty + 1e-6 * penalty;
            if score > best_score {
                best_score = score;
                best = p;
            }
        }
        if best == usize::MAX {
            best = (0..parts).min_by_key(|&p| loads[p]).unwrap();
        }
        assign[v as usize] = best as u32;
        loads[best] += g.nweights[v as usize];
    }
    assign
}

/// Bounded KL/FM refinement: repeatedly move boundary nodes to the neighbor
/// part with the highest positive gain, respecting the balance cap.
fn refine(g: &WGraph, assign: &mut Assignment, parts: usize, passes: usize, balance: f64) {
    let total_w: u64 = g.nweights.iter().sum();
    let cap = ((total_w as f64 / parts as f64) * balance).ceil() as u64 + 1;
    let mut loads = vec![0u64; parts];
    for v in 0..g.n {
        loads[assign[v] as usize] += g.nweights[v];
    }
    let mut conn = vec![0i64; parts];
    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..g.n as u32 {
            let from = assign[v as usize] as usize;
            let (ns, ws) = g.neighbors(v);
            if ns.is_empty() {
                continue;
            }
            for c in conn.iter_mut() {
                *c = 0;
            }
            let mut boundary = false;
            for (&u, &w) in ns.iter().zip(ws) {
                let a = assign[u as usize] as usize;
                conn[a] += w as i64;
                if a != from {
                    boundary = true;
                }
            }
            if !boundary {
                continue;
            }
            let internal = conn[from];
            let mut best = from;
            let mut best_gain = 0i64;
            for (p, &c) in conn.iter().enumerate() {
                if p == from || c == 0 {
                    continue;
                }
                if loads[p] + g.nweights[v as usize] > cap {
                    continue;
                }
                let gain = c - internal;
                if gain > best_gain {
                    best_gain = gain;
                    best = p;
                }
            }
            if best != from {
                assign[v as usize] = best as u32;
                loads[from] -= g.nweights[v as usize];
                loads[best] += g.nweights[v as usize];
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, SynthConfig};
    use crate::partition::{quality, RandomPartitioner};

    #[test]
    fn beats_random_by_a_lot_on_communities() {
        let mut cfg = SynthConfig::by_name("tiny").unwrap();
        cfg.n = 2000;
        cfg.homophily = 0.9;
        let ds = generators::generate(&cfg, 1);
        let mut rng = Pcg64::new(2);
        let ml = MultilevelPartitioner::default().partition(&ds.graph, 4, &mut rng);
        let rd = RandomPartitioner.partition(&ds.graph, 4, &mut rng);
        let ml_cut = ds.graph.cut_ratio(&ml);
        let rd_cut = ds.graph.cut_ratio(&rd);
        assert!(
            ml_cut < 0.5 * rd_cut,
            "multilevel {ml_cut} not << random {rd_cut}"
        );
    }

    #[test]
    fn respects_balance() {
        let ds = generators::by_name("tiny", 3).unwrap();
        let mut rng = Pcg64::new(4);
        for parts in [2usize, 4, 8] {
            let a = MultilevelPartitioner::default().partition(&ds.graph, parts, &mut rng);
            let q = quality(&ds.graph, &a, parts);
            assert!(q.imbalance < 1.35, "imbalance {} at p={parts}", q.imbalance);
            assert!(q.sizes.iter().all(|&s| s > 0));
        }
    }

    #[test]
    fn handles_tiny_and_disconnected_graphs() {
        let g = CsrGraph::from_edges(6, &[(0, 1), (2, 3)]); // node 4, 5 isolated
        let mut rng = Pcg64::new(5);
        let a = MultilevelPartitioner::default().partition(&g, 2, &mut rng);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&x| x < 2));
    }

    #[test]
    fn perfect_communities_recovered() {
        // two cliques joined by one edge: the 2-way cut should be exactly 1
        let mut edges = Vec::new();
        for i in 0..10u32 {
            for j in (i + 1)..10 {
                edges.push((i, j));
                edges.push((10 + i, 10 + j));
            }
        }
        edges.push((0, 10));
        let g = CsrGraph::from_edges(20, &edges);
        let mut rng = Pcg64::new(6);
        let a = MultilevelPartitioner::default().partition(&g, 2, &mut rng);
        assert_eq!(g.edge_cut(&a), 1, "assignment {a:?}");
    }
}
