//! Descriptive statistics for benches, metrics, and experiment reports:
//! online mean/variance (Welford), percentiles, and a compact summary type.

/// Online mean / variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// The latency percentile set (p50/p90/p95/p99) of a sample — one
/// interpolation rule shared by every latency report: the bench harness
/// sections (`BENCH_*.json`), the serve load generator, and [`Summary`].
#[derive(Clone, Copy, Debug)]
pub struct Percentiles {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    pub fn of(xs: &[f64]) -> Percentiles {
        assert!(!xs.is_empty(), "Percentiles::of on empty sample");
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles::of_sorted(&sorted)
    }

    /// [`Percentiles::of`] over an already ascending-sorted slice.
    pub fn of_sorted(sorted: &[f64]) -> Percentiles {
        Percentiles {
            p50: percentile_sorted(sorted, 0.50),
            p90: percentile_sorted(sorted, 0.90),
            p95: percentile_sorted(sorted, 0.95),
            p99: percentile_sorted(sorted, 0.99),
        }
    }

    /// The same percentile set over a fixed-bucket histogram
    /// (`obs::Histogram`): `bounds[i]` is bucket `i`'s `(lo, hi)` value
    /// range, `counts[i]` how many samples landed in it.
    pub fn of_buckets(bounds: &[(f64, f64)], counts: &[u64]) -> Percentiles {
        Percentiles {
            p50: percentile_bucketed(bounds, counts, 0.50),
            p90: percentile_bucketed(bounds, counts, 0.90),
            p95: percentile_bucketed(bounds, counts, 0.95),
            p99: percentile_bucketed(bounds, counts, 0.99),
        }
    }
}

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = Percentiles::of_sorted(&sorted);
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: w.min(),
            p50: p.p50,
            p90: p.p90,
            p95: p.p95,
            p99: p.p99,
            max: w.max(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} std={:.4} min={:.4} p50={:.4} p90={:.4} p95={:.4} p99={:.4} max={:.4}",
            self.n, self.mean, self.std, self.min, self.p50, self.p90, self.p95, self.p99,
            self.max
        )
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of a fixed-bucket histogram, q in [0, 1]: find the bucket
/// holding the q-th sample by cumulative count and linearly interpolate
/// inside its `(lo, hi)` range — the histogram analogue of
/// [`percentile_sorted`]'s interpolation rule. Returns 0 for an all-empty
/// histogram.
pub fn percentile_bucketed(bounds: &[(f64, f64)], counts: &[u64], q: f64) -> f64 {
    assert_eq!(bounds.len(), counts.len());
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    // rank in [0, total-1] on the same index scale as percentile_sorted
    let rank = q * (total - 1) as f64;
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        // this bucket covers ranks [cum, cum + c)
        if rank < (cum + c) as f64 {
            let (lo, hi) = bounds[i];
            // position of the rank within the bucket, in (0, 1]: the
            // bucket's samples are spread evenly across its value range
            let frac = (rank - cum as f64 + 1.0) / c as f64;
            return lo + frac.min(1.0) * (hi - lo);
        }
        cum += c;
    }
    bounds.last().map(|&(_, hi)| hi).unwrap_or(0.0)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile_sorted(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 1.0) - 100.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 0.5) - 50.5).abs() < 1e-9);
        let s = Summary::of(&xs);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p90 - 90.1).abs() < 1e-6);
        assert!((s.p95 - 95.05).abs() < 1e-6);
        // the standalone percentile set agrees with Summary's fields
        let p = Percentiles::of(&xs);
        assert_eq!(p.p50.to_bits(), s.p50.to_bits());
        assert_eq!(p.p90.to_bits(), s.p90.to_bits());
        assert_eq!(p.p95.to_bits(), s.p95.to_bits());
        assert_eq!(p.p99.to_bits(), s.p99.to_bits());
    }

    #[test]
    fn percentiles_unsorted_input() {
        let p = Percentiles::of(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert!((p.p50 - 3.0).abs() < 1e-12);
        assert!((p.p99 - 4.96).abs() < 1e-9);
    }

    #[test]
    fn bucketed_percentiles_interpolate_within_buckets() {
        // 10 samples in [0,1), 10 in [1,2), none above
        let bounds = [(0.0, 1.0), (1.0, 2.0), (2.0, 4.0)];
        let counts = [10u64, 10, 0];
        let p = Percentiles::of_buckets(&bounds, &counts);
        // the median sits at the first bucket's upper edge
        assert!((p.p50 - 1.0).abs() < 0.06, "p50 {}", p.p50);
        // the tail stays inside the second bucket, never in the empty third
        assert!(p.p99 > 1.8 && p.p99 <= 2.0, "p99 {}", p.p99);
        assert!(p.p90 > 1.5 && p.p90 < 2.0, "p90 {}", p.p90);
        // empty histogram reports zeros; q is clamped
        assert_eq!(percentile_bucketed(&bounds, &[0, 0, 0], 0.5), 0.0);
        assert!(percentile_bucketed(&bounds, &counts, 2.0) <= 2.0);
    }

    #[test]
    fn single_element() {
        let s = Summary::of(&[3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 3.0);
    }
}
