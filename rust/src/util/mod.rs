//! Std-only substrates: seeded RNG, JSON, statistics, timing.
//!
//! The offline build environment provides no `rand`/`serde`/`serde_json`
//! crates, so these are purpose-built (DESIGN.md §Substitutions). Each is a
//! small, fully-tested implementation of exactly what the coordinator needs.

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Pcg64;
pub use stats::Summary;

/// Wall-clock a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}
