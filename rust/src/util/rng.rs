//! PCG-64 (XSL-RR) pseudo-random generator — deterministic, seedable,
//! splittable. Every stochastic component (generators, partitioners,
//! samplers, initializers) takes one of these so whole distributed runs are
//! exactly reproducible from a single seed.

/// PCG-XSL-RR 128/64 (O'Neill 2014). 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with SplitMix64 expansion so nearby integer seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Self { state, inc };
        rng.next_u64(); // burn-in so state fully mixes the seed
        rng
    }

    /// Derive an independent child stream (worker p, round r, ...).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Export the raw generator state for checkpointing. The pair is opaque
    /// except to [`Pcg64::from_raw_state`]; restoring it resumes the stream
    /// exactly where this generator left off.
    pub fn raw_state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::raw_state`] output (checkpoint
    /// resume). No burn-in: the state is already mixed.
    pub fn from_raw_state(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller (polar form avoided: trig is fine here).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// Sample `k` distinct items from `xs` without replacement.
    /// Uses partial Fisher–Yates over an index buffer for small `xs`,
    /// reservoir ("Algorithm R") when `xs` is large relative to `k`.
    pub fn sample_without_replacement<T: Copy>(&mut self, xs: &[T], k: usize) -> Vec<T> {
        let mut out = Vec::new();
        let mut idx_scratch = Vec::new();
        self.sample_without_replacement_into(xs, k, &mut out, &mut idx_scratch);
        out
    }

    /// Allocation-free form of [`sample_without_replacement`]: writes the
    /// sample into `out` (cleared first) and reuses `idx_scratch` for the
    /// Fisher–Yates index buffer. Consumes the *identical* RNG stream as
    /// the allocating variant, so arena-based callers stay bit-reproducible
    /// with fresh-allocation callers.
    ///
    /// [`sample_without_replacement`]: Pcg64::sample_without_replacement
    pub fn sample_without_replacement_into<T: Copy>(
        &mut self,
        xs: &[T],
        k: usize,
        out: &mut Vec<T>,
        idx_scratch: &mut Vec<u32>,
    ) {
        out.clear();
        let n = xs.len();
        let k = k.min(n);
        if k == 0 {
            return;
        }
        if n <= 64 || k * 4 >= n {
            idx_scratch.clear();
            idx_scratch.extend(0..n as u32);
            for i in 0..k {
                let j = i + self.gen_range((n - i) as u64) as usize;
                idx_scratch.swap(i, j);
            }
            out.extend(idx_scratch[..k].iter().map(|&i| xs[i as usize]));
        } else {
            out.extend_from_slice(&xs[..k]);
            for i in k..n {
                let j = self.gen_range(i as u64 + 1) as usize;
                if j < k {
                    out[j] = xs[i];
                }
            }
        }
    }

    /// Sample from an unnormalized discrete distribution (linear scan).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index needs positive mass");
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Pcg64::new(1), Pcg64::new(2));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg64::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut rng = Pcg64::new(3);
        let xs: Vec<u32> = (0..100).collect();
        for &k in &[0usize, 1, 5, 50, 100, 150] {
            let s = rng.sample_without_replacement(&xs, k);
            assert_eq!(s.len(), k.min(100));
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), s.len(), "duplicates at k={k}");
        }
    }

    #[test]
    fn reservoir_path_uniformity() {
        // k small relative to n triggers the reservoir path; check coverage.
        let mut rng = Pcg64::new(5);
        let xs: Vec<u32> = (0..1000).collect();
        let mut counts = vec![0u32; 1000];
        for _ in 0..2000 {
            for v in rng.sample_without_replacement(&xs, 10) {
                counts[v as usize] += 1;
            }
        }
        let hit = counts.iter().filter(|&&c| c > 0).count();
        assert!(hit > 950, "coverage {hit}/1000");
    }

    #[test]
    fn sample_into_matches_allocating_variant() {
        // both fisher-yates (small n) and reservoir (large n, small k) paths
        for &(n, k) in &[(40usize, 7usize), (1000, 10), (1000, 800)] {
            let xs: Vec<u32> = (0..n as u32).collect();
            let mut a = Pcg64::new(21);
            let mut b = Pcg64::new(21);
            let mut out = Vec::new();
            let mut idx = Vec::new();
            for _ in 0..5 {
                let fresh = a.sample_without_replacement(&xs, k);
                b.sample_without_replacement_into(&xs, k, &mut out, &mut idx);
                assert_eq!(fresh, out, "stream diverged at n={n} k={k}");
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_mass() {
        let mut rng = Pcg64::new(17);
        let w = [0.0, 0.0, 1.0, 3.0];
        let mut counts = [0u32; 4];
        for _ in 0..4000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0] + counts[1], 0);
        let ratio = counts[3] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn raw_state_roundtrip_resumes_stream() {
        let mut a = Pcg64::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.raw_state();
        let mut b = Pcg64::from_raw_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
