//! Minimal JSON parser + serializer (std-only; the offline registry has no
//! serde). Full RFC 8259 value model, recursive-descent parser with escape
//! handling, and a pretty serializer. Used for the artifact manifest, run
//! configs, and experiment logs.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------------------------------------------------------- parse
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that panics with a useful message — for required fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    // --------------------------------------------------------- constructors
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Array(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ------------------------------------------------------------ serialize
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // RFC 8259 has no NaN/Infinity literal; null keeps the
                    // document parseable (NaN val_score on non-eval rounds)
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 5;
                                if self.b.get(self.pos) != Some(&b'\\')
                                    || self.b.get(self.pos + 1) != Some(&b'u')
                                {
                                    return Err(self.err("lone high surrogate"));
                                }
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.pos + 2..self.pos + 6],
                                )
                                .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                self.pos += 1; // compensating for shared +5 below
                                char::from_u32(
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                )
                                .ok_or_else(|| self.err("bad surrogate pair"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(ch);
                            self.pos += 4; // the 4 hex digits ('u' consumed below)
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("a").as_array().unwrap().len(), 3);
        assert_eq!(v.req("c").as_str(), Some("x"));
        assert_eq!(v.req("a").as_array().unwrap()[2].req("b"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"A"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\n"],"num":-7,"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let v = Json::obj(vec![("x", Json::num(f64::NAN))]);
        // the emitted document must stay parseable
        assert_eq!(Json::parse(&v.to_string()).unwrap().req("x"), &Json::Null);
    }
}
