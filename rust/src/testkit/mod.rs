//! Property-based-testing harness (std-only substitute for `proptest`,
//! DESIGN.md §Substitutions).
//!
//! `check(cases, strategy, property)` runs `property` on `cases` random
//! inputs drawn by `strategy`; on failure it performs greedy shrinking via
//! the strategy's `shrink` and reports the minimal failing input plus the
//! seed needed to replay it deterministically.

use crate::util::Pcg64;

/// A value generator with optional shrinking.
pub trait Strategy {
    type Value: std::fmt::Debug + Clone;

    fn generate(&self, rng: &mut Pcg64) -> Self::Value;

    /// Candidate "smaller" values, most aggressive first.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` generated inputs. Panics (with the shrunk
/// counterexample + replay seed) if the property returns false or panics.
pub fn check<S: Strategy>(
    seed: u64,
    cases: usize,
    strategy: &S,
    property: impl Fn(&S::Value) -> bool,
) {
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let value = strategy.generate(&mut rng);
        if holds(&property, &value) {
            continue;
        }
        // shrink greedily
        let mut failing = value;
        let mut improved = true;
        let mut rounds = 0;
        while improved && rounds < 200 {
            improved = false;
            rounds += 1;
            for cand in strategy.shrink(&failing) {
                if !holds(&property, &cand) {
                    failing = cand;
                    improved = true;
                    break;
                }
            }
        }
        panic!(
            "property failed (seed={seed}, case={case})\nminimal counterexample: {failing:#?}"
        );
    }
}

fn holds<V: std::fmt::Debug>(property: &impl Fn(&V) -> bool, v: &V) -> bool {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(v))).unwrap_or(false)
}

// ---------------------------------------------------------------- strategies

/// Uniform usize in `[lo, hi]`, shrinks toward `lo`.
pub struct UsizeRange(pub usize, pub usize);

impl Strategy for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.gen_range((self.1 - self.0 + 1) as u64) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Pair of independent strategies.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Random graph specification: node count + edge list + a seed to vary
/// topology. Shrinks by dropping edges then nodes.
#[derive(Clone, Debug)]
pub struct GraphCase {
    pub n: usize,
    pub edges: Vec<(u32, u32)>,
}

pub struct GraphStrategy {
    pub max_n: usize,
    pub max_extra_edges: usize,
}

impl Strategy for GraphStrategy {
    type Value = GraphCase;

    fn generate(&self, rng: &mut Pcg64) -> GraphCase {
        let n = 2 + rng.gen_range((self.max_n - 1) as u64) as usize;
        let mut edges = Vec::new();
        // random spanning-ish chain for connectivity, then noise edges
        for v in 1..n as u32 {
            let u = rng.gen_range(v as u64) as u32;
            edges.push((u, v));
        }
        let extra = rng.gen_range(self.max_extra_edges as u64 + 1) as usize;
        for _ in 0..extra {
            let u = rng.gen_range(n as u64) as u32;
            let v = rng.gen_range(n as u64) as u32;
            if u != v {
                edges.push((u, v));
            }
        }
        GraphCase { n, edges }
    }

    fn shrink(&self, v: &GraphCase) -> Vec<GraphCase> {
        let mut out = Vec::new();
        if v.edges.len() > 1 {
            out.push(GraphCase {
                n: v.n,
                edges: v.edges[..v.edges.len() / 2].to_vec(),
            });
            out.push(GraphCase {
                n: v.n,
                edges: v.edges[..v.edges.len() - 1].to_vec(),
            });
        }
        if v.n > 2 {
            let n2 = v.n - 1;
            out.push(GraphCase {
                n: n2,
                edges: v
                    .edges
                    .iter()
                    .copied()
                    .filter(|&(a, b)| (a as usize) < n2 && (b as usize) < n2)
                    .collect(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(1, 50, &UsizeRange(0, 100), |&x| x <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics_with_counterexample() {
        check(2, 100, &UsizeRange(0, 1000), |&x| x < 500);
    }

    #[test]
    fn shrinking_finds_small_case() {
        // capture the panic message and ensure the shrunk value is minimal
        let result = std::panic::catch_unwind(|| {
            check(3, 200, &UsizeRange(0, 1_000), |&x| x < 700);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink should land well below the generated failure
        assert!(msg.contains("counterexample"), "{msg}");
        let value: usize = msg
            .rsplit(':')
            .next()
            .unwrap()
            .trim()
            .parse()
            .expect("numeric counterexample");
        assert!(value >= 700 && value <= 720, "poorly shrunk: {value}");
    }

    #[test]
    fn graph_strategy_generates_valid_edges() {
        let s = GraphStrategy {
            max_n: 30,
            max_extra_edges: 50,
        };
        let mut rng = Pcg64::new(4);
        for _ in 0..50 {
            let g = s.generate(&mut rng);
            assert!(g.n >= 2);
            for &(u, v) in &g.edges {
                assert!((u as usize) < g.n && (v as usize) < g.n && u != v);
            }
        }
    }
}
